"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA [arXiv:2401.16818; unverified].

Sliding-window attention (mistral-style, 4096 window) makes the KV cache
O(window), so this arch RUNS the long_500k cell (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    rope="rope",
    rope_theta=10_000.0,
    sliding_window=4096,
    act="swiglu",
)
SMOKE = CONFIG.smoke()
