"""Architecture registry: ``--arch <id>`` -> ModelConfig.

All 10 assigned architectures plus the paper's own CNN benchmark family
(used by the faithful reproduction, see repro/cnn/).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    LONG_CONTEXT_OK,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_runnable,
)

# arch id -> module name
ARCHS: dict[str, str] = {
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "glm4-9b": "glm4_9b",
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "musicgen-large": "musicgen_large",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    key = arch.replace("_", "-")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choices: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[key]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)
