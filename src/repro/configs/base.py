"""Config schema: architectures and input shapes.

One ``ModelConfig`` per assigned architecture (exact numbers from the brief)
plus reduced smoke variants.  ``ShapeConfig`` covers the 4 assigned input
shapes.  Everything is a frozen dataclass — hashable, usable as a jit static
argument.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    # positions / attention flavor
    rope: str = "rope"          # rope | mrope | abs_sin | none
    rope_theta: float = 1e4
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # half-dim split
    sliding_window: int | None = None
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_interleave: int = 1     # 1 = every layer MoE; 2 = alternate dense/MoE
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    wkv_lora_rank: int = 64
    chunk_size: int = 64        # linear-attention chunk length
    # frontend stub (vlm/audio): inputs arrive as precomputed embeddings
    frontend: str | None = None
    act: str = "swiglu"         # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # quantization: groups matching these prefixes are frozen at 8 bits
    # (paper keeps first/last layers at high precision; we freeze routers too)
    frozen_at_8: tuple[str, ...] = ("embed", "lm_head", "router")
    # attention flash chunk sizes
    q_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=2 if self.moe_interleave == 1 else 2 * self.moe_interleave,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=96,
            vocab_size=251,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            wkv_lora_rank=8,
            chunk_size=8,
            sliding_window=8 if self.sliding_window else None,
            q_chunk=16,
            kv_chunk=16,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Sub-quadratic archs for which long_500k is runnable (DESIGN.md §5):
# SSM (O(1) state), hybrid (SSM + windowed KV), SWA-dense (windowed KV).
LONG_CONTEXT_OK = ("rwkv6-1.6b", "hymba-1.5b", "h2o-danube-3-4b")


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "full-attention arch: 500k dense KV decode is the quadratic regime (skip per brief)"
    return True, ""
