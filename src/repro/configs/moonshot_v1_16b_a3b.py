"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight [hf:moonshotai/Moonlight-16B-A3B; hf].

Every layer is MoE (interleave=1); d_ff=1408 is the per-expert hidden dim.
Router frozen at 8 bits for ReLeQ (sensitivity — paper's first/last rule).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    moe_interleave=1,
    rope="rope",
    rope_theta=50_000.0,
    act="swiglu",
)
SMOKE = CONFIG.smoke()
