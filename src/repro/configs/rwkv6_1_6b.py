"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; unverified].

head_dim=64 (RWKV standard) -> 32 wkv heads.  O(1) decode state, so this
arch RUNS long_500k.  chunk_size=16 bounds the pairwise intra-chunk decay
tensor (see models/rwkv.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rope="none",
    wkv_lora_rank=64,
    chunk_size=16,
    act="swiglu",  # unused by rwkv blocks
)
SMOKE = CONFIG.smoke()
