"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, MoE 128e top-1 — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Maverick-style interleaved MoE: every other layer routes over 128 experts
(top-1) with a shared expert in parallel; the alternate layers are dense.
Param accounting at these numbers: attn ≈3.0B + routed 24·128·3·D·F ≈387B +
shared ≈3.0B + dense FFN ≈3.0B + embeddings ≈2.1B ≈ 398B total, ≈15-17B
active per token — matching the 400b-a17b name.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_interleave=2,
    shared_expert=True,
    rope="rope",
    rope_theta=500_000.0,
    act="swiglu",
)
SMOKE = CONFIG.smoke()
