"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676; hf].

Each block runs attention and a Mamba SSM branch in PARALLEL on the same
normed input, combined with a learned per-layer mix (the Hymba signature).
Sliding-window attention (1024) + O(1) SSM state -> RUNS long_500k.
Meta-tokens are omitted (backbone-only; noted in DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
    rope="rope",
    rope_theta=10_000.0,
    act="swiglu",
)
SMOKE = CONFIG.smoke()
