"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only (per brief): the vision frontend is a STUB — ``input_specs``
provides precomputed patch embeddings (B, S, D) plus the (3, B, S) M-RoPE
position streams (temporal / height / width).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # hd=128 -> half-dim 64 split
    frontend="vision",
    act="swiglu",
)
SMOKE = CONFIG.smoke()
