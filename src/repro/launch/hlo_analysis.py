"""HLO-text analysis: loop-aware flops / HBM bytes / collective bytes.

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts each
``while``-loop *body once* — verified with a minimal scan reproducer
(scan of 10 matmuls reports the flops of 1).  Every interesting program
here is a ``lax.scan`` over layers with further scans inside (flash
attention kv blocks, rwkv/mamba chunks), so flops, bytes AND collective
traffic would be undercounted by 1-3 orders of magnitude.

This module parses the *optimized* HLO text instead:

1. split the module into named computations,
2. recover each while loop's trip count from its condition computation
   (XLA canonicalizes counted loops to ``compare(iv, constant), LT``),
3. build the call-graph multiplier: entry=1, while-body ×= trip count,
   fusions/calls ×= 1,
4. per computation, accumulate
   - dot/conv flops (2 × numel(out) × contraction size),
   - HBM traffic ≈ Σ over top-level instructions of (operands + output)
     bytes — post-fusion instruction boundaries approximate real traffic,
   - collective payload bytes by kind,
   each scaled by the computation's multiplier.

Validated in tests/test_hlo_analysis.py against known-flop programs.

Roofline (TPU v5e targets):  compute = flops / 197e12,
memory = bytes / 819e9, collective = coll_bytes / 50e9 — all per chip
(SPMD HLO is already the per-partition program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _parse_shape(s: str):
    """'bf16[16,4096,3072]' -> (dtype, [dims]); tuples -> list of both."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _parse_shape(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)  # (var, out_shape_str, op, rest)
    shapes: dict = field(default_factory=dict)  # var -> shape str


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{$")
_INSTR = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:{[^}]*})?))\s*"
    r"([\w\-]+)\((.*)$")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        m = _COMP_HDR.match(line)
        if m and line.endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            # parameters appear in the header; shapes resolved per-instr below
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            var, shape, op, rest = mi.groups()
            cur.instrs.append((var, shape, op, rest))
            cur.shapes[var] = shape
    return comps


def _while_trip_count(cond: Computation) -> int:
    """Counted loops canonicalize to compare(iv, K), direction=LT."""
    const_vals = {}
    for var, shape, op, rest in cond.instrs:
        if op == "constant":
            m = re.match(r"([\-\d]+)", rest)
            if m and shape.startswith(("s32[]", "s64[]", "u32[]", "u64[]")):
                const_vals[var] = int(m.group(1))
    for var, shape, op, rest in cond.instrs:
        if op == "compare":
            refs = re.findall(r"%?([\w\.\-]+)", rest)
            for r in refs:
                if r in const_vals:
                    return max(const_vals[r], 1)
    return 1


def _dot_flops(shape_str: str, rest: str, shapes: dict) -> float:
    """dot: 2 × numel(out) × contraction size (from lhs shape + dims)."""
    out = _parse_shape(shape_str)
    if not out:
        return 0.0
    out_numel = _numel(out[0][1])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    ops = re.findall(r"%?([\w\.\-]+)", rest)
    lhs_shape = None
    for o in ops:
        if o in shapes:
            lhs_shape = _parse_shape(shapes[o])
            break
    if not m or not lhs_shape:
        return 2.0 * out_numel  # degenerate
    dims = [int(d) for d in m.group(1).split(",") if d]
    k = 1
    for d in dims:
        if d < len(lhs_shape[0][1]):
            k *= lhs_shape[0][1][d]
    return 2.0 * out_numel * k


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "call",
                 "get-dimension-size", "after-all", "partition-id"}
# computations entered via these edges run *inside* an op — their
# instructions do not individually touch HBM (the call site's operands and
# output are the traffic)
_INLINE_EDGE = re.compile(
    r"(?:calls=|to_apply=|comparator=|update_computation=|select=|scatter=)"
    r"%?([\w\.\-]+)")
_BRANCH_EDGE = re.compile(
    r"(?:(?:true|false)_computation=|on_true=|on_false=|branch_computations=\{)"
    r"%?([\w\.\-]+)")


@dataclass
class HLOCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    coll_bytes_by_kind: dict = field(default_factory=dict)
    coll_count_by_kind: dict = field(default_factory=dict)
    trip_counts: dict = field(default_factory=dict)
    breakdown: list = field(default_factory=list)  # (bytes, comp, var, op)

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_kind.values()))

    def coll_summary(self) -> str:
        ks = sorted(self.coll_bytes_by_kind)
        return ", ".join(
            f"{k}:{self.coll_count_by_kind[k]}x/{self.coll_bytes_by_kind[k]/1e6:.0f}MB"
            for k in ks) or "none"


def analyze_hlo(text: str) -> HLOCosts:
    comps = parse_hlo(text)
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = m.group(1) if m else next(iter(comps), None)

    # comp -> (multiplier, counts_traffic)
    mult: dict[str, float] = {name: 0.0 for name in comps}
    inline: dict[str, bool] = {name: False for name in comps}
    if entry in mult:
        mult[entry] = 1.0

    def callees(comp: Computation):
        """yield (callee, trip_multiplier, is_inline)."""
        for var, shape, op, rest in comp.instrs:
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", rest)
                if mb and mc and mb.group(1) in comps and mc.group(1) in comps:
                    mt = re.search(r'known_trip_count[^0-9]*(\d+)', rest)
                    tc = int(mt.group(1)) if mt else _while_trip_count(
                        comps[mc.group(1)])
                    yield mb.group(1), float(tc), False
                    yield mc.group(1), float(tc), True  # cond: negligible traffic
            else:
                for mm in _INLINE_EDGE.finditer(rest):
                    if mm.group(1) in comps:
                        yield mm.group(1), 1.0, True
                for mm in _BRANCH_EDGE.finditer(rest):
                    if mm.group(1) in comps:
                        yield mm.group(1), 1.0, False

    changed, rounds = True, 0
    while changed and rounds < 64:
        changed = False
        rounds += 1
        for name, comp in comps.items():
            base = mult.get(name, 0.0)
            if base <= 0:
                continue
            for callee, k, is_inline in callees(comp):
                new = base * k
                if new > mult.get(callee, 0.0):
                    mult[callee] = new
                    inline[callee] = is_inline
                    changed = True

    costs = HLOCosts()
    for name, comp in comps.items():
        m_ = mult.get(name, 0.0)
        if m_ <= 0:
            continue
        count_traffic = not inline.get(name, False)
        for var, shape, op, rest in comp.instrs:
            if op == "while":
                mt = re.search(r'known_trip_count[^0-9]*(\d+)', rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", rest)
                costs.trip_counts[var] = (
                    int(mt.group(1)) if mt else _while_trip_count(
                        comps.get(mc.group(1), Computation(""))) if mc else 1)
            if op in ("dot", "convolution"):
                costs.flops += m_ * _dot_flops(shape, rest, comp.shapes)
            for kind in _COLLECTIVE_KINDS:
                if op == kind or op == kind + "-start":
                    b = _shape_bytes(shape)
                    costs.coll_bytes_by_kind[kind] = (
                        costs.coll_bytes_by_kind.get(kind, 0) + m_ * b)
                    costs.coll_count_by_kind[kind] = (
                        costs.coll_count_by_kind.get(kind, 0) + 1)
            if (count_traffic and op not in _SKIP_TRAFFIC
                    and not op.endswith("-done")):
                # OUTPUT-based traffic: every byte produced is written once
                # and read ~once downstream (2×out).  Operand sums would
                # massively overcount fusions that embed a dynamic-slice of
                # a large stacked buffer (they read a slice, not the buffer).
                # dynamic-update-slice aliases its big operand: charge the
                # update window, not the full result.
                out_b = _shape_bytes(shape)
                if op in ("dynamic-update-slice", "scatter"):
                    oper_str = rest.split(")")[0]
                    opers = [_shape_bytes(comp.shapes[o])
                             for o in re.findall(r"%?([\w\.\-]+)", oper_str)
                             if o in comp.shapes]
                    small = [b for b in opers if b < out_b]
                    out_b = max(small) if small else out_b
                costs.traffic_bytes += m_ * 2 * out_b
                if m_ * 2 * out_b > 1e9:
                    costs.breakdown.append(
                        (m_ * 2 * out_b, name, var, op, shape[:70]))
    costs.breakdown.sort(reverse=True)
    return costs


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


@dataclass
class Roofline:
    flops: float               # per-chip loop-corrected HLO flops
    hbm_bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0
    collectives: str = ""

    @property
    def t_total(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful model flops per chip vs what the bottleneck allows."""
        if self.t_total <= 0:
            return 0.0
        return (self.model_flops / V5E["peak_flops"]) / self.t_total

    def row(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "model_flops": self.model_flops, "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
        }


def roofline_from_costs(costs: HLOCosts, *, chips: int, model_flops: float,
                        hw=V5E) -> Roofline:
    t_c = costs.flops / hw["peak_flops"]
    t_m = costs.traffic_bytes / hw["hbm_bw"]
    t_x = costs.coll_bytes / hw["ici_bw"]
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    return Roofline(costs.flops, costs.traffic_bytes, costs.coll_bytes,
                    t_c, t_m, t_x, bottleneck=max(terms, key=terms.get),
                    model_flops=model_flops / chips,
                    collectives=costs.coll_summary())
