import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct inputs (launch/specs.py — no allocation),
  3. ``jit(step).lower(...).compile()`` with the dist/sharding.py specs,
  4. prints ``memory_analysis()`` (proves fit) and ``cost_analysis()``,
  5. parses the optimized HLO for collective bytes,
  6. writes the roofline record to benchmarks/results/dryrun/.

Run one cell:   python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh pod
Run everything: python -m repro.launch.dryrun --all        (spawns subprocesses)

The 512 fake CPU devices exist ONLY in this process — never set the
XLA_FLAGS override globally.
"""
import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _log(msg):
    print(msg, flush=True)


DEFAULT_PROFILE = {"train": "fsdp", "prefill": "tp", "decode": "tp"}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, serve_bits: int = 4,
             remat: str = "full", out_dir: str | None = None,
             seq_shard: bool | None = None, profile: str | None = None,
             tag: str = "") -> dict:
    from repro.configs import SHAPES, cell_is_runnable, get_config
    from repro.dist import sharding as shd
    from repro.launch import specs as S
    from repro.launch.hlo_analysis import analyze_hlo, roofline_from_costs
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.quant.qat import bits_assignment, policy_for
    from repro.train.train_step import make_eval_step  # noqa: F401 (import check)

    shape = SHAPES[shape_name]
    # per-kind default (train: fsdp — the layout that fits every arch in
    # 16 GB; serve cells: tp); shard_profile() reads the env var lazily
    profile = profile or DEFAULT_PROFILE[shape.kind]
    os.environ["REPRO_SHARD_PROFILE"] = profile
    ok, reason = cell_is_runnable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    n_params = S.count_params(model)
    n_active = S.active_params(cfg, model)
    if seq_shard is None:
        seq_shard = shape.seq_len >= 32_768

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt = AdamW(lr=3e-4, weight_decay=0.1,
                        moments="int8" if n_params > 60e9 else "fp32")
            groups = model.quant_groups(seq_len=shape.seq_len)
            policy = policy_for(model, default_bits=8)
            bits_map = {k: jnp.asarray(v)
                        for k, v in bits_assignment(groups, policy).items()}

            def step(state, batch, bm):
                from repro.quant.qat import quantize_params

                def loss_fn(p):
                    qp = quantize_params(p, bm, groups)
                    return model.loss(qp, batch, remat=remat)

                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"])
                new_p, new_o = opt.update(state["params"], grads, state["opt"])
                return {"params": new_p, "opt": new_o}, loss

            pstruct = S.params_struct(model)
            ostruct = jax.eval_shape(opt.init, pstruct)
            state_struct = {"params": pstruct, "opt": ostruct}
            batch = S.batch_struct(cfg, shape, train=True)
            st_specs = shd.state_specs(state_struct, mesh)
            in_sh = (shd.to_named(st_specs, mesh),
                     shd.to_named(shd.batch_specs(batch, mesh, seq_shard=False), mesh),
                     None)
            lowered = jax.jit(step, in_shardings=in_sh,
                              donate_argnums=(0,)).lower(
                state_struct, batch, bits_map)
            model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len

        elif shape.kind == "prefill":
            def step(params, batch):
                logits, _ = model.forward(
                    params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                    positions=batch.get("positions"), remat="full")
                return logits.astype(jnp.bfloat16)

            pstruct = S.params_struct(model)
            batch = S.batch_struct(cfg, shape, train=False)
            in_sh = (shd.to_named(shd.param_specs(pstruct, mesh), mesh),
                     shd.to_named(shd.batch_specs(batch, mesh, seq_shard=seq_shard), mesh))
            lowered = jax.jit(step, in_shardings=in_sh).lower(pstruct, batch)
            model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len

        else:  # decode
            policy = policy_for(model, default_bits=serve_bits)
            sparams, cache, tokens = S.decode_structs(model, shape, policy)

            def step(sp, c, t):
                logits, c2 = model.decode_step(sp, c, t)
                return logits.astype(jnp.bfloat16), c2

            cache_sh = shd.to_named(shd.cache_specs(cache, mesh), mesh)
            in_sh = (shd.to_named(shd.param_specs(sparams, mesh), mesh),
                     cache_sh,
                     shd.to_named(shd.batch_specs(tokens, mesh), mesh))
            # out cache sharding pinned to the in sharding so donation aliases
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=(None, cache_sh),
                              donate_argnums=(1,)).lower(sparams, cache, tokens)
            model_flops = 2.0 * n_active * shape.global_batch * serve_bits / 8.0

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()  # XLA:CPU: while bodies counted ONCE
    if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    costs = analyze_hlo(compiled.as_text())  # loop-corrected (see hlo_analysis)
    rl = roofline_from_costs(costs, chips=chips, model_flops=model_flops)

    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)  # donated in/out overlap
    mem_d = {
        "argument_bytes": arg_b, "output_bytes": out_b, "temp_bytes": tmp_b,
        "alias_bytes": alias_b,
        "peak_bytes": arg_b + tmp_b + max(out_b - alias_b, 0),
    }
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "status": "ok", "params": n_params, "active_params": n_active,
        "profile": profile, "remat": remat, "serve_bits": serve_bits,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_d, "roofline": rl.row(),
        "fits_16g": mem_d["peak_bytes"] < 16e9,
    }
    _log(f"[dryrun] {arch} × {shape_name} × {mesh_kind} ({profile}): "
         f"peak/device={mem_d['peak_bytes']/1e9:.2f} GB "
         f"flops/chip={rl.flops:.3e} bottleneck={rl.bottleneck} "
         f"roofline_frac={rl.roofline_fraction:.3f} "
         f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    _log(f"  memory_analysis: {mem_d}")
    _log(f"  terms: compute={rl.t_compute*1e3:.1f}ms memory={rl.t_memory*1e3:.1f}ms "
         f"collective={rl.t_collective*1e3:.1f}ms useful={rl.useful_ratio:.2f}")
    _log(f"  raw cost_analysis (uncorrected): flops={cost.get('flops'):.3e}")
    _log(f"  collectives: {costs.coll_summary()}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_kind}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def run_dp_collectives(arch: str = "glm4-9b", *, planes: int = 2,
                       devices: int = 8, seq_len: int = 64,
                       global_batch: int = 8,
                       out_dir: str | None = None) -> dict:
    """Wire-byte report for the compressed data-parallel gradient path.

    Compiles ``train_step.make_dp_train_step`` twice on a ``devices``-wide
    data mesh — fp8-plane compressed all-reduce (error feedback carried in
    the train state) vs the exact fp32 pmean — and reports the *measured*
    gradient-collective payload bytes from each optimized HLO
    (``hlo_analysis``: trip-count-corrected).  Smoke-sized model: the
    ratio is what matters and it is size-invariant (planes + 4/n vs 4
    bytes per gradient element).
    """
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data import SyntheticLMData
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.quant.qat import bits_assignment, policy_for
    from repro.train.train_step import init_dp_state, make_dp_train_step

    prev_profile = os.environ.get("REPRO_SHARD_PROFILE")
    os.environ["REPRO_SHARD_PROFILE"] = "dp"
    try:
        mesh = jax.make_mesh((devices,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        bm = {k: jnp.asarray(v) for k, v in bits_assignment(
            model.quant_groups(), policy_for(model, 8)).items()}
        n_grad = sum(l.size for l in jax.tree.leaves(model.init(
            jax.random.PRNGKey(0))))

        wire = {}
        with jax.set_mesh(mesh):
            state = init_dp_state(model, opt, jax.random.PRNGKey(0), mesh)
            data = SyntheticLMData(seed=0, global_batch=global_batch,
                                   seq_len=seq_len, vocab=cfg.vocab_size)
            batch = {k: jnp.asarray(v) for k, v in data.next().items()}
            # send-bytes per device from the measured per-kind payloads: a
            # ring all-reduce sends 2(n-1)/n x its payload, all-gather/
            # all-to-all send (n-1)/n x their (output) payload
            frac = (devices - 1) / devices
            send_mult = {"all-reduce": 2 * frac, "all-gather": frac,
                         "all-to-all": frac, "reduce-scatter": frac,
                         "collective-permute": 1.0}
            for name, p in (("compressed", planes), ("exact", 0)):
                step = make_dp_train_step(model, opt, mesh, planes=p,
                                          donate=False)
                compiled = jax.jit(step).lower(state, batch, bm).compile()
                costs = analyze_hlo(compiled.as_text())
                wire[name] = {
                    "payload_bytes": round(costs.coll_bytes),
                    "send_bytes": round(sum(
                        send_mult.get(k, 1.0) * v
                        for k, v in costs.coll_bytes_by_kind.items())),
                    "by_kind": {k: round(v) for k, v in
                                costs.coll_bytes_by_kind.items()},
                }
    finally:
        if prev_profile is None:
            os.environ.pop("REPRO_SHARD_PROFILE", None)
        else:
            os.environ["REPRO_SHARD_PROFILE"] = prev_profile
    red = (wire["exact"]["send_bytes"]
           / max(wire["compressed"]["send_bytes"], 1.0))
    rec = {
        "benchmark": "dp_collectives", "arch": cfg.name, "devices": devices,
        "planes": planes, "grad_elements": n_grad,
        "wire": wire, "send_reduction_x": round(red, 3),
        "analytic_send_bytes_per_elem": {
            "exact": 8.0 * frac,
            "compressed": 2.0 * planes * frac,
        },
    }
    _log(f"[dp-collectives] {cfg.name} x{devices}dev planes={planes}: "
         f"exact send={wire['exact']['send_bytes']/1e6:.1f}MB "
         f"compressed send={wire['compressed']['send_bytes']/1e6:.1f}MB "
         f"-> {red:.2f}x wire reduction")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"dp_collectives_{arch}.json"),
                  "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def run_all(meshes=("pod", "multipod"), out_dir=RESULTS_DIR, archs=None,
            shapes=None, timeout: int = 3600, profile: str | None = None):
    """Spawn one subprocess per cell (isolates the 512-device client and
    caps compile-memory growth).  profile=None picks the per-kind default
    (train cells: fsdp — the layout that fits every arch in 16 GB;
    serve cells: tp)."""
    from repro.configs import SHAPES, all_archs, cell_is_runnable

    archs = archs or all_archs()
    shapes = shapes or list(SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            cell_profile = profile or DEFAULT_PROFILE[SHAPES[shape].kind]
            for mesh in meshes:
                ok, reason = cell_is_runnable(arch, shape)
                fn = os.path.join(out_dir, f"{arch}_{shape}_{mesh}.json")
                if not ok:
                    os.makedirs(out_dir, exist_ok=True)
                    with open(fn, "w") as f:
                        json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                                   "status": "skipped", "reason": reason}, f)
                    _log(f"[dryrun] SKIP {arch} × {shape}: {reason}")
                    continue
                if os.path.exists(fn):
                    _log(f"[dryrun] cached {arch} × {shape} × {mesh}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh,
                       "--profile", cell_profile, "--out", out_dir]
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout)
                _log(r.stdout.strip())
                if r.returncode != 0:
                    _log(f"[dryrun] FAIL {arch} × {shape} × {mesh} "
                         f"({time.time()-t0:.0f}s):\n{r.stderr[-3000:]}")
                    results.append({"arch": arch, "shape": shape, "mesh": mesh,
                                    "status": "fail"})
                else:
                    results.append({"arch": arch, "shape": shape, "mesh": mesh,
                                    "status": "ok"})
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--profile", default=None, choices=[None, "tp", "tp_sp", "fsdp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--dp-collectives", action="store_true",
                    help="measure compressed-vs-exact DP gradient wire "
                         "bytes (PR-2 follow-up) instead of a cell compile")
    ap.add_argument("--planes", type=int, default=2)
    args = ap.parse_args()
    if args.dp_collectives:
        run_dp_collectives(args.arch or "glm4-9b", planes=args.planes,
                           out_dir=args.out or RESULTS_DIR)
        return
    if args.all:
        run_all(out_dir=args.out or RESULTS_DIR, profile=args.profile)
        return
    run_cell(args.arch, args.shape, args.mesh, serve_bits=args.bits,
             remat=args.remat, out_dir=args.out, profile=args.profile,
             tag=args.tag)


if __name__ == "__main__":
    main()
