"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant QAT training loop (trainer.py) on whatever devices
exist — the production entry point a real fleet would invoke per host.  On
this CPU container it drives the reduced configs (--smoke, default); on a
TPU slice drop --smoke and point --mesh at the pod shape.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim import AdamW, cosine_schedule
from repro.quant.policy import QuantPolicy
from repro.quant.qat import bits_assignment, policy_for
from repro.train.train_step import init_state, make_train_step
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--bits", type=int, default=8, help="uniform QAT bits")
    ap.add_argument("--policy-json", default=None,
                    help="QuantPolicy JSON from a ReLeQ search")
    ap.add_argument("--opt8", action="store_true", help="8-bit Adam moments")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="mesh shape over local devices, e.g. '2,4'; enables "
                         "sharded training + elastic checkpoint restore")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)],
                             axis_types=(jax.sharding.AxisType.Auto,) * len(shape))

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=20, total=args.steps),
                weight_decay=0.1, moments="int8" if args.opt8 else "fp32")
    groups = model.quant_groups(seq_len=args.seq_len)
    if args.policy_json:
        policy = QuantPolicy.from_file(args.policy_json)
    else:
        policy = policy_for(model, default_bits=args.bits)
    bits_map = {k: jnp.asarray(v)
                for k, v in bits_assignment(groups, policy).items()}

    data = SyntheticLMData(seed=args.seed, global_batch=args.global_batch,
                           seq_len=args.seq_len, vocab=cfg.vocab_size)
    state = init_state(model, opt, jax.random.PRNGKey(args.seed))
    step_fn = make_train_step(model, opt, remat=args.remat)
    trainer = Trainer(model=model, optimizer=opt, data=data, step_fn=step_fn,
                      bits_map=bits_map, ckpt_dir=args.ckpt_dir, mesh=mesh)
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"training {args.arch} ({n/1e6:.1f}M params, QAT "
          f"avg {policy.average_bits():.1f} bits) for {args.steps} steps"
          + (f" on mesh {mesh.shape}" if mesh is not None else ""))
    if mesh is not None:
        from repro.dist import elastic

        with jax.set_mesh(mesh):
            trainer.run(elastic.place(state, mesh), args.steps)
    else:
        trainer.run(state, args.steps)


if __name__ == "__main__":
    main()
