"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape)`` returns the exact pytree of structs the jit'd
step is lowered against: training batches (tokens/labels — or precomputed
frontend embeddings for the vlm/audio stub archs), serving caches, packed
serving params.  Weak-type-correct, shardable, zero bytes allocated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, *, train: bool) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if cfg.frontend:  # stub modality frontend: precomputed embeddings
        out["embeds"] = sds((B, S, cfg.d_model), cfg.dtype)
    else:
        out["tokens"] = sds((B, S), jnp.int32)
    if cfg.rope == "mrope":
        out["positions"] = sds((3, B, S), jnp.int32)
    if train:
        out["labels"] = sds((B, S), jnp.int32)
    return out


def params_struct(model):
    return jax.eval_shape(model.init, jax.random.key(0))


def serving_params_struct(model, policy):
    from repro.train.serve import quantize_for_serving

    pstruct = params_struct(model)
    return jax.eval_shape(
        lambda p: quantize_for_serving(model, p, policy), pstruct)


def cache_struct(model, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len=max_len))


def decode_structs(model, shape: ShapeConfig, policy):
    """(serving params, cache, one-token batch) structs for serve_step."""
    sparams = serving_params_struct(model, policy)
    cache = cache_struct(model, shape.global_batch, shape.seq_len)
    # decode against a *full* cache (length = seq_len context)
    tokens = sds((shape.global_batch, 1), jnp.int32)
    return sparams, cache, tokens


def count_params(model) -> int:
    import math

    return sum(math.prod(x.shape) for x in jax.tree.leaves(params_struct(model)))


def active_params(cfg: ModelConfig, model) -> int:
    """Per-token active parameters (MoE: routed k of E)."""
    total = count_params(model)
    if not cfg.num_experts:
        return total
    groups = model.quant_groups()
    bank = sum(g.n_weights for g in groups if "/moe/" in "/".join(map(str, g.path))
               or "moe." in g.name)
    return int(total - bank + bank * cfg.experts_per_token / cfg.num_experts)
