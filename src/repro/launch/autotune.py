"""Autotune launcher: ``python -m repro.launch.autotune --arch glm4-9b``.

One command from "pretrained params" to "discovered policy is serving":

1. pretrain (or restore) the reduced model,
2. run the asynchronous ReLeQ search service (``repro.autotune``) with
   short-QAT accuracy workers and, optionally, hardware-in-the-loop
   latency workers (``--hw engine|hlo|analytic``),
3. checkpoint the Pareto archive (``--archive``: JSON, warm-started if
   the file already exists — searches compose across runs),
4. ``--deploy``: pull the ``--select`` winner, bit-pack its weights,
   hot-swap them into a live ServeEngine and run the A/B parity gate.

``--task cnn:lenet`` swaps the LM substrate for the paper's CNN oracle.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np


def _build_lm(args):
    from repro.configs import get_config
    from repro.core.search import make_lm_env_factory
    from repro.data import SyntheticLMData
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.quant.qat import bits_assignment, policy_for
    from repro.train.train_step import init_state, make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    data = SyntheticLMData(seed=0, global_batch=8, seq_len=32,
                           vocab=cfg.vocab_size)
    opt = AdamW(lr=3e-3)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = make_train_step(model, opt)
    bm = {k: jax.numpy.asarray(v) for k, v in bits_assignment(
        model.quant_groups(), policy_for(model, 8)).items()}
    print(f"== pretraining reduced {args.arch} "
          f"({args.pretrain_steps} steps) ==", flush=True)
    m = {}
    for _ in range(args.pretrain_steps):
        state, m = step(state, data.next(), bm)
    if m:
        print(f"pretrain loss: {float(m['loss']):.3f}")
    params = state["params"]
    factory = make_lm_env_factory(model, params, data,
                                  finetune_steps=args.finetune_steps,
                                  eval_mode="deferred")
    return model, params, factory, model.quant_groups(), model.frozen_bits()


def _build_cnn(args, net: str):
    from repro.cnn import CNNTask

    task = CNNTask(net, seed=0)
    print(f"== pretraining {net} ({args.pretrain_steps} steps) ==", flush=True)
    task.pretrain(args.pretrain_steps)
    print(f"fp accuracy: {task.fp_acc:.3f}")
    factory = task.make_env_factory(retrain_steps=args.finetune_steps,
                                    eval_mode="deferred")
    # no ServeEngine deploy path for CNNs, but the analytic hw signal works
    return None, None, factory, task.groups, task.frozen


def _latency_eval(args, model, params, groups, frozen):
    from repro.autotune import (
        AnalyticLatencyEvaluator,
        EngineLatencyEvaluator,
        HLOLatencyEvaluator,
    )

    if args.hw == "none":
        return None
    if args.hw in ("engine", "hlo") and model is None:
        raise SystemExit(f"--hw {args.hw} needs the LM serving stack "
                         f"(--task lm); CNN tasks support --hw analytic")
    if args.hw == "engine":
        return EngineLatencyEvaluator(model, params,
                                      num_slots=args.hw_slots,
                                      decode_steps=args.hw_decode_steps)
    if args.hw == "hlo":
        return HLOLatencyEvaluator(model)
    return AnalyticLatencyEvaluator(groups, frozen)


def main():
    from repro.autotune import (
        AutotuneService,
        ParetoArchive,
        ServiceConfig,
        deploy as deploy_policy_to_engine,
    )

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--task", default="lm",
                    help="'lm' or 'cnn:<net>' (lenet, simplenet, ...)")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--episodes", type=int, default=24)
    ap.add_argument("--pretrain-steps", type=int, default=120)
    ap.add_argument("--finetune-steps", type=int, default=2)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--inflight", type=int, default=8)
    ap.add_argument("--batch-episodes", type=int, default=4)
    ap.add_argument("--max-staleness", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hw", choices=("none", "analytic", "hlo", "engine"),
                    default="analytic",
                    help="latency evaluator: measured ServeEngine decode "
                         "steps (engine), compiled-HLO roofline (hlo), "
                         "closed-form TPU model (analytic), or none")
    ap.add_argument("--hw-weight", type=float, default=0.5,
                    help="latency-ratio share of the terminal quant state")
    ap.add_argument("--hw-slots", type=int, default=2)
    ap.add_argument("--hw-decode-steps", type=int, default=8)
    ap.add_argument("--archive", default=None,
                    help="Pareto archive JSON (warm-started when present)")
    ap.add_argument("--deploy", action="store_true",
                    help="hot-swap the archive winner into a ServeEngine "
                         "and run the A/B parity gate")
    ap.add_argument("--select", default="knee",
                    choices=("knee", "accuracy", "efficiency", "latency",
                             "reward"))
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record episode rollouts, evaluator-pool work "
                         "and PPO updates as a Chrome-trace file")
    ap.add_argument("--log-json", action="store_true",
                    help="structured logs as JSON lines instead of text")
    args = ap.parse_args()

    if args.log_json:
        from repro.obs import configure
        configure(json_mode=True)

    if args.task.startswith("cnn:"):
        model, params, factory, groups, frozen = _build_cnn(
            args, args.task.split(":", 1)[1])
    else:
        model, params, factory, groups, frozen = _build_lm(args)

    latency_eval = _latency_eval(args, model, params, groups, frozen)
    objectives = ("acc", "sq", "latency") if latency_eval is not None \
        else ("acc", "sq")
    archive = ParetoArchive.warm_start(args.archive, objectives=objectives)
    if len(archive):
        print(f"warm-started archive: {len(archive)} entries")

    print(f"\n== async ReLeQ search: {args.episodes} episodes, "
          f"{args.workers} workers, hw={args.hw} ==", flush=True)
    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer

        tracer = Tracer(enabled=True)
        tracer.name_thread("actor-learner")
    service = AutotuneService(
        factory, latency_eval=latency_eval, archive=archive,
        config=ServiceConfig(num_workers=args.workers,
                             max_inflight=args.inflight,
                             batch_episodes=args.batch_episodes,
                             max_staleness=args.max_staleness,
                             hw_weight=args.hw_weight, seed=args.seed),
        tracer=tracer)
    result = service.run(args.episodes, log_every=4)
    service.shutdown()
    if tracer is not None:
        tracer.save(args.trace)
        print(f"wrote {tracer.num_events} trace events to {args.trace}")

    s = result.service_stats
    print(f"\nbest reward {result.best_reward:.4f} "
          f"(avg {result.average_bits():.2f} bits) after "
          f"{s['evals_to_best']} evaluations")
    print(f"throughput {s['episodes_per_s']:.2f} episodes/s, "
          f"{s['updates']} PPO updates (final version {s['policy_version']}, "
          f"{s['stale_dropped']} stale dropped), "
          f"cache hit-rate {result.cache_stats['hit_rate']:.2f}")
    print(f"archive: {len(archive)} non-dominated points")
    for e in archive.entries()[:8]:
        lat = f" lat={e.latency:.3e}s" if e.latency is not None else ""
        print(f"  acc={e.acc:.3f} sq={e.sq:.3f}{lat} "
              f"avg_bits={np.mean([b for _, b in e.bits]):.2f}")

    if args.archive:
        archive.save(args.archive)
        print(f"archive checkpointed to {args.archive}")

    if args.deploy:
        if model is None:
            raise SystemExit("--deploy needs the LM task (a ServeEngine)")
        from repro.serve import ServeEngine
        from repro.quant.qat import policy_for

        max_len = 16 + args.gen + 1
        engine = ServeEngine.from_params(
            model, params, policy_for(model, default_bits=8),
            num_slots=args.num_slots, max_len=max_len)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, model.cfg.vocab_size, 8) for _ in range(2)]
        policy, report = deploy_policy_to_engine(
            archive, model, params, engine, select=args.select,
            parity_prompts=prompts, max_new_tokens=args.gen)
        print(f"\ndeployed {args.select} winner "
              f"(avg {policy.average_bits():.2f} bits): "
              f"parity={'OK' if report['parity']['match'] else 'FAIL'}")
        print(json.dumps({k: v for k, v in report.items() if k != "parity"},
                         indent=2))


if __name__ == "__main__":
    main()
