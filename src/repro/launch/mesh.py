"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before jax initializes, and smoke tests must keep seeing one
device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips, ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Whatever-fits mesh for tests/examples on local devices."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n) if n > 1 else (1, 1)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
