"""Serving launcher: ``python -m repro.launch.serve --arch <id> --bits 4``.

Thin CLI over :class:`repro.serve.ServeEngine`.  Loads (or initializes)
params, packs them at a ReLeQ policy, and serves a synthetic workload:

- ``--mode continuous`` (default): staggered-arrival requests with
  heterogeneous output lengths, admitted mid-decode — reports tokens/s,
  per-request TTFT, row occupancy and (paged) preemptions + block
  occupancy.  ``--cache paged`` (default) uses the block-granular pool
  with chunked prefill; ``--cache slot`` keeps the legacy slot pool for
  one release as the parity baseline.
- ``--mode static``: the legacy one-shot fixed-batch greedy loop (kept
  as the parity/latency baseline).

``--kv-bits B [B ...]`` quantizes the paged KV blocks themselves (int8
codes + per-token-head scales, nibble-packed at 4 bits; one value per
layer or one for all) — ``--kv-oracle`` serves the same tokens from the
dequantized fp values as a parity check.

``--prefix-cache`` (default on, paged only) shares full prompt-prefix
KV blocks across sequences via the pool's refcounted trie; ``--tenants
N`` shapes the synthetic workload into N tenants sharing a system
prompt so the hit-rate/shared-blocks printout exercises it.

``--spec-k K --draft-bits B`` turns on speculative decoding with the
quantized self-draft (``repro.spec``): the same packed weights re-read
at B bitplanes roll K tokens per window and one batched verify call
scores them against the full-precision policy — output is distribution-
exact, so every other flag means the same thing with spec on.  Paged
cache only.

Token selection runs on device by default (``repro.serve.sampler``) with
a one-step-lookahead decode pipeline hiding the host loop —
``--host-sampling`` falls back to fetching full logits and sampling in
Python (the bisectable legacy path), ``--no-pipeline`` keeps device
sampling but dispatches synchronously; the summary line reports which
path ran plus the lookahead/bubble counts.

Observability (``repro.obs``): ``--trace out.json`` records the full
request lifecycle (queue wait, prefill chunks, decode device/host
split, spec windows, preempt/COW/evict instants) as a Chrome-trace
file loadable at ui.perfetto.dev; ``--metrics-interval N`` prints the
engine's registry snapshot every N steps; ``--log-json`` switches all
structured logs to JSON lines.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.quant.policy import QuantPolicy
from repro.quant.qat import policy_for
from repro.serve import SamplingParams, ServeEngine
from repro.train.serve import make_decode_step, quantize_for_serving


def _build(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    if args.ckpt_dir:
        from repro import ckpt as ckpt_lib

        tree, _, step = ckpt_lib.restore(args.ckpt_dir)
        params = jax.tree.map(jnp.asarray, tree["params"])
        print(f"restored step {step} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.PRNGKey(0))
    if args.policy_json:
        policy = QuantPolicy.from_file(args.policy_json)
    else:
        policy = policy_for(model, default_bits=args.bits)
    return cfg, model, quantize_for_serving(model, params, policy), policy


def _static(args, cfg, model, sparams, policy):
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    logits, cache = model.prefill(sparams, tokens=prompts,
                                  max_len=args.prompt_len + args.gen + 1)
    dec = make_decode_step(model, donate=False)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t0 = time.time()
    toks = [tok]
    for _ in range(args.gen):
        logits, cache = dec(sparams, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        toks.append(tok)
    dt = time.time() - t0
    print(f"served batch={args.batch} gen={args.gen} at "
          f"{dt / args.gen * 1e3:.1f} ms/token-step "
          f"(avg policy {policy.average_bits():.1f} bits)")
    print("first sequence:", jnp.concatenate(toks, 1)[0].tolist())


def _continuous(args, cfg, model, sparams, policy):
    from repro.obs import get_logger
    from repro.obs.trace import Tracer
    from repro.spec import SpecConfig

    max_len = args.prompt_len + args.gen + 1
    spec = (SpecConfig(k=args.spec_k, draft_bits=args.draft_bits)
            if args.spec_k else None)
    kv_kw = {}
    if args.kv_bits:
        kv_kw["kv_bits"] = (args.kv_bits[0] if len(args.kv_bits) == 1
                            else args.kv_bits)
        kv_kw["kv_oracle"] = args.kv_oracle
    tracer = Tracer(enabled=True) if args.trace else None
    if tracer is not None:
        tracer.name_thread("serve-loop")
    engine = ServeEngine(model, sparams, num_slots=args.num_slots,
                         max_len=max_len, cache=args.cache,
                         block_size=args.block_size,
                         num_blocks=args.num_blocks,
                         prefill_chunk=args.prefill_chunk,
                         prefix_cache=args.prefix_cache,
                         sample_device=not args.host_sampling,
                         pipeline=not args.no_pipeline,
                         spec=spec, tracer=tracer, **kv_kw)
    mlog = get_logger("serve.metrics")
    rng = np.random.default_rng(1)
    gens = [int(g) for g in
            rng.integers(max(1, args.gen // 2), args.gen + 1, args.requests)]
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len))
    if args.tenants:
        # multi-tenant mix: requests of one tenant share a system-prompt
        # prefix (3/4 of the prompt), exercising the prefix cache
        shared = args.prompt_len * 3 // 4
        sys_prompts = rng.integers(0, cfg.vocab_size,
                                   (args.tenants, shared))
        for i in range(args.requests):
            prompts[i, :shared] = sys_prompts[i % args.tenants]
    sampling = SamplingParams(temperature=args.temperature)
    submitted = 0
    while submitted < args.requests or engine.scheduler.has_work():
        # staggered arrivals: a fresh request every --arrival-every steps
        while (submitted < args.requests
               and engine.steps >= submitted * args.arrival_every):
            engine.submit(prompts[submitted], gens[submitted] + 1,
                          sampling=sampling)
            submitted += 1
        engine.step()
        if args.metrics_interval and engine.steps % args.metrics_interval == 0:
            m = engine.metrics()
            mlog.event("snapshot", step=engine.steps,
                       tokens=m["tokens_total"],
                       tokens_per_s=m["tokens_per_s"],
                       queued=engine.num_queued, running=engine.num_running,
                       recompiles=m["recompiles"])
    m = engine.metrics()
    print(f"served {args.requests} requests on {args.num_slots} "
          f"{args.cache} rows (avg policy {policy.average_bits():.1f} bits)")
    print(f"tokens/s={m['tokens_per_s']:.1f} occupancy={m['mean_occupancy']:.2f} "
          f"decode_steps={m['decode_steps']} tokens={m['tokens_total']}"
          + (f" preemptions={m['preemptions']} "
             f"block_occ={m['mean_block_occupancy']:.2f}"
             if args.cache == "paged" else ""))
    sm, pl = m["sampler"], m["pipeline"]
    print(f"sampler={'device' if sm['device'] else 'host'} "
          f"fallbacks={sm['fallbacks']} "
          f"pipeline={'on' if pl['enabled'] else 'off'} "
          f"lookahead={pl['lookahead_steps']} bubbles={pl['bubbles']} "
          f"device/host p50={m['decode_device_p50_ms']:.2f}/"
          f"{m['decode_host_p50_ms']:.2f} ms")
    if args.cache == "paged":
        pc = m["prefix_cache"]
        print(f"prefix_cache={'on' if pc['enabled'] else 'off'} "
              f"hit_rate={m['prefix_hit_rate']:.3f} "
              f"hits={m['prefix_hits']}/{m['prefix_lookups']} lookups "
              f"blocks_shared={m['blocks_shared']:.1f} "
              f"prefill_launches={m['prefill_launches']} "
              f"hit_tokens={pc['hit_tokens']} cow={pc['cow_copies']} "
              f"evictions={pc['evictions']}")
    if "spec" in m:
        s = m["spec"]
        print(f"spec k={s['k']} draft_bits={args.draft_bits} "
              f"windows={s['windows']} "
              f"acceptance={s['acceptance_rate']:.3f} "
              f"({s['accepted']}/{s['proposed']})")
    for r in m["requests"]:
        print(f"  req {r['id']}: {r['new_tokens']} tokens, "
              f"ttft={r['ttft_steps']} steps / {r['ttft_s'] * 1e3:.0f} ms, "
              f"latency={r['latency_s'] * 1e3:.0f} ms")
    print("first sequence:", engine.output(0))
    if tracer is not None:
        tracer.save(args.trace)
        print(f"wrote {tracer.num_events} trace events "
              f"({tracer.dropped} dropped) to {args.trace} — open at "
              f"ui.perfetto.dev or chrome://tracing")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--policy-json", default=None)
    ap.add_argument("--batch", type=int, default=4,
                    help="static mode: fixed batch size")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="continuous mode: max concurrent sequences")
    ap.add_argument("--cache", choices=("paged", "slot"), default="paged",
                    help="paged: block-granular pool + chunked prefill "
                         "(one executable for any prompt mix); slot: "
                         "legacy slot pool, kept one release for parity")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged cache: tokens per KV block")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged cache: physical KV blocks (default: full "
                         "slot-equivalent capacity; less oversubscribes "
                         "and may preempt)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="paged cache: fixed prefill chunk length")
    ap.add_argument("--kv-bits", type=int, nargs="+", default=None,
                    help="paged cache: quantize KV blocks to this many "
                         "bits (one value for all layers, or one per "
                         "layer; int8 codes + per-token-head scales, "
                         "nibble-packed at 4; requires --cache paged)")
    ap.add_argument("--kv-oracle", action="store_true",
                    help="store the dequantized fp KV values instead of "
                         "codes (parity oracle for --kv-bits; same "
                         "tokens, fp-size pool)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="paged cache: share full prompt-prefix KV blocks "
                         "across sequences (refcounted copy-on-write; "
                         "auto-off for ring/recurrent families)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="continuous mode: group requests into this many "
                         "tenants sharing a system-prompt prefix (0 = "
                         "fully unique prompts)")
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous mode: synthetic workload size")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="continuous mode: steps between request arrivals")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="continuous mode: speculate this many tokens per "
                         "window with the quantized self-draft (0 = off; "
                         "requires --cache paged)")
    ap.add_argument("--draft-bits", type=int, default=2,
                    help="bitwidth of the self-draft's packed-weight view "
                         "(fewer bitplanes read per draft step)")
    ap.add_argument("--host-sampling", action="store_true",
                    help="continuous mode: select tokens on the host from "
                         "fetched logits (the bisectable legacy path) "
                         "instead of the on-device fused sampler; implies "
                         "--no-pipeline")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="continuous mode: disable the one-step-lookahead "
                         "decode pipeline (synchronous dispatch/fetch "
                         "every step)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="continuous mode: record a Chrome-trace of the "
                         "run (queue wait, prefill chunks, decode "
                         "device/host split, spec windows, preempt/COW/"
                         "evict instants) — open at ui.perfetto.dev")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="continuous mode: log a registry snapshot line "
                         "every N engine steps (0 = off)")
    ap.add_argument("--log-json", action="store_true",
                    help="structured logs as JSON lines instead of text")
    args = ap.parse_args()

    if args.log_json:
        from repro.obs import configure
        configure(json_mode=True)
    cfg, model, sparams, policy = _build(args)
    if args.mode == "static":
        _static(args, cfg, model, sparams, policy)
    else:
        _continuous(args, cfg, model, sparams, policy)


if __name__ == "__main__":
    main()
