"""Serving launcher: ``python -m repro.launch.serve --arch <id> --bits 4``.

Loads (or initializes) params, packs them at a ReLeQ policy, and serves
batched greedy decode requests — the production serve loop the decode
dry-run cells lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.quant.policy import QuantPolicy
from repro.quant.qat import policy_for
from repro.train.serve import make_decode_step, quantize_for_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--policy-json", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    if args.ckpt_dir:
        from repro import ckpt as ckpt_lib

        tree, _, step = ckpt_lib.restore(args.ckpt_dir)
        params = jax.tree.map(jnp.asarray, tree["params"])
        print(f"restored step {step} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.PRNGKey(0))
    if args.policy_json:
        with open(args.policy_json) as f:
            policy = QuantPolicy.from_json(f.read())
    else:
        policy = policy_for(model, default_bits=args.bits)
    sparams = quantize_for_serving(model, params, policy)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    logits, cache = model.prefill(sparams, tokens=prompts,
                                  max_len=args.prompt_len + args.gen + 1)
    dec = make_decode_step(model, donate=False)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t0 = time.time()
    toks = [tok]
    for _ in range(args.gen):
        logits, cache = dec(sparams, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        toks.append(tok)
    dt = time.time() - t0
    print(f"served batch={args.batch} gen={args.gen} at "
          f"{dt / args.gen * 1e3:.1f} ms/token-step "
          f"(avg policy {policy.average_bits():.1f} bits)")
    print("first sequence:", jnp.concatenate(toks, 1)[0].tolist())


if __name__ == "__main__":
    main()
