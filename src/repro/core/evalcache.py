"""Shared, thread-safe memo-cache for candidate-policy evaluations.

The short retrain behind ``evaluate(bits_by_name)`` is the search's
wall-clock bottleneck, and bit-vectors recur across episodes (the agent
revisits policies; early-episode prefixes repeat).  PR 1 memoized the LM
evaluator with a plain dict; the async autotune service shares ONE cache
across a pool of evaluation workers, which needs three more properties:

- **canonical key**: a frozen ``((name, bits), ...)`` tuple sorted by
  group name, so hits are independent of dict insertion order and the
  same cache serves the accuracy and latency evaluators;
- **concurrency safety**: a lock around the table plus per-key in-flight
  coalescing — two workers racing on the same candidate run the retrain
  once, the loser blocks on the winner's result (re-entrant: a cache
  layered over an already-cached evaluator computes inline instead of
  deadlocking on its own in-flight event);
- **hit-rate counters**: ``stats()`` is surfaced in the search record
  (``SearchResult.cache_stats``) and the autotune bench.
"""
from __future__ import annotations

import threading


class EvalCache:
    """get-or-compute memo keyed on a canonical frozen bits tuple."""

    def __init__(self):
        self._lock = threading.Lock()
        self._values: dict[tuple, object] = {}
        # key -> (owner thread id, event) while a compute is in flight
        self._inflight: dict[tuple, tuple[int, threading.Event]] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(bits_by_name: dict) -> tuple:
        """Canonical frozen key: sorted (name, bits) pairs."""
        return tuple(sorted((str(n), int(b)) for n, b in bits_by_name.items()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._values)

    def get_or_compute(self, bits_by_name: dict, fn):
        """-> (value, was_hit).  ``fn()`` runs at most once per key across
        all threads; concurrent same-key callers block on the winner."""
        key = self.key(bits_by_name)
        me = threading.get_ident()
        while True:
            with self._lock:
                if key in self._values:
                    self.hits += 1
                    return self._values[key], True
                entry = self._inflight.get(key)
                if entry is None:
                    event = threading.Event()
                    self._inflight[key] = (me, event)
                    self.misses += 1
                    owner = True
                elif entry[0] == me:
                    # re-entrant: this thread already owns the compute for
                    # this key (cache layered over a cached evaluator) —
                    # run the inner fn inline; the outer frame stores it
                    return fn(), False
                else:
                    owner = False
                    event = entry[1]
            if owner:
                try:
                    value = fn()
                except BaseException:
                    with self._lock:  # let a waiter retry (and re-raise)
                        self._inflight.pop(key, None)
                    event.set()
                    raise
                with self._lock:
                    self._values[key] = value
                    self._inflight.pop(key, None)
                event.set()
                return value, False
            event.wait()
            # winner stored the value (loop re-checks; if the winner
            # raised, this thread becomes the new owner and recomputes)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._values),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
            }
