"""The ReLeQ quantization environment (paper §2.3-2.5, Fig 4).

An episode walks the network's quantizable groups in order; at step t the
agent picks group t's bitwidth from the flexible action set (Fig 2a — any
bitwidth, not ±1 moves).  The environment then

  1. updates the policy-so-far,
  2. obtains the State of Relative Accuracy from the *evaluator* (short
     retrain + validation, or the cheaper end-of-episode mode the paper
     uses for deeper nets),
  3. computes the State of Quantization (costmodel.py, the paper's formula),
  4. emits the shaped reward (reward.py).

The evaluator is an injected callable ``evaluate(bits_by_name) -> rel_acc``
so the same environment drives the paper's CNNs (accuracy ratio) and the
LM stack (likelihood ratio), locally or sharded over a pod.

Evaluation modes (``eval_mode``):
  per_step     evaluate after every action (paper's shallow-net mode)
  episode_end  evaluate once, at the final action (deep nets)
  deferred     never evaluate inside ``step`` — the episode's terminal
               reward stays provisional (acc = the initial 1.0) until an
               external evaluator reports back and the caller patches it
               via :meth:`reward_for`.  This is the step-level API the
               async ``repro.autotune`` service uses to roll out episodes
               without blocking on the short retrain.

State embedding (Table 1, both axes):
  layer-specific static : layer index (norm), log #weights (norm), weight std
  layer-specific dynamic: current bitwidth (norm)
  network-specific dyn. : State_Quantization, State_Accuracy
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import costmodel
from repro.core.reward import REWARDS

STATE_DIM = 6


@dataclass
class QuantEnv:
    groups: list                      # QuantGroup list (searchable ORDER)
    evaluate: object                  # callable(dict name->bits) -> rel acc
    weight_std: dict                  # name -> std of the fp weights (static)
    bitset: tuple = (2, 3, 4, 5, 6, 7, 8)
    frozen: dict = field(default_factory=dict)   # name -> fixed bits
    reward_mode: str = "proposed"
    reward_kwargs: dict = field(default_factory=dict)
    eval_mode: str = "per_step"       # per_step | episode_end (deep nets)
    init_bits: int = 8                # paper: all layers start at 8 bits
    # HAQ-style extension: per-layer KV-cache bitwidth pseudo-groups
    # (``model.kv_quant_groups()``, names ``kv.L..``) appended after the
    # weight walk — the agent picks serving KV precision with the same
    # flexible action set, and SQ prices the cache bytes through the
    # groups' n_weights (n_macs = 0: bits buy bandwidth, not precision)
    kv_groups: list = field(default_factory=list)

    def __post_init__(self):
        if self.eval_mode not in ("per_step", "episode_end", "deferred"):
            raise ValueError(f"eval_mode={self.eval_mode!r}")
        if self.kv_groups:
            self.groups = list(self.groups) + list(self.kv_groups)
        self.searchable = [g for g in self.groups if g.name not in self.frozen]
        self.T = len(self.searchable)
        self._logw = {g.name: np.log(max(g.n_weights, 1)) for g in self.groups}
        self._logw_max = max(self._logw.values())
        self._reward = REWARDS[self.reward_mode]
        self.reset()

    # ------------------------------------------------------------------
    def reset(self):
        self.bits = {g.name: self.init_bits for g in self.groups}
        self.bits.update(self.frozen)
        self.t = 0
        self.acc_state = 1.0  # starts from the (re)trained 8-bit baseline
        self.quant_state = self._quant_state()
        return self._obs()

    def _quant_state(self) -> float:
        vec = [self.bits[g.name] for g in self.groups]
        return costmodel.state_of_quantization(vec, self.groups)

    def _obs(self) -> np.ndarray:
        g = self.searchable[min(self.t, self.T - 1)]
        return np.asarray([
            self.t / max(self.T - 1, 1),
            self._logw[g.name] / self._logw_max,
            min(self.weight_std.get(g.name, 0.0), 2.0),
            self.bits[g.name] / max(self.bitset),
            self.quant_state,
            min(self.acc_state, 1.2),
        ], np.float32)

    # ------------------------------------------------------------------
    def step(self, action: int):
        """-> (obs, reward, done, info)."""
        g = self.searchable[self.t]
        self.bits[g.name] = int(self.bitset[action])
        self.quant_state = self._quant_state()
        done = self.t == self.T - 1
        if self.eval_mode == "per_step" or (done and self.eval_mode == "episode_end"):
            self.acc_state = float(self.evaluate(dict(self.bits)))
        reward = self._reward(self.acc_state, self.quant_state,
                              **self.reward_kwargs)
        self.t += 1
        info = {"bits": dict(self.bits), "acc": self.acc_state,
                "quant": self.quant_state, "group": g.name}
        return self._obs(), float(reward), done, info

    # ------------------------------------------------------------------
    def reward_for(self, acc: float, quant: float) -> float:
        """Step-level API: the episode reward for an externally supplied
        (rel-accuracy, quant-state) pair, under this env's reward shaping.
        The async service uses it to finalize a ``deferred`` episode once
        its evaluation worker reports back — identical to what
        ``episode_end`` would have computed in-line."""
        return float(self._reward(float(acc), float(quant),
                                  **self.reward_kwargs))
