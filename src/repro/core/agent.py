"""ReLeQ agent networks (paper §2.7): shared-LSTM actor-critic, pure JAX.

    state embedding -> LSTM(128)  ("first hidden layer for both networks")
        policy head: FC 128 -> FC 128 -> |bitwidths| softmax
        value head:  FC 128 -> FC 64  -> 1

The LSTM carry persists across the layer-steps of one episode — that is how
"quantization levels are selected with the context of previous layers'
bitwidths" — and resets between episodes.  Paper reports the LSTM gives
~1.33× faster convergence than an MLP-only agent (we reproduce that
ablation in benchmarks/fig_lstm_ablation.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

HIDDEN = 128


def _dense(key, n_in, n_out, scale=None):
    s = scale if scale is not None else (2.0 / n_in) ** 0.5
    return {
        "w": jax.random.normal(key, (n_in, n_out), jnp.float32) * s,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def init_agent(key, state_dim: int, num_actions: int):
    ks = jax.random.split(key, 7)
    return {
        "lstm": {
            "wx": jax.random.normal(ks[0], (state_dim, 4 * HIDDEN), jnp.float32)
            * (1.0 / state_dim) ** 0.5,
            "wh": jax.random.normal(ks[1], (HIDDEN, 4 * HIDDEN), jnp.float32)
            * (1.0 / HIDDEN) ** 0.5,
            "b": jnp.zeros((4 * HIDDEN,), jnp.float32),
        },
        "pi1": _dense(ks[2], HIDDEN, 128),
        "pi2": _dense(ks[3], 128, 128),
        "pi_head": _dense(ks[4], 128, num_actions, scale=0.01),
        "v1": _dense(ks[5], HIDDEN, 128),
        "v2": _dense(ks[6], 128, 64),
        "v_head": _dense(jax.random.fold_in(ks[6], 1), 64, 1, scale=0.01),
    }


def lstm_carry(batch: int):
    return (jnp.zeros((batch, HIDDEN), jnp.float32),
            jnp.zeros((batch, HIDDEN), jnp.float32))


def _lstm_step(p, carry, x):
    h, c = carry
    z = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c2 = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h2 = jax.nn.sigmoid(o) * jnp.tanh(c2)
    return (h2, c2), h2


def _ff(p, x):
    return x @ p["w"] + p["b"]


def agent_step(params, carry, state, use_lstm: bool = True):
    """One step.  state: (B, state_dim) -> (carry', logits (B, A), value (B,))."""
    if use_lstm:
        carry2, h = _lstm_step(params["lstm"], carry, state)
    else:  # MLP ablation (paper §2.7: LSTM converges ~1.33× faster)
        carry2, h = carry, jnp.tanh(state @ params["lstm"]["wx"][:, :HIDDEN])
    hp = jax.nn.relu(_ff(params["pi1"], h))
    hp = jax.nn.relu(_ff(params["pi2"], hp))
    logits = _ff(params["pi_head"], hp)
    hv = jax.nn.relu(_ff(params["v1"], h))
    hv = jax.nn.relu(_ff(params["v2"], hv))
    value = _ff(params["v_head"], hv)[..., 0]
    return carry2, logits, value


def rollout_logits(params, states, use_lstm: bool = True):
    """Teacher-forced pass over stored trajectories.

    states: (B, T, S) -> logits (B, T, A), values (B, T).
    """
    B = states.shape[0]

    def step(carry, s_t):
        carry, logits, value = agent_step(params, carry, s_t, use_lstm)
        return carry, (logits, value)

    _, (logits, values) = jax.lax.scan(step, lstm_carry(B),
                                       jnp.moveaxis(states, 1, 0))
    return jnp.moveaxis(logits, 0, 1), jnp.moveaxis(values, 0, 1)
