"""Reward shaping (paper §2.6, Fig 3) — three formulations.

The paper's proposed reward (Fig 3a) is given graphically, not as a printed
equation; the text pins down its properties and parameters:

- asymmetric: accuracy is prioritized over quantization benefit,
- smooth 2-D gradient that steepens as the agent approaches the optimum,
- parameters a = 0.2, b = 0.4 ("can be tuned"),
- hard threshold th = 0.4 on relative accuracy, below which the reward is a
  flat penalty (prunes unrecoverable regions, accelerating learning).

We reconstruct it as

    R(acc, q) = -1                              acc < th
    R(acc, q) = acc^(2/b) · (1 - q^a)           otherwise

acc^(2/b) = acc^5 is the steep accuracy emphasis — chosen so the
asymmetry is a checkable property (an ε loss of relative accuracy always
costs more reward than an ε gain of quantization benefit recovers, for
acc ≥ 0.9, q ≥ 0.3; tests/test_core_rl.py); (1 - q^a) with a = 0.2
rewards quantization progressively faster as q drops (the "smooth
gradient toward the optimum"); the threshold is the flat dark region of
Fig 3a.  The two ablation alternatives (Fig 3b, 3c) are implemented
exactly as stated: acc/q and acc − q.
"""
from __future__ import annotations

import numpy as np


def reward_proposed(acc: float, quant: float, a: float = 0.2, b: float = 0.4,
                    th: float = 0.4) -> float:
    acc = float(np.clip(acc, 0.0, 1.5))   # relative accuracy can exceed 1 slightly
    quant = float(np.clip(quant, 0.0, 1.0))
    if acc < th:
        return -1.0
    return acc ** (2.0 / b) * (1.0 - quant ** a)


def reward_ratio(acc: float, quant: float, **_) -> float:
    """Fig 3b: State_Accuracy / State_Quantization."""
    return float(acc) / max(float(quant), 1e-6)


def reward_difference(acc: float, quant: float, **_) -> float:
    """Fig 3c: State_Accuracy - State_Quantization."""
    return float(acc) - float(quant)


REWARDS = {
    "proposed": reward_proposed,
    "ratio": reward_ratio,
    "difference": reward_difference,
}
