"""ReLeQ search driver: PPO agent × quantization environment (Fig 4).

Faithful mode (paper): one environment, PPO update at the end of every
episode.  Scale-out mode: ``num_envs`` environments step in lockstep
through one batched agent forward — on a multi-pod mesh each pod evaluates
its own environment's candidate policy, turning the search's wall-clock
bottleneck (short retrains) embarrassingly parallel (DESIGN.md §4).

Produces the full learning record the paper's figures need:
per-episode (reward, acc state, quant state, bits) and the per-layer
action-probability evolution (Fig 5).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import init_agent
from repro.core.env import STATE_DIM, QuantEnv
from repro.core.evalcache import EvalCache
from repro.core.ppo import PPO, PPOConfig


@dataclass
class SearchResult:
    best_bits: dict
    best_reward: float
    episodes: list = field(default_factory=list)   # per-episode records
    prob_evolution: list = field(default_factory=list)  # (episode, T, A)
    cache_stats: dict = field(default_factory=dict)  # evaluate() memo hit-rate
    service_stats: dict = field(default_factory=dict)  # async-run throughput

    def bits_vector(self, groups):
        return [self.best_bits[g.name] for g in groups]

    def average_bits(self, searchable_only=None) -> float:
        """Mean bitwidth over ``searchable_only`` (None -> every group).

        ``None`` and ``[]`` are distinct: None means "average everything",
        while an explicit empty selection has no defined mean and raises
        (it used to silently fall through to "all groups")."""
        names = list(self.best_bits) if searchable_only is None \
            else list(searchable_only)
        if not names:
            raise ValueError("average_bits over an empty group selection")
        return float(np.mean([self.best_bits[n] for n in names]))


class ReLeQSearch:
    def __init__(self, make_env, *, num_envs: int = 1, seed: int = 0,
                 ppo_config: PPOConfig | None = None):
        self.make_env = make_env
        self.envs = [make_env(i) for i in range(num_envs)]
        self.num_envs = num_envs
        num_actions = len(self.envs[0].bitset)
        key = jax.random.PRNGKey(seed)
        params = init_agent(key, STATE_DIM, num_actions)
        # fresh config per instance: a dataclass default here would be ONE
        # shared object across every ReLeQSearch construction
        self.ppo = PPO(params, ppo_config if ppo_config is not None else PPOConfig())
        self.rng = jax.random.PRNGKey(seed + 1)

    def _collect(self):
        """Run one episode in every env -> trajectories + records."""
        E, T = self.num_envs, self.envs[0].T
        states = np.zeros((E, T, STATE_DIM), np.float32)
        actions = np.zeros((E, T), np.int32)
        logps = np.zeros((E, T), np.float32)
        values = np.zeros((E, T), np.float32)
        rewards = np.zeros((E, T), np.float32)
        probs = np.zeros((E, T, len(self.envs[0].bitset)), np.float32)
        infos = [None] * E

        obs = np.stack([env.reset() for env in self.envs])
        carry = self.ppo.initial_carry(E)
        for t in range(T):
            self.rng, sub = jax.random.split(self.rng)
            carry, act, logp, val, pr = self.ppo.act(carry, jnp.asarray(obs), sub)
            act = np.asarray(act)
            states[:, t] = obs
            actions[:, t] = act
            logps[:, t] = np.asarray(logp)
            values[:, t] = np.asarray(val)
            probs[:, t] = np.asarray(pr)
            nxt = []
            for e, env in enumerate(self.envs):
                o, r, done, info = env.step(int(act[e]))
                rewards[e, t] = r
                nxt.append(o)
                if done:
                    infos[e] = info
            obs = np.stack(nxt)
        traj = {"states": states, "actions": actions, "logp_old": logps,
                "values": values, "rewards": rewards}
        return traj, rewards, infos, probs

    def run(self, episodes: int, log_every: int = 0) -> SearchResult:
        result = SearchResult(best_bits={}, best_reward=-np.inf)
        for ep in range(episodes):
            traj, rewards, infos, probs = self._collect()
            metrics = self.ppo.update(traj)
            for e, info in enumerate(infos):
                final_r = float(rewards[e, -1])
                result.episodes.append({
                    "episode": ep, "env": e, "reward": final_r,
                    "mean_reward": float(rewards[e].mean()),
                    "acc": info["acc"], "quant": info["quant"],
                    "bits": info["bits"],
                })
                if final_r > result.best_reward:
                    result.best_reward = final_r
                    result.best_bits = dict(info["bits"])
            result.prob_evolution.append(probs.mean(axis=0))
            if log_every and (ep + 1) % log_every == 0:
                from repro.obs import get_logger

                last = result.episodes[-1]
                get_logger("search").event(
                    "episode", episode=ep + 1,
                    reward=float(last["reward"]), acc=float(last["acc"]),
                    quant=float(last["quant"]),
                    avg_bits=float(np.mean(list(last["bits"].values()))),
                    pi_loss=float(metrics["pi_loss"]))
        cache = getattr(self.make_env, "eval_cache", None)
        if cache is not None:
            result.cache_stats = cache.stats()
        return result


def make_lm_env_factory(model, params, data, *, finetune_steps: int = 4,
                        eval_batches: int = 1, reward_mode: str = "proposed",
                        bitset=(2, 3, 4, 5, 6, 7, 8), eval_mode: str = "episode_end",
                        lr: float = 1e-4):
    """Environment factory for LM architectures.

    Accuracy proxy: per-token likelihood ratio exp(nll_fp − nll_q) after
    ``finetune_steps`` of QAT at the candidate policy (the paper's "short
    retrain", DESIGN.md §3).  The candidate bits enter the jit'd step as
    data, so every candidate shares one executable.
    """
    import jax.numpy as jnp

    from repro.optim import AdamW
    from repro.quant.qat import bits_assignment, policy_for
    from repro.quant.policy import QuantPolicy
    from repro.train.train_step import make_eval_step, make_fp_eval_step, make_train_step

    groups = model.quant_groups()
    frozen = model.frozen_bits()
    eval_step = make_eval_step(model)
    fp_eval = make_fp_eval_step(model)
    opt = AdamW(lr=lr, weight_decay=0.0)
    train_step = make_train_step(model, opt, donate=False)
    eval_batch = [data.eval_batch(data.local_batch, index=10_000_000 + i)
                  for i in range(eval_batches)]
    nll_fp = float(np.mean([float(fp_eval(params, b)) for b in eval_batch]))

    wstd = {}
    for g in groups:
        from repro.quant.qat import get_by_path
        leaf = get_by_path(params, g.path)
        if g.layer is not None:
            leaf = leaf[g.layer]
        wstd[g.name] = float(jnp.std(leaf.astype(jnp.float32)))

    # bit-vectors recur across episodes (the agent revisits policies, and
    # early-episode prefixes repeat); the short retrain is the search's
    # wall-clock bottleneck, so memoize on the canonical frozen bits tuple.
    # EvalCache is lock-guarded and coalesces concurrent same-key calls,
    # so the autotune worker pool can share it across threads.
    memo = EvalCache()

    def compute(bits_by_name: dict) -> float:
        pol = QuantPolicy.from_array(tuple(g.name for g in groups),
                                     [bits_by_name[g.name] for g in groups])
        bm = {k: jnp.asarray(v) for k, v in bits_assignment(groups, pol).items()}
        if finetune_steps:
            state = {"params": params, "opt": opt.init(params)}
            for _ in range(finetune_steps):
                state, _ = train_step(state, data.next(), bm)
            p_eval = state["params"]
        else:
            p_eval = params
        nll_q = float(np.mean([float(eval_step(p_eval, b, bm)) for b in eval_batch]))
        return float(np.exp(nll_fp - nll_q))

    def evaluate(bits_by_name: dict) -> float:
        value, _ = memo.get_or_compute(bits_by_name,
                                       lambda: compute(bits_by_name))
        return value

    def factory(env_id: int) -> QuantEnv:
        return QuantEnv(groups=groups, evaluate=evaluate, weight_std=wstd,
                        bitset=bitset, frozen=frozen, reward_mode=reward_mode,
                        eval_mode=eval_mode)

    factory.eval_cache = memo          # shared across searches/worker pools
    factory.evaluate = evaluate        # cached step-level API
    factory.compute = compute          # raw retrain (autotune workers layer
    #                                    their own cache exactly once)
    return factory
