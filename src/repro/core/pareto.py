"""Design-space enumeration + Pareto frontier (paper §5.2, Fig 6).

For networks small enough to enumerate (LeNet: 4 layers, SimpleNet: 5), we
sweep every bitwidth combination, record (State_Quantization, rel-accuracy)
per point, extract the Pareto frontier, and check where the ReLeQ solution
lands — the paper's validation that the RL agent finds the "desired region"
of the frontier.

This module is the *small-network oracle* for the persistent archive in
``repro.autotune.archive``: :func:`as_archive` lifts an enumerated space
into a :class:`~repro.autotune.archive.ParetoArchive`, whose 2-objective
frontier provably equals :func:`pareto_frontier` (pinned in
tests/test_autotune.py) while adding dominance-pruned insertion, a third
latency objective, JSON checkpointing and warm-start.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import costmodel


def enumerate_space(groups, evaluate, bitset=(2, 3, 4, 5, 6, 7, 8),
                    frozen: dict | None = None, limit: int | None = None):
    """-> list of {bits, quant, acc}.  ``evaluate``: dict->rel_acc."""
    frozen = frozen or {}
    searchable = [g for g in groups if g.name not in frozen]
    combos = itertools.product(bitset, repeat=len(searchable))
    points = []
    for i, combo in enumerate(combos):
        if limit is not None and i >= limit:
            break
        bits = {g.name: b for g, b in zip(searchable, combo)}
        bits.update(frozen)
        vec = [bits[g.name] for g in groups]
        points.append({
            "bits": bits,
            "quant": costmodel.state_of_quantization(vec, groups),
            "acc": float(evaluate(bits)),
        })
    return points


def pareto_frontier(points):
    """Non-dominated set: maximize acc, minimize quant."""
    pts = sorted(points, key=lambda p: (p["quant"], -p["acc"]))
    frontier, best_acc = [], -np.inf
    for p in pts:
        if p["acc"] > best_acc:
            frontier.append(p)
            best_acc = p["acc"]
    return frontier


def as_archive(points, latency_fn=None):
    """Enumerated points -> a ``repro.autotune`` Pareto archive (oracle)."""
    from repro.autotune.archive import ParetoArchive

    return ParetoArchive.from_enumeration(points, latency_fn=latency_fn)


def distance_to_frontier(point, frontier) -> float:
    """L2 distance in (quant, acc) space from a point to the frontier."""
    d = min(((point["quant"] - f["quant"]) ** 2 +
             (point["acc"] - f["acc"]) ** 2) ** 0.5 for f in frontier)
    return float(d)
