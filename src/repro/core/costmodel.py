"""Cost models: the paper's State-of-Quantization metric + hardware models.

State of Quantization (paper §2.4, verbatim formula):

    SQ = Σ_l (n_w_l · E_mem/E_mac + n_mac_l) · bits_l
         ───────────────────────────────────────────────
         Σ_l (n_w_l · E_mem/E_mac + n_mac_l) · bits_max

with E_mem/E_mac ≈ 120 (TETRIS [16]).  SQ ∈ (0, 1]; smaller = cheaper.

Hardware models (paper §4.4-4.5 + our TPU adaptation):
- **stripes**: bit-serial weight execution — per-layer time ∝ n_mac·bits;
  energy adds the memory term.  Reproduces Fig 9 / Table 4 as analytic
  estimates (the physical accelerator isn't in this container).
- **tvm_cpu**: bit-serial vector ops on CPU — same bits-proportional
  compute law (activations stay 8-bit), reproducing Fig 8.
- **tpu_v5e**: OUR serving model — decode is weight-traffic-bound, so
  time ∝ max(flops/peak, bytes(bits)/hbm_bw); speedup vs 8-bit comes from
  the bitplane packing (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

E_MEM_OVER_E_MAC = 120.0

# TPU v5e (per chip)
V5E_PEAK_FLOPS = 197e12       # bf16
V5E_HBM_BW = 819e9            # bytes/s
V5E_ICI_BW = 50e9             # bytes/s/link


def _weights(groups):
    return np.asarray([g.n_weights for g in groups], np.float64)


def _macs(groups):
    return np.asarray([g.n_macs for g in groups], np.float64)


def state_of_quantization(bits, groups, max_bits: int = 8,
                          e_ratio: float = E_MEM_OVER_E_MAC) -> float:
    """The paper's SQ metric.  bits: per-group vector (fp groups -> max_bits)."""
    b = np.minimum(np.asarray(bits, np.float64), max_bits)
    w, m = _weights(groups), _macs(groups)
    cost = w * e_ratio + m
    return float(np.sum(cost * b) / np.sum(cost * max_bits))


def stripes_time(bits, groups) -> float:
    """Bit-serial accelerator: cycles ∝ Σ n_mac·bits (weights serialized)."""
    return float(np.sum(_macs(groups) * np.asarray(bits, np.float64)))


def stripes_energy(bits, groups, e_ratio: float = E_MEM_OVER_E_MAC) -> float:
    """MAC energy ∝ bits; weight-memory energy ∝ n_w·bits·E_mem."""
    b = np.asarray(bits, np.float64)
    return float(np.sum(_macs(groups) * b + _weights(groups) * b * e_ratio / 8.0))


def tvm_cpu_time(bits, groups, act_bits: int = 8) -> float:
    """Bit-serial popcount GEMM: ops ∝ weight_bits × act_bits."""
    return float(np.sum(_macs(groups) * np.asarray(bits, np.float64) * act_bits))


def tpu_decode_time(bits, groups, batch: int = 1,
                    peak=V5E_PEAK_FLOPS, bw=V5E_HBM_BW) -> float:
    """Per-token decode latency estimate: per-layer max(compute, weight DMA).

    Weight bytes stream at bits/8 per weight (bitplane packing); compute is
    2·n_w·batch flops at bf16.
    """
    b = np.asarray(bits, np.float64)
    w = _weights(groups)
    t_comp = 2.0 * w * batch / peak
    t_mem = (w * b / 8.0) / bw
    return float(np.sum(np.maximum(t_comp, t_mem)))


def speedup_vs_8bit(time_fn, bits, groups, **kw) -> float:
    eight = np.full(len(groups), 8.0)
    return time_fn(eight, groups, **kw) / max(time_fn(bits, groups, **kw), 1e-30)


def energy_reduction_vs_8bit(bits, groups) -> float:
    eight = np.full(len(groups), 8.0)
    return stripes_energy(eight, groups) / max(stripes_energy(bits, groups), 1e-30)
