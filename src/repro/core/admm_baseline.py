"""ADMM bitwidth-selection baseline (paper §4.6 / Table 4, Ye et al. [46]).

The paper describes the comparison method as: "runs a binary search to
minimize the total square quantization error in order to decide the
quantization levels for the layers, then an iterative optimization
technique for fine-tuning".  We implement that decision rule:

    min_b  Σ_l ‖W_l − Q_{b_l}(W_l)‖²   s.t.  Σ_l cost_l·b_l ≤ budget

solved exactly by binary search on the Lagrange multiplier λ — for each λ
every layer independently picks b_l = argmin_b err_l(b) + λ·cost_l·b (the
per-layer objective is separable), and λ is bisected until the budget
binds.  Fine-tuning afterwards uses the same QAT short-retrain as ReLeQ,
so the comparison isolates the bitwidth-*selection* policy.
"""
from __future__ import annotations

import numpy as np

from repro.quant.wrpn import fake_quant


def layer_quant_errors(weights_by_name: dict, bitset=(2, 3, 4, 5, 6, 7, 8)):
    """name -> {bits: squared quantization error}."""
    import jax.numpy as jnp

    out = {}
    for name, w in weights_by_name.items():
        w = jnp.asarray(w, jnp.float32)
        errs = {}
        for b in bitset:
            wq = fake_quant(w, b)
            errs[b] = float(jnp.sum((w - wq) ** 2))
        out[name] = errs
    return out


def admm_select(groups, weights_by_name: dict, budget_avg_bits: float,
                bitset=(2, 3, 4, 5, 6, 7, 8), frozen: dict | None = None,
                iters: int = 50) -> dict:
    """-> bits dict meeting the average-bits budget with min total sq error."""
    frozen = frozen or {}
    searchable = [g for g in groups if g.name not in frozen]
    errs = layer_quant_errors(
        {g.name: weights_by_name[g.name] for g in searchable}, bitset)
    cost = {g.name: float(g.n_weights) for g in searchable}
    budget = budget_avg_bits * sum(cost.values())

    def pick(lmbda):
        bits = {}
        for g in searchable:
            obj = [(errs[g.name][b] + lmbda * cost[g.name] * b, b) for b in bitset]
            bits[g.name] = min(obj)[1]
        return bits

    lo, hi = 0.0, 1.0
    # grow hi until budget satisfied
    for _ in range(60):
        b = pick(hi)
        if sum(cost[n] * v for n, v in b.items()) <= budget:
            break
        hi *= 4.0
    for _ in range(iters):  # bisect λ
        mid = 0.5 * (lo + hi)
        b = pick(mid)
        used = sum(cost[n] * v for n, v in b.items())
        if used > budget:
            lo = mid
        else:
            hi = mid
    bits = pick(hi)
    bits.update(frozen)
    return bits
