"""Proximal Policy Optimization (paper §2.7, Table 3) — from scratch.

Hyper-parameters follow Table 3: Adam step 1e-4, GAE parameter 0.99,
3 epochs per update, clipping ε = 0.1 (Table 5 shows 0.1 wins).  The
clipped surrogate is the standard PPO objective; advantages come from GAE
over the per-layer-step rewards of each episode.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import agent_step, lstm_carry, rollout_logits
from repro.optim.adamw import AdamW


@dataclass(frozen=True)
class PPOConfig:
    lr: float = 1e-4
    clip_eps: float = 0.1
    epochs: int = 3
    gamma: float = 0.99          # Table 3 "GAE parameter"
    lam: float = 0.95
    value_coef: float = 0.5
    entropy_coef: float = 1e-2
    max_grad_norm: float = 1.0
    use_lstm: bool = True        # paper §2.7 ablation switch


def gae_advantages(rewards, values, gamma: float, lam: float):
    """rewards/values: (B, T) -> (advantages, returns), episode ends at T."""
    B, T = rewards.shape
    adv = np.zeros((B, T), np.float32)
    last = np.zeros((B,), np.float32)
    next_v = np.zeros((B,), np.float32)
    for t in range(T - 1, -1, -1):
        delta = rewards[:, t] + gamma * next_v - values[:, t]
        last = delta + gamma * lam * last
        adv[:, t] = last
        next_v = values[:, t]
    returns = adv + values
    return adv, returns


@partial(jax.jit, static_argnames=("cfg",))
def ppo_loss(params, batch, cfg: PPOConfig):
    logits, values = rollout_logits(params, batch["states"], cfg.use_lstm)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch["actions"][..., None], -1)[..., 0]
    ratio = jnp.exp(logp - batch["logp_old"])
    adv = batch["adv"]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
    pi_loss = -jnp.mean(jnp.minimum(unclipped, clipped))
    v_loss = jnp.mean((values - batch["returns"]) ** 2)
    entropy = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, -1))
    total = pi_loss + cfg.value_coef * v_loss - cfg.entropy_coef * entropy
    return total, {"pi_loss": pi_loss, "v_loss": v_loss, "entropy": entropy,
                   "ratio_max": jnp.max(ratio)}


class PPO:
    def __init__(self, params, cfg: PPOConfig = PPOConfig()):
        self.cfg = cfg
        self.opt = AdamW(lr=cfg.lr, weight_decay=0.0, clip_norm=cfg.max_grad_norm)
        self.params = params
        self.opt_state = self.opt.init(params)
        self._grad = jax.jit(
            jax.grad(lambda p, b: ppo_loss(p, b, self.cfg)[0]))

    def update(self, trajectories: dict) -> dict:
        """trajectories: states (B,T,S) f32, actions (B,T) i32,
        logp_old (B,T), rewards (B,T), values (B,T) — numpy."""
        adv, ret = gae_advantages(trajectories["rewards"], trajectories["values"],
                                  self.cfg.gamma, self.cfg.lam)
        batch = {
            "states": jnp.asarray(trajectories["states"], jnp.float32),
            "actions": jnp.asarray(trajectories["actions"], jnp.int32),
            "logp_old": jnp.asarray(trajectories["logp_old"], jnp.float32),
            "adv": jnp.asarray(adv),
            "returns": jnp.asarray(ret),
        }
        metrics = {}
        for _ in range(self.cfg.epochs):
            grads = self._grad(self.params, batch)
            self.params, self.opt_state = self.opt.update(
                self.params, grads, self.opt_state)
        _, metrics = ppo_loss(self.params, batch, self.cfg)
        return {k: float(v) for k, v in metrics.items()}

    # -- acting ----------------------------------------------------------
    def act(self, carry, state, rng):
        """state: (B, S) -> (carry', action (B,), logp (B,), value (B,),
        probs (B, A))."""
        carry, logits, value = jax.jit(agent_step, static_argnames=("use_lstm",))(
            self.params, carry, state, use_lstm=self.cfg.use_lstm)
        probs = jax.nn.softmax(logits)
        action = jax.random.categorical(rng, logits)
        logp = jnp.take_along_axis(jax.nn.log_softmax(logits),
                                   action[:, None], -1)[:, 0]
        return carry, action, logp, value, probs

    def initial_carry(self, batch: int):
        return lstm_carry(batch)
