"""ReLeQ core: the paper's contribution.

- env.py        layer-stepping quantization environment (state space of
                Table 1, flexible action space of Fig 2a)
- reward.py     asymmetric shaped reward + the two Fig 3 alternatives
- agent.py      shared-LSTM actor-critic (policy 128-128-|A|, value 128-64-1)
- ppo.py        PPO from scratch (clip 0.1, Adam 1e-4, GAE 0.99, 3 epochs)
- search.py     episode driver (faithful 1-env mode + vectorized pod mode;
                the async scale-out path lives in repro.autotune.service)
- evalcache.py  thread-safe evaluate() memo shared with autotune workers
- costmodel.py  State-of-Quantization + Stripes / TVM-CPU / TPU-v5e models
- pareto.py     design-space enumeration (Fig 6 validation; the persistent
                multi-objective archive is repro.autotune.archive)
- admm_baseline.py  the ADMM comparison policy (Table 4)
"""
from repro.core.env import QuantEnv, STATE_DIM  # noqa: F401
from repro.core.evalcache import EvalCache  # noqa: F401
from repro.core.ppo import PPO, PPOConfig  # noqa: F401
from repro.core.search import ReLeQSearch, SearchResult, make_lm_env_factory  # noqa: F401
