"""QAT train/eval step factories.

``train_step(state, batch, bits_map)``:
  1. QDQ every quantizable weight group at its (runtime-data) bitwidth —
     the paper's WRPN technique with STE, so "short retrain" inside the
     ReLeQ environment is just N of these steps at the candidate policy.
  2. forward + backward with the configured remat policy,
  3. AdamW update (fp32 or int8 moments).

``bits_map`` is a pytree of int32 leaves: feeding the SAME executable
different policies costs nothing — that's what makes the RL environment's
inner loop cheap at scale (DESIGN.md §4).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW, global_norm
from repro.quant.qat import quantize_params


def init_state(model, optimizer: AdamW, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": optimizer.init(params)}


def make_train_step(model, optimizer: AdamW, *, remat: str = "none",
                    donate: bool = True):
    groups = model.quant_groups()

    def step(state, batch, bits_map):
        def loss_fn(params):
            qp = quantize_params(params, bits_map, groups)
            return model.loss(qp, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state["params"])
        new_params, new_opt = optimizer.update(state["params"], grads, state["opt"])
        out = {"loss": loss, "grad_norm": global_norm(grads), **metrics}
        return {"params": new_params, "opt": new_opt}, out

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def make_dp_train_step(model, optimizer: AdamW, mesh, *, axis: str = "data",
                       planes: int = 2, remat: str = "none",
                       donate: bool = True):
    """Pure data-parallel QAT train step with a *compressed* gradient
    all-reduce (``dist.collectives.compressed_allreduce_tree``: fp8-plane
    all-gather + error feedback) instead of the exact fp32 psum.

    The whole step runs under ``jax.shard_map`` over ``axis``: params/opt
    replicate, the batch shards its leading dim, each shard backprops its
    local microbatch, and the gradient crosses the wire as ``planes`` fp8
    payloads per element — ``planes + 4/n`` bytes/element vs 4 for fp32
    (measured from compiled HLO by ``launch/dryrun.py --dp-collectives``).
    What the last plane couldn't represent is carried per-shard in the
    train state (``state["ef"]``, leading axis = shard count) and folded
    into the next step — the standard error-feedback construction that
    keeps compressed SGD unbiased over time.  ``planes=0`` switches to the
    exact fp32 pmean (the wire-byte baseline; EF carries zeros).

    Requires ``REPRO_SHARD_PROFILE=dp`` so in-model sharding constraints
    no-op inside the manual (shard_map) context.

    ``init_dp_state(model, optimizer, rng, mesh, axis)`` builds the
    matching state; step signature matches ``make_train_step``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import compressed_allreduce_tree

    groups = model.quant_groups()
    n_shards = dict(zip(mesh.axis_names, mesh.axis_sizes))[axis]

    def step(state, batch, bits_map):
        def local(params, opt, ef, batch, bits_map):
            ef = jax.tree.map(lambda e: e[0], ef)  # drop the shard axis

            def loss_fn(p):
                qp = quantize_params(p, bits_map, groups)
                return model.loss(qp, batch, remat=remat)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            if planes:
                grads, ef = compressed_allreduce_tree(
                    grads, axis, residuals=ef, planes=planes,
                    axis_size=n_shards)
            else:  # exact fp32 baseline
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            loss = jax.lax.pmean(loss, axis)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
            new_params, new_opt = optimizer.update(params, grads, opt)
            out = {"loss": loss, "grad_norm": global_norm(grads), **metrics}
            return (new_params, new_opt,
                    jax.tree.map(lambda e: e[None], ef), out)

        new_p, new_o, new_ef, out = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(axis), P(axis), P()),
            out_specs=(P(), P(), P(axis), P()),
            check_vma=False,  # compat shim maps this onto 0.4's check_rep
        )(state["params"], state["opt"], state["ef"], batch, bits_map)
        return {"params": new_p, "opt": new_o, "ef": new_ef}, out

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def init_dp_state(model, optimizer: AdamW, rng, mesh, axis: str = "data"):
    """Train state for :func:`make_dp_train_step`: params + opt moments
    plus the per-shard error-feedback residual tree (leading shard axis)."""
    params = model.init(rng)
    n = dict(zip(mesh.axis_names, mesh.axis_sizes))[axis]
    ef = jax.tree.map(
        lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
    return {"params": params, "opt": optimizer.init(params), "ef": ef}


def make_eval_step(model):
    """Eval NLL of a *quantized* model — the ReLeQ accuracy-proxy input."""
    groups = model.quant_groups()

    def step(params, batch, bits_map):
        qp = quantize_params(params, bits_map, groups)
        _, metrics = model.loss(qp, batch)
        return metrics["nll"]

    return jax.jit(step)


def make_fp_eval_step(model):
    def step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics["nll"]

    return jax.jit(step)
