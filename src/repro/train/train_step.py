"""QAT train/eval step factories.

``train_step(state, batch, bits_map)``:
  1. QDQ every quantizable weight group at its (runtime-data) bitwidth —
     the paper's WRPN technique with STE, so "short retrain" inside the
     ReLeQ environment is just N of these steps at the candidate policy.
  2. forward + backward with the configured remat policy,
  3. AdamW update (fp32 or int8 moments).

``bits_map`` is a pytree of int32 leaves: feeding the SAME executable
different policies costs nothing — that's what makes the RL environment's
inner loop cheap at scale (DESIGN.md §4).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW, global_norm
from repro.quant.qat import quantize_params


def init_state(model, optimizer: AdamW, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": optimizer.init(params)}


def make_train_step(model, optimizer: AdamW, *, remat: str = "none",
                    donate: bool = True):
    groups = model.quant_groups()

    def step(state, batch, bits_map):
        def loss_fn(params):
            qp = quantize_params(params, bits_map, groups)
            return model.loss(qp, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state["params"])
        new_params, new_opt = optimizer.update(state["params"], grads, state["opt"])
        out = {"loss": loss, "grad_norm": global_norm(grads), **metrics}
        return {"params": new_params, "opt": new_opt}, out

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def make_eval_step(model):
    """Eval NLL of a *quantized* model — the ReLeQ accuracy-proxy input."""
    groups = model.quant_groups()

    def step(params, batch, bits_map):
        qp = quantize_params(params, bits_map, groups)
        _, metrics = model.loss(qp, batch)
        return metrics["nll"]

    return jax.jit(step)


def make_fp_eval_step(model):
    def step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics["nll"]

    return jax.jit(step)
