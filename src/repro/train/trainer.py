"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested):

- **checkpoint/restart**: full state (params, optimizer, data cursor, RNG,
  step, and — when driven by ReLeQ — the search state) saved atomically
  every ``ckpt_interval`` steps; ``Trainer.run`` resumes from the newest
  complete checkpoint automatically after a crash.
- **straggler mitigation**: per-step wall-clock watermarks vs a running
  EMA; a step slower than ``straggler_factor ×`` EMA increments a counter
  and fires ``on_straggler`` (on a real fleet: re-issue the step / evict
  the slow host; here: logged + surfaced in metrics so tests can assert).
- **elastic scaling**: construct the Trainer with ``mesh=`` and restore
  goes through ``dist/elastic.py`` — a checkpoint written under any device
  count is re-placed under the specs ``dist/sharding.py`` derives for the
  *current* mesh (4-chip save -> 8-chip restart); the data pipeline
  re-shards itself from the same meta.
- **NaN quarantine**: a non-finite loss aborts the step, reloads the last
  checkpoint and skips the offending batch — cheap insurance at 1000-node
  scale where a single bad host can poison the run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro import ckpt as ckpt_lib


@dataclass
class Trainer:
    model: object
    optimizer: object
    data: object                      # SyntheticLMData-like
    step_fn: object                   # jitted (state, batch, bits_map) -> (state, metrics)
    bits_map: dict
    ckpt_dir: str | None = None
    mesh: object = None               # != None: elastic restore onto this mesh
    ckpt_interval: int = 50
    straggler_factor: float = 3.0
    on_straggler: object = None
    log_every: int = 10
    history: list = field(default_factory=list)
    straggler_count: int = 0
    _ema: float | None = None

    def save(self, state, step: int):
        if self.ckpt_dir is None:
            return
        ckpt_lib.save(self.ckpt_dir, step, state,
                      meta={"data": self.data.state_dict(),
                            "bits_map": {k: np.asarray(v).tolist()
                                         for k, v in self.bits_map.items()}})

    def _reload(self, state):
        """-> (restored state, meta, step); mesh-aware placement when the
        Trainer has one (shared by restart and the NaN quarantine — a
        quarantine reload must come back under the same sharding specs or
        the next step recompiles against a replicated layout)."""
        if self.mesh is not None:
            from repro.dist.elastic import restore_elastic

            return restore_elastic(self.ckpt_dir, state, self.mesh)
        tree, meta, step = ckpt_lib.restore(self.ckpt_dir)
        restored = jax.tree.map(
            lambda ref, a: jax.numpy.asarray(a, ref.dtype), state, tree)
        return restored, meta, step

    def try_restore(self, state):
        """-> (state, start_step); falls back to the given fresh state."""
        if self.ckpt_dir is None:
            return state, 0
        try:
            restored, meta, step = self._reload(state)
        except FileNotFoundError:
            return state, 0
        self.data.load_state_dict(meta["data"])
        return restored, step

    _warmup: int = 0

    def _watch(self, dt: float, step: int):
        # first 2 steps include compilation — never seed the EMA with them
        self._warmup += 1
        if self._warmup <= 2:
            return
        if self._ema is None:
            self._ema = dt
            return
        if dt > self.straggler_factor * self._ema:
            self.straggler_count += 1
            if self.on_straggler:
                self.on_straggler(step, dt, self._ema)
            return  # don't let the straggler poison the EMA
        self._ema = 0.9 * self._ema + 0.1 * dt

    def run(self, state, num_steps: int, start_step: int | None = None):
        state, resumed = self.try_restore(state)
        step = resumed if start_step is None else start_step
        last_good = step
        while step < num_steps:
            batch = self.data.next()
            t0 = time.perf_counter()
            new_state, metrics = self.step_fn(state, batch, self.bits_map)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watch(dt, step)
            if not np.isfinite(loss):
                # NaN quarantine: reload last checkpoint, skip this batch
                if self.ckpt_dir is not None and ckpt_lib.latest_step(self.ckpt_dir) is not None:
                    state, _, step = self._reload(state)
                self.data.index += 1  # skip the poisoned batch
                continue
            state = new_state
            step += 1
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if self.log_every and step % self.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms, stragglers={self.straggler_count})")
            if self.ckpt_dir and step % self.ckpt_interval == 0:
                self.save(state, step)
                last_good = step
        if self.ckpt_dir and last_good != step:
            self.save(state, step)
        return state
