from repro.train.train_step import make_train_step, make_eval_step  # noqa: F401
from repro.train.serve import quantize_for_serving, make_decode_step  # noqa: F401
