"""Serving transform: pack ReLeQ's bitwidths into bitplane weights.

``quantize_for_serving`` converts a training params pytree + QuantPolicy
into the *serving layout*:

- per-layer LIST structure (the decode path unrolls layers so each layer's
  packed buffers specialize to their own bitwidth),
- every packable matrix replaced by ``{"planes": (bits, K//8, N) uint8,
  "scale": (1, N) f32, "bits": int}`` (expert banks get a leading E axis),
- embeddings kept dense but tagged ``{"w": ..., "bits": b}`` (a gather, not
  a matmul; QDQ applied at lookup),
- norms / routers / decay-LoRA etc. untouched.

Pure-jax and shape-static given the policy, so the dry-run can lower
``decode_step`` over ``jax.eval_shape(quantize_for_serving, ...)`` structs
without materializing a single weight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.pack import Packed, QDQ, pack_weight
from repro.quant.policy import QuantPolicy
from repro.quant.qat import get_by_path, path_key, set_by_path
from repro.quant.wrpn import FP_BITS


def _pack_matrix(w, bits: int):
    if bits >= 16:  # not worth packing; serve bf16
        return w
    planes, scale = pack_weight(w.astype(jnp.float32), bits)
    return Packed(planes, scale, bits)


def _pack_bank(w, bits: int):
    """(E, K, N) expert bank -> per-expert packed planes."""
    if bits >= 16:
        return w
    packed = jax.vmap(lambda m: pack_weight(m.astype(jnp.float32), bits))(w)
    return Packed(packed[0], packed[1], bits)


def quantize_for_serving(model, params, policy: QuantPolicy):
    cfg = model.cfg
    groups = model.quant_groups()
    by_key = {path_key(g.path): g for g in groups}

    # 1) unroll the stacked blocks into per-layer lists
    blocks = params["blocks"]
    if isinstance(blocks, list):  # transformer: n_sub stacked subtrees
        unrolled = [
            [jax.tree.map(lambda a: a[i], sub)
             for i in range(jax.tree.leaves(sub)[0].shape[0])]
            for sub in blocks
        ]
    else:  # rwkv: one stacked subtree
        L = jax.tree.leaves(blocks)[0].shape[0]
        unrolled = [jax.tree.map(lambda a: a[i], blocks) for i in range(L)]

    out = dict(params)
    out["blocks"] = unrolled

    # 2) walk groups, replacing leaves
    for g in groups:
        bits = policy.get(g.name)
        if g.path[0] == "blocks":
            if isinstance(blocks, list):
                sub = g.path[1]
                rest = g.path[2:]
                layer_tree = unrolled[sub][g.layer]
            else:
                rest = g.path[1:]
                layer_tree = unrolled[g.layer]
            w = get_by_path(layer_tree, rest)
            packed = _pack_bank(w, bits) if w.ndim == 3 else _pack_matrix(w, bits)
            new_layer = set_by_path(layer_tree, rest, packed)
            if isinstance(blocks, list):
                unrolled[sub][g.layer] = new_layer
            else:
                unrolled[g.layer] = new_layer
        elif g.path == ("embed",):
            if bits < FP_BITS:
                out["embed"] = QDQ(params["embed"], bits)
        elif g.path == ("lm_head",):
            out["lm_head"] = _pack_matrix(params["lm_head"], bits)
        else:  # pragma: no cover - future group kinds
            w = get_by_path(params, g.path)
            out = set_by_path(out, g.path, _pack_matrix(w, bits))
    return out


def serving_bytes(model, sparams) -> int:
    """Total weight bytes the decode step streams (roofline input)."""
    total = 0
    for leaf in jax.tree.leaves(sparams):
        total += leaf.size * leaf.dtype.itemsize
    return total


def make_decode_step(model, donate: bool = True):
    def step(sparams, cache, tokens):
        return model.decode_step(sparams, cache, tokens)

    return jax.jit(step, donate_argnums=(1,) if donate else ())


def make_prefill(model):
    """jit'd full-prompt prefill over serving-layout params (slot path).

    ``max_len`` is static (it sizes the KV cache); each distinct prompt
    length compiles its own executable — the compile churn the paged
    engine's chunked prefill (:func:`make_chunked_prefill`) eliminates.
    Kept for ``--cache slot`` parity.
    """

    def pre(sparams, tokens, max_len):
        return model.prefill(sparams, tokens=tokens, max_len=max_len)

    return jax.jit(pre, static_argnums=(2,))


def make_chunked_prefill(model, donate: bool = True):
    """jit'd fixed-shape chunk prefill into a pooled cache (paged path).

    ``step(sparams, cache, tokens (1, C), seq, start, valid)`` — C is
    static (baked by the tokens shape); seq/start/valid are data.  Any mix
    of prompt lengths therefore compiles exactly ONE executable (pinned by
    ``tests/test_serve_paged.py`` via the jit cache-size counter).  The
    pool cache is donated so chunk writes update the KV blocks in place.
    """

    def pre(sparams, cache, tokens, seq, start, valid):
        return model.prefill_chunk(sparams, cache, tokens, seq, start, valid)

    return jax.jit(pre, donate_argnums=(1,) if donate else ())


def make_verify_chunk(model, donate: bool = True):
    """jit'd batched speculative verifier over the pooled cache.

    ``ver(sparams, cache, tokens (B, C), starts (B,), valids (B,))`` — B is
    every pool row, C = spec window (k + 1); starts/valids are data, so all
    k+1 positions of every row are scored by ONE executable per window
    width (pinned alongside the prefill counter in the spec parity tests).
    Returns all-position logits (B, C, V) — the rejection sampler consumes
    them on the host.
    """

    def ver(sparams, cache, tokens, starts, valids):
        return model.verify_chunk(sparams, cache, tokens, starts, valids)

    return jax.jit(ver, donate_argnums=(1,) if donate else ())
