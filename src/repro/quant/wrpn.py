"""WRPN mid-tread quantizer (the paper's Eq. 1) with straight-through grads.

The paper (§4.2) adopts the technique of WRPN (Mishra et al., ICLR'18):

    weights are first scaled and clipped to the (-1.0, 1.0) range and
    quantized as per

        w_q = round((2^(k-1) - 1) * w_f) / (2^(k-1) - 1)

    where ``k`` is the bitwidth, of which ``k-1`` bits encode magnitude and
    one bit encodes sign.  Mid-tread style: zero IS a representable level.

Scaling convention: WRPN assumes weights already live in (-1, 1).  For
arbitrary pre-trained tensors we scale by ``max|w|`` per tensor (or per
output channel), quantize in the unit box, and scale back.  The scale is a
*dynamic* function of the weights during QAT (recomputed each step, cheap)
and is frozen into the packed representation at serving time.

Everything here is pure jnp and differentiable-by-construction (STE), so it
can be vmapped/pjit'd and used inside ``lax.scan`` layer stacks.  The Pallas
kernel in :mod:`repro.kernels.fake_quant` implements the same math tiled for
VMEM; :func:`fake_quant` is its oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Bitwidth >= FP_BITS means "leave in full precision".
FP_BITS = 32


def _levels(bits: jax.Array | int) -> jax.Array:
    """Number of positive quantization steps: 2^(k-1) - 1 (one bit = sign)."""
    bits = jnp.asarray(bits, dtype=jnp.float32)
    return jnp.maximum(2.0 ** (bits - 1.0) - 1.0, 1.0)


def tensor_scale(w: jax.Array, axis=None, eps: float = 1e-8) -> jax.Array:
    """max|w| scale so w/scale ∈ [-1, 1].  axis=None → per-tensor."""
    s = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
    return jnp.maximum(s, eps).astype(jnp.float32)


def fake_quant(
    w: jax.Array,
    bits: jax.Array | int,
    scale: jax.Array | None = None,
    axis=None,
) -> jax.Array:
    """Quantize-dequantize (no STE — raw, non-differentiable at steps).

    ``bits`` may be a traced scalar (so a *batch of bitwidth policies* can be
    fed as data — that is what lets vectorized ReLeQ environments share one
    executable, DESIGN.md §4).  ``bits >= FP_BITS`` returns ``w`` unchanged.
    """
    w = jnp.asarray(w)
    if scale is None:
        scale = tensor_scale(w, axis=axis)
    n = _levels(bits)
    wc = jnp.clip(w / scale, -1.0, 1.0)
    wq = jnp.round(wc * n) / n * scale
    is_fp = jnp.asarray(bits, dtype=jnp.int32) >= FP_BITS
    return jnp.where(is_fp, w, wq.astype(w.dtype))


@jax.custom_vjp
def _fq_ste(w: jax.Array, bits: jax.Array, scale: jax.Array) -> jax.Array:
    return fake_quant(w, bits, scale=scale)


def _fq_fwd(w, bits, scale):
    return fake_quant(w, bits, scale=scale), (w, scale)


def _fq_bwd(res, g):
    w, scale = res
    inside = (jnp.abs(w) <= scale).astype(g.dtype)
    return (g * inside, None, None)


_fq_ste.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_ste(w: jax.Array, bits: jax.Array, axis=None) -> jax.Array:
    """fake_quant with a straight-through estimator.

    Forward: WRPN mid-tread QDQ at max|w| scale (``axis=None``: per-tensor,
    the paper's choice; ``axis=0``: per-output-column, what the LM path uses
    so QAT sees EXACTLY the codes the bitplane serving path will pack).
    Backward: identity inside the clip region, zero outside (clipped STE) —
    standard QAT gradient, matching the paper's short-retrain loop.  The
    scale is treated as a constant in the backward pass.
    """
    scale = jax.lax.stop_gradient(tensor_scale(w, axis=axis))
    return _fq_ste(w, jnp.asarray(bits, jnp.int32), scale)


def quantize_to_int(
    w: jax.Array, bits: int, scale: jax.Array | None = None, axis=None
):
    """Quantize to signed integer codes in [-(2^(k-1)-1), +(2^(k-1)-1)].

    Returns ``(codes_int8_or_int32, scale)``.  Static ``bits`` only — this is
    the serving-time path (pack.py consumes the codes).
    """
    if bits >= FP_BITS:
        raise ValueError("quantize_to_int requires bits < 32")
    if scale is None:
        scale = tensor_scale(w, axis=axis)
    n = float(2 ** (bits - 1) - 1) if bits > 1 else 1.0
    wc = jnp.clip(jnp.asarray(w, jnp.float32) / scale, -1.0, 1.0)
    codes = jnp.round(wc * n)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return codes.astype(dtype), scale


def dequantize_from_int(codes: jax.Array, bits: int, scale: jax.Array):
    """Inverse of :func:`quantize_to_int`."""
    n = float(2 ** (bits - 1) - 1) if bits > 1 else 1.0
    return codes.astype(jnp.float32) / n * scale


def quant_error(w: jax.Array, bits: int) -> jax.Array:
    """Total squared quantization error ‖w − Q(w)‖² (ADMM baseline uses it)."""
    return jnp.sum((w - fake_quant(w, bits)) ** 2)
