"""Quantization substrate: WRPN quantizer, bitwidth policies, bitplane packing.

This package implements the quantization machinery that ReLeQ (core/) drives:

- :mod:`repro.quant.wrpn` — the paper's quantization technique (WRPN
  mid-tread, Eq. 1 of the paper) with a straight-through estimator so it can
  sit inside a QAT training step.
- :mod:`repro.quant.policy` — ``QuantPolicy``: the per-weight-group bitwidth
  assignment that the RL agent produces and every other layer consumes.
- :mod:`repro.quant.pack` — bitplane packing for the serving path (memory
  traffic scales with bitwidth; see DESIGN.md §3).
- :mod:`repro.quant.int8_opt` — block-wise int8 quantization of optimizer
  moments (beyond-paper: needed to fit 400B-scale optimizer state).
"""
from repro.quant.wrpn import (
    fake_quant,
    fake_quant_ste,
    quantize_to_int,
    dequantize_from_int,
    quant_error,
)
from repro.quant.policy import QuantPolicy, BITWIDTH_CHOICES
from repro.quant.pack import (
    pack_bitplanes,
    unpack_bitplanes,
    packed_nbytes,
)

__all__ = [
    "fake_quant",
    "fake_quant_ste",
    "quantize_to_int",
    "dequantize_from_int",
    "quant_error",
    "QuantPolicy",
    "BITWIDTH_CHOICES",
    "pack_bitplanes",
    "unpack_bitplanes",
    "packed_nbytes",
]
