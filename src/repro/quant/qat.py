"""QAT parameter transform: apply the WRPN STE fake-quant to every
quantizable group at its policy bitwidth — with bitwidths entering the jit'd
step as DATA, so one executable serves every ReLeQ policy candidate.

Paths are string keys ``"blocks/0/attn/wq"``; leaves with a stacked layer
axis get a per-layer bits vector and are vmapped (nested vmap for expert
banks), so the scan-based forward sees per-layer heterogeneous bitwidths at
zero HLO cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.quant.policy import QuantPolicy
from repro.quant.wrpn import FP_BITS, _fq_ste, tensor_scale


def path_key(path: tuple) -> str:
    return "/".join(str(p) for p in path)


def get_by_path(tree, path: tuple):
    node = tree
    for p in path:
        node = node[p]
    return node


def set_by_path(tree, path: tuple, value):
    """Functional set returning a shallow-copied tree along the path."""
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, list):
        new = list(tree)
    else:
        new = dict(tree)
    new[head] = set_by_path(tree[head], rest, value)
    return new


def bits_assignment(groups, policy: QuantPolicy) -> dict[str, np.ndarray]:
    """QuantPolicy -> {path_key: int32 () or (L_stack,) array}."""
    per_path: dict[tuple, dict | int] = {}
    for g in groups:
        b = policy.get(g.name)
        if g.layer is None:
            per_path[g.path] = b
        else:
            per_path.setdefault(g.path, {})[g.layer] = b
    out = {}
    for path, v in per_path.items():
        if isinstance(v, dict):
            L = max(v) + 1
            arr = np.full((L,), FP_BITS, np.int32)
            for i, b in v.items():
                arr[i] = b
            out[path_key(path)] = arr
        else:
            out[path_key(path)] = np.int32(v)
    return out


def _paths_index(groups):
    """path_key -> path tuple (stable order)."""
    return {path_key(g.path): g.path for g in groups}


def _qdq(leaf: jax.Array, bits: jax.Array, spec=None) -> jax.Array:
    """STE fake-quant at per-output-column scale (reduce dim ``ndim - 2``
    of every leaf: the matrix contraction dim, under any stacking of
    layer/expert axes) — exactly the codes the bitplane serving path
    packs, so there is no train/serve gap.

    ``spec`` (the leaf's PartitionSpec under the ambient mesh) anchors the
    scale's and output's sharding.  Without it, GSPMD propagates a
    conflicting layout onto the (..., 1, N) scale and re-broadcasting it
    against the weight triggers an *involuntary full rematerialization* of
    the whole stacked tensor — the 22.9 GB/device fsdp failure mode the
    dryrun log pointed at wrpn.py (scale div/mul + the STE backward's
    ``|w| <= scale`` compare).
    """
    ax = leaf.ndim - 2 if leaf.ndim >= 2 else 0
    scale = jax.lax.stop_gradient(tensor_scale(leaf, axis=ax))
    bits = jnp.asarray(bits, jnp.int32)
    bits = bits.reshape(bits.shape + (1,) * (leaf.ndim - bits.ndim))
    if spec is None:
        return _fq_ste(leaf, bits, scale)
    entries = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
    scale = jax.lax.with_sharding_constraint(
        scale, P(*(None if i == ax else e for i, e in enumerate(entries))))
    return jax.lax.with_sharding_constraint(
        _fq_ste(leaf, bits, scale), P(*entries))


def quantize_params(params, bits_map: dict[str, jax.Array], groups):
    """Return params with every group's leaf QDQ'd at its bitwidth.

    Under an ambient mesh (``jax.set_mesh``) each leaf's QDQ is annotated
    with its ``dist/sharding.py`` rule-table spec — see ``_qdq``."""
    from repro.compat import ambient_mesh
    from repro.models.common import shard_profile

    idx = _paths_index(groups)
    mesh = ambient_mesh()
    # dp profile: the step body runs inside shard_map (manual axes), where
    # sharding constraints are illegal — and pointless, params replicate
    use_mesh = (mesh is not None and not mesh.empty
                and shard_profile() != "dp")
    new = params
    for key, bits in bits_map.items():
        path = idx[key]
        leaf = get_by_path(params, path)
        spec = None
        if use_mesh:
            from repro.dist.sharding import leaf_spec

            spec = leaf_spec([str(p) for p in path], leaf.shape, mesh)
        new = set_by_path(new, path, _qdq(leaf, jnp.asarray(bits), spec))
    return new


def policy_for(model, default_bits: int = 8) -> QuantPolicy:
    """Fresh all-``default_bits`` policy with the model's frozen groups."""
    groups = model.quant_groups()
    return QuantPolicy(
        tuple(g.name for g in groups),
        {g.name: default_bits for g in groups},
        default_bits=default_bits,
        frozen=model.frozen_bits(),
    )
