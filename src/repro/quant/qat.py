"""QAT parameter transform: apply the WRPN STE fake-quant to every
quantizable group at its policy bitwidth — with bitwidths entering the jit'd
step as DATA, so one executable serves every ReLeQ policy candidate.

Paths are string keys ``"blocks/0/attn/wq"``; leaves with a stacked layer
axis get a per-layer bits vector and are vmapped (nested vmap for expert
banks), so the scan-based forward sees per-layer heterogeneous bitwidths at
zero HLO cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.policy import QuantPolicy
from repro.quant.wrpn import FP_BITS, fake_quant_ste


def path_key(path: tuple) -> str:
    return "/".join(str(p) for p in path)


def get_by_path(tree, path: tuple):
    node = tree
    for p in path:
        node = node[p]
    return node


def set_by_path(tree, path: tuple, value):
    """Functional set returning a shallow-copied tree along the path."""
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, list):
        new = list(tree)
    else:
        new = dict(tree)
    new[head] = set_by_path(tree[head], rest, value)
    return new


def bits_assignment(groups, policy: QuantPolicy) -> dict[str, np.ndarray]:
    """QuantPolicy -> {path_key: int32 () or (L_stack,) array}."""
    per_path: dict[tuple, dict | int] = {}
    for g in groups:
        b = policy.get(g.name)
        if g.layer is None:
            per_path[g.path] = b
        else:
            per_path.setdefault(g.path, {})[g.layer] = b
    out = {}
    for path, v in per_path.items():
        if isinstance(v, dict):
            L = max(v) + 1
            arr = np.full((L,), FP_BITS, np.int32)
            for i, b in v.items():
                arr[i] = b
            out[path_key(path)] = arr
        else:
            out[path_key(path)] = np.int32(v)
    return out


def _paths_index(groups):
    """path_key -> path tuple (stable order)."""
    return {path_key(g.path): g.path for g in groups}


def _qdq(leaf: jax.Array, bits: jax.Array) -> jax.Array:
    """STE fake-quant with the right vmap nesting for this leaf's rank.

    Scales are per output column (axis=0 of each 2-D matrix) — exactly the
    codes the bitplane serving path packs, so there is no train/serve gap.
    """
    fq = lambda w, b: fake_quant_ste(w, b, axis=0)
    if bits.ndim == 0:
        if leaf.ndim == 3:  # unstacked expert bank (E, D, F): per-expert scale
            return jax.vmap(lambda w: fq(w, bits))(leaf)
        return fq(leaf, bits)
    # stacked (L, ...) with per-layer bits
    if leaf.ndim == 4:  # (L, E, D, F) expert bank: per-(layer, expert) scale
        return jax.vmap(lambda w, b: jax.vmap(lambda we: fq(we, b))(w))(leaf, bits)
    return jax.vmap(fq)(leaf, bits)


def quantize_params(params, bits_map: dict[str, jax.Array], groups):
    """Return params with every group's leaf QDQ'd at its bitwidth."""
    idx = _paths_index(groups)
    new = params
    for key, bits in bits_map.items():
        path = idx[key]
        leaf = get_by_path(params, path)
        new = set_by_path(new, path, _qdq(leaf, jnp.asarray(bits)))
    return new


def policy_for(model, default_bits: int = 8) -> QuantPolicy:
    """Fresh all-``default_bits`` policy with the model's frozen groups."""
    groups = model.quant_groups()
    return QuantPolicy(
        tuple(g.name for g in groups),
        {g.name: default_bits for g in groups},
        default_bits=default_bits,
        frozen=model.frozen_bits(),
    )
