"""QuantPolicy: the per-layer bitwidth assignment ReLeQ searches over.

A *quantizable group* is one named weight tensor family of a model (e.g.
``"blocks.attn.wq"`` or CNN ``"conv1"``).  The RL agent's episode walks these
groups in order and assigns each a bitwidth from ``BITWIDTH_CHOICES``.

The policy has two faces:

- a host-side, human-readable mapping (dict, JSON round-trippable, printed in
  Table-2-style benchmark output), and
- a device-side dense ``int32[num_groups]`` vector (``as_array``) that enters
  the pjit'd train/serve step as *data* — crucial so that a vectorized batch
  of policies (num_envs × num_groups) shares one compiled executable.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.quant.wrpn import FP_BITS

# The paper's action set (§2.5 uses {1..8}; experiments use {2..8} for deep
# quantization with 8 as the safe ceiling).  Keep 1..8 available; configs can
# restrict.
BITWIDTH_CHOICES: tuple[int, ...] = (1, 2, 3, 4, 5, 6, 7, 8)


@dataclass
class QuantPolicy:
    """Mapping group-name -> bitwidth, with fixed (non-searchable) groups."""

    group_names: tuple[str, ...]
    bits: dict[str, int] = field(default_factory=dict)
    default_bits: int = 8
    frozen: dict[str, int] = field(default_factory=dict)  # e.g. router: 8, first/last: 8

    def __post_init__(self):
        self.group_names = tuple(self.group_names)
        unknown = set(self.bits) - set(self.group_names)
        if unknown:
            raise KeyError(f"bits for unknown groups: {sorted(unknown)}")
        for k, v in self.frozen.items():
            if k not in self.group_names:
                raise KeyError(f"frozen group {k!r} not in group_names")
            self.bits[k] = v

    # -- search interface ---------------------------------------------------
    @property
    def searchable(self) -> tuple[str, ...]:
        return tuple(g for g in self.group_names if g not in self.frozen)

    def with_bits(self, name: str, bits: int) -> "QuantPolicy":
        if name in self.frozen:
            raise ValueError(f"group {name!r} is frozen at {self.frozen[name]}")
        new = dict(self.bits)
        new[name] = int(bits)
        return QuantPolicy(self.group_names, new, self.default_bits, dict(self.frozen))

    def with_all(self, bits: int) -> "QuantPolicy":
        new = {g: int(bits) for g in self.searchable}
        new.update(self.frozen)
        return QuantPolicy(self.group_names, new, self.default_bits, dict(self.frozen))

    def get(self, name: str) -> int:
        return int(self.bits.get(name, self.default_bits))

    # -- device-side --------------------------------------------------------
    def as_array(self) -> np.ndarray:
        """Dense int32 vector aligned with ``group_names`` order."""
        return np.asarray([self.get(g) for g in self.group_names], dtype=np.int32)

    @classmethod
    def from_array(cls, group_names, arr, frozen=None) -> "QuantPolicy":
        arr = np.asarray(arr).reshape(-1)
        if len(arr) != len(group_names):
            raise ValueError(f"policy length {len(arr)} != groups {len(group_names)}")
        bits = {g: int(b) for g, b in zip(group_names, arr)}
        return cls(tuple(group_names), bits, frozen=dict(frozen or {}))

    # -- metrics ------------------------------------------------------------
    def average_bits(self) -> float:
        return float(np.mean(self.as_array()))

    def describe(self) -> str:
        return "{" + ", ".join(str(self.get(g)) for g in self.group_names) + "}"

    # -- serialization ------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "group_names": list(self.group_names),
                "bits": {g: self.get(g) for g in self.group_names},
                "default_bits": self.default_bits,
                "frozen": self.frozen,
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, s: str) -> "QuantPolicy":
        d = json.loads(s)
        return cls(
            tuple(d["group_names"]),
            {k: int(v) for k, v in d["bits"].items()},
            int(d.get("default_bits", 8)),
            {k: int(v) for k, v in d.get("frozen", {}).items()},
        )

    @classmethod
    def from_file(cls, path) -> "QuantPolicy":
        """Load a policy JSON written by ``to_json`` (search artifacts)."""
        with open(path) as f:
            return cls.from_json(f.read())

    @classmethod
    def full_precision(cls, group_names, frozen=None) -> "QuantPolicy":
        return cls(
            tuple(group_names),
            {g: FP_BITS for g in group_names if g not in (frozen or {})},
            frozen=dict(frozen or {}),
        )
