"""Block-wise int8 quantization of optimizer moments (beyond-paper).

Rationale (DESIGN.md §4): Adam on llama4-maverick-400b needs ~3.2 TB of
moment state in fp32 — it does not fit a 256×16 GB pod even fully sharded.
Storing both moments in block-wise int8 (dynamic per-block absmax scale,
block = 256 contiguous elements) cuts moment memory 4× at negligible quality
cost (the same scheme as 8-bit Adam, Dettmers et al.), and is thematically
the paper's own insight applied to the *optimizer*: bits you don't need are
bandwidth and capacity you get back.

The representation is a pytree-of-arrays (codes + scales) so it checkpoints
and reshards exactly like any other state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_pytree_with_keys_class
@dataclass
class QTensor:
    """Block-quantized tensor: int8 codes + float32 per-block scales.

    ``shape`` (the original tensor shape) is pytree aux data, so QTensor
    jits/vmaps/checkpoints like any array pair.
    """

    codes: jax.Array  # fp8 codes, (nblocks, BLOCK)
    scale: jax.Array  # float32, (nblocks,)
    shape: tuple      # original shape (static aux)

    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return ((k("codes"), self.codes), (k("scale"), self.scale)), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @property
    def nbytes_effective(self) -> int:
        return self.codes.size + 4 * self.scale.size


_F8 = jnp.float8_e4m3fn
_F8_MAX = 448.0  # finfo max; per-block scale maps blockmax here


def _block_for(last_dim: int) -> int:
    b = BLOCK
    while b > 1 and last_dim % b:
        b //= 2
    return b


def quantize_state(x: jax.Array) -> QTensor | jax.Array:
    """float tensor -> block-wise 8-bit QTensor.

    Codes are float8 e4m3 (dynamic/exponent quantization a la 8-bit Adam):
    linear int8 zeroes out the small entries of Adam's second moment inside
    a block (ratio < 1/127) and the rsqrt then explodes — fp8's ~2^18
    in-block dynamic range keeps tiny v entries alive.

    Blocks run along the LAST axis only — ``(…, F) -> (…, F/B, B)`` — so
    the leading dims keep their GSPMD sharding.  (A flat reshape replicates
    the tensor under SPMD: "involuntary full rematerialization", 515 GB of
    gathers on the llama4 expert banks; EXPERIMENTS.md §Perf.)  Leaves whose
    last dim resists blocking (<8) stay fp32 — tiny in practice.
    """
    shape = x.shape
    last = shape[-1] if shape else 1
    b = _block_for(last)
    if b < 8 or x.ndim == 0:
        return x  # not worth quantizing (scale overhead / scalars)
    blocks = x.astype(jnp.float32).reshape(*shape[:-1], last // b, b)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1), 1e-20)
    codes = (blocks / scale[..., None] * _F8_MAX).astype(_F8)
    return QTensor(codes, scale, shape)


def dequantize_state(q) -> jax.Array:
    if not isinstance(q, QTensor):
        return q
    blocks = (q.codes.astype(jnp.float32) / _F8_MAX) * q.scale[..., None]
    return blocks.reshape(q.shape)


def quantize_state_sq(x: jax.Array) -> QTensor:
    """Sqrt-space quantization for Adam's second moment: v's dynamic range
    is the SQUARE of the gradients' (ratio 1e-3 in g -> 1e-6 in v), which
    under-runs fp8 subnormals within a block and dequantizes to 0 — the
    rsqrt then explodes.  Storing sqrt(v) halves the log-range."""
    return quantize_state(jnp.sqrt(jnp.maximum(x, 0.0)))


def dequantize_state_sq(q: QTensor) -> jax.Array:
    return jnp.square(dequantize_state(q))


def tree_quantize(tree):
    return jax.tree.map(quantize_state, tree)


def tree_dequantize(tree):
    return jax.tree.map(
        dequantize_state, tree, is_leaf=lambda x: isinstance(x, QTensor)
    )
