"""Bitplane packing of quantized weights (serving path).

The TPU adaptation of the paper's bit-serial execution (DESIGN.md §3): a
``k``-bit weight matrix is stored as ``k`` binary planes, each packed 8 rows
per byte along the contraction axis.  HBM traffic then scales with ``k`` —
the property Stripes gets from bit-serial ALUs.

Layout
------
Given codes ``c ∈ [-(n), +n]`` with ``n = 2^(k-1) - 1`` for a ``(K, N)``
matrix, we store the *shifted unsigned* codes ``u = c + n ∈ [0, 2n]`` which
need exactly ``k`` bits.  Plane ``b`` holds bit ``b`` of ``u``.  Packed
buffer shape: ``(k, K//8, N) uint8`` — byte ``[b, j, :]`` holds rows
``8j..8j+7`` of plane ``b`` (row ``8j+i`` in bit ``i``).  ``N`` (the
non-contracted / output axis) stays minor-most so TP sharding of the packed
buffer divides ``N`` exactly like the parent matrix.

Reconstruction:  ``W = (Σ_b 2^b · plane_b − n) / n · scale``
Bit-serial GEMM: ``x @ W = (Σ_b 2^b (x @ plane_b) − n · rowsum(x)) / n · scale``
(the offset is a rank-1 correction computed once per activation tile).

Quantized-KV block layout (``serve.cache.PagedCachePool`` at ``kv_bits``)
-------------------------------------------------------------------------
KV blocks reuse the same symmetric mid-tread code family as the weight
planes but store *codes*, not bitplanes — a KV block is written once per
token and read many times, so read-side unpack cost dominates and plain
int8 containers win:

- code leaves ``k``/``v``: ``(L, NB, bs, KV, hd) int8`` holding
  ``c = round(x / scale) ∈ [-qmax, +qmax]`` with ``qmax = 2^(b-1) - 1``;
  at *uniform* 4 bits the container is nibble-packed to
  ``(L, NB, bs, KV, hd//2) uint8`` (two codes per byte, ``u = c + 8``,
  even head-dim index in the low nibble) — the 4x capacity deploy mode.
- scale leaves ``k_scale``/``v_scale``: ``(L, NB, bs, KV) float32`` —
  one amax scale per (layer, token slot, KV head).  Scales live at token
  granularity *within* each block, so a token is quantized exactly once
  at write time and never rescaled when its block's neighbors change.
- ``kv_qmax``: ``(L,) float32`` data leaf carrying each layer's code
  ceiling.  Per-layer bitwidths (the ReLeQ/HAQ search output) are plain
  *data* under one int8 container, so a mixed KV grid still compiles
  ONE decode executable.

The fp-KV parity oracle (``kv_oracle=True``) keeps fp32 code leaves but
writes ``dequantize(quantize(x))`` — the identical value the quantized
read path reconstructs — so token streams must match bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_with_keys_class
@dataclass
class Packed:
    """Bitplane-packed weight: planes (bits, K//8, N) u8 (+E axis for expert
    banks), per-column scale, and STATIC bits (pytree aux — it determines
    buffer shapes and kernel specialization)."""

    planes: jax.Array
    scale: jax.Array
    bits: int

    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return ((k("planes"), self.planes), (k("scale"), self.scale)), self.bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


@jax.tree_util.register_pytree_with_keys_class
@dataclass
class QDQ:
    """Dense weight tagged for quantize-dequantize at lookup (embeddings:
    a gather, not a matmul — packing buys no traffic there)."""

    w: jax.Array
    bits: int

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("w"), self.w),), self.bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def packed_nbytes(K: int, N: int, bits: int) -> int:
    """Bytes of the packed buffer for a (K, N) matrix at ``bits``."""
    return bits * ((K + 7) // 8) * N


def _check_k(K: int):
    if K % 8 != 0:
        raise ValueError(f"contraction dim {K} must be a multiple of 8 (pad first)")


def _check_bits(bits: int):
    # mid-tread ternary (k=1: {-1,0,1}) needs 2 planes — pack at >= 2 bits.
    if not 2 <= bits <= 8:
        raise ValueError(f"bitplane packing supports 2..8 bits, got {bits}")


def pack_bitplanes(codes, bits: int):
    """Pack signed codes (K, N) int -> (bits, K//8, N) uint8 planes.

    ``codes`` must lie in ``[-(2^(bits-1)-1), 2^(bits-1)-1]``.
    """
    codes = jnp.asarray(codes)
    K, N = codes.shape
    _check_k(K)
    _check_bits(bits)
    n = 2 ** (bits - 1) - 1 if bits > 1 else 1
    u = (codes.astype(jnp.int32) + n).astype(jnp.uint32)  # [0, 2n] needs `bits` bits
    # (bits, K, N) binary planes
    planes = (u[None, :, :] >> jnp.arange(bits, dtype=jnp.uint32)[:, None, None]) & 1
    # pack 8 consecutive K-rows into one byte
    planes = planes.reshape(bits, K // 8, 8, N).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))[None, None, :, None]
    return jnp.sum(planes * weights, axis=2, dtype=jnp.uint8)


def unpack_bitplanes(packed, bits: int):
    """Inverse: (bits, K//8, N) uint8 -> signed codes (K, N) int32."""
    packed = jnp.asarray(packed)
    b, K8, N = packed.shape
    if b != bits:
        raise ValueError(f"packed has {b} planes, expected {bits}")
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, None, :, None]
    bit = (packed[:, :, None, :] >> shifts) & 1  # (bits, K//8, 8, N)
    bit = bit.reshape(bits, K8 * 8, N).astype(jnp.int32)
    u = jnp.sum(bit << jnp.arange(bits, dtype=jnp.int32)[:, None, None], axis=0)
    n = 2 ** (bits - 1) - 1 if bits > 1 else 1
    return u - n


def pack_weight(w, bits: int):
    """Convenience: float (K, N) weight -> (packed_planes, scale per column).

    Returns ``(packed uint8 (bits, K//8, N), scale float32 (1, N))``.
    Per-output-channel scales (axis=0 reduction) — finer than the paper's
    per-tensor scale, strictly better accuracy at identical storage O(N).
    """
    from repro.quant.wrpn import quantize_to_int

    w = jnp.asarray(w)
    codes, scale = quantize_to_int(w, bits, axis=0)
    return pack_bitplanes(codes, bits), scale


def dequant_packed(packed, scale, bits: int):
    """Reconstruct float32 weights from packed planes + per-column scale."""
    n = float(2 ** (bits - 1) - 1) if bits > 1 else 1.0
    codes = unpack_bitplanes(packed, bits)
    return codes.astype(jnp.float32) / n * scale


def repack_weight(packed: Packed, bits: int) -> Packed:
    """Low-bit *view* of an already-packed weight: dequantize the planes
    and re-pack at ``bits`` < ``packed.bits``.

    This is how ``repro.spec`` derives its quantized self-draft — the
    draft is the SAME weights at fewer bitplanes (decode HBM traffic
    scales with plane count), so no second set of master weights is ever
    materialized.  If ``bits >= packed.bits`` the input is returned
    unchanged (re-packing could only lose precision).  Expert banks
    (leading E axis on the planes) re-pack per expert.
    """
    if bits >= packed.bits:
        return packed

    def one(planes, scale):
        w = dequant_packed(planes, scale, packed.bits)
        return pack_weight(w, bits)

    if packed.planes.ndim == 4:  # (E, bits, K//8, N) expert bank
        planes, scale = jax.vmap(one)(packed.planes, packed.scale)
    else:
        planes, scale = one(packed.planes, packed.scale)
    return Packed(planes, scale, bits)


# --------------------------------------------------------------------------
# Quantized-KV helpers (module docstring: "Quantized-KV block layout").
# These four functions are the single source of truth for KV numerics: the
# serve cache, the Pallas kernels and the jnp oracles all call them, which
# is what makes the fp-KV oracle parity gate *exact* rather than allclose.


def kv_quantize(x, qmax):
    """Per-(token, KV-head) symmetric quantization of new KV vectors.

    ``x``: float (..., KV, hd); ``qmax``: scalar (static or traced) code
    ceiling ``2^(b-1) - 1``.  Returns ``(codes int8 (..., KV, hd),
    scale float32 (..., KV))`` with ``scale = amax(|x|) / qmax`` over the
    head dim.  All-zero vectors get scale 0 and codes 0 (dequant -> 0).
    """
    x = jnp.asarray(x, jnp.float32)
    qmax = jnp.asarray(qmax, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)                     # (..., KV)
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    codes = jnp.clip(jnp.round(x / safe), -qmax, qmax).astype(jnp.int8)
    return codes, scale


def kv_dequantize(codes, scale):
    """codes int (..., KV, hd) + scale f32 (..., KV) -> float32 values."""
    return codes.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)[..., None]


def kv_qdq(x, qmax):
    """Quantize-dequantize — the write-side value of the fp-KV oracle.

    Computes *exactly* ``kv_dequantize(*kv_quantize(x, qmax))`` so an
    oracle cache (fp32 storage of these values) reproduces the quantized
    read path bit-for-bit.
    """
    codes, scale = kv_quantize(x, qmax)
    return kv_dequantize(codes, scale)


def kv_pack_int4(codes):
    """Nibble-pack int8 codes in [-7, 7]: (..., hd) -> (..., hd//2) uint8.

    Shifted ``u = c + 8 ∈ [1, 15]``; even head index -> low nibble.  Only
    used at *uniform* 4-bit KV (mixed per-layer grids stay int8).
    """
    hd = codes.shape[-1]
    if hd % 2:
        raise ValueError(f"head dim {hd} must be even for int4 packing")
    u = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)
    return u[..., 0::2] | (u[..., 1::2] << 4)


def kv_unpack_int4(packed):
    """Inverse of :func:`kv_pack_int4`: (..., hd//2) uint8 -> (..., hd) int8."""
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                                packed.shape[-1] * 2)


def pad_contraction_to_8(w: np.ndarray) -> np.ndarray:
    """Zero-pad axis 0 (contraction) up to a multiple of 8."""
    K = w.shape[0]
    pad = (-K) % 8
    if pad == 0:
        return w
    return np.pad(w, [(0, pad)] + [(0, 0)] * (w.ndim - 1))
