"""ReLeQ reproduction package.  Importing installs jax compat shims."""
from repro import compat as _compat  # noqa: F401
