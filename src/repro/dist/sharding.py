"""PartitionSpecs for every leaf the system moves: params, opt, batch, cache.

One rule table instead of per-arch spec trees: a leaf is classified by the
*names on its tree path* (``blocks/0/attn/wq``, ``blocks/tm/wo``,
``.../moe/wg/planes``) so the same rules cover every layout the repo
produces —

- stacked training params (leading ``L_super`` axis from the scan),
- unrolled serving params (per-layer lists of ``Packed`` bitplane weights,
  whose ``planes``/``scale`` leaves inherit their matrix's rule),
- optimizer moments (fp32 mirrors or int8 ``QTensor`` code blocks, which
  inherit the parent parameter's rule through their path suffix).

Profiles (``models.common.shard_profile``, env ``REPRO_SHARD_PROFILE``):

- ``tp`` / ``tp_sp``: Megatron pairing — attention/MLP input projections
  column-parallel (output dim on "model"), output projections row-parallel
  (contraction dim on "model"); embed vocab-parallel; lm_head
  vocab-parallel (matching the readout's ``constrain(..., "model")``);
  MoE banks expert-parallel (E on "model", feeding ``moe._moe_ep``'s
  all-to-all).
- ``fsdp``: every matched weight shards its rule dim over *all* mesh axes
  (ZeRO-3 layout; activations batch-shard over everything).

Every placement passes a divisibility guard — an axis (or axis suffix)
that does not divide the dim is dropped, never erred on — so glm4's
kv=2 heads, a 251-token smoke vocab, or a batch-1 long-context decode all
degrade to replication instead of failing to lower.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import shard_profile
from repro.models.model import cache_batch_axis

# (parent, matrix) -> column-parallel (shard the output/minor dim) or
# row-parallel (shard the contraction dim).  Covers transformer attn/mlp,
# hymba's mamba branch, and rwkv's time-mix/channel-mix blocks.
_COL = {
    ("attn", "wq"), ("attn", "wk"), ("attn", "wv"),
    ("mlp", "wg"), ("mlp", "wu"), ("shared", "wg"), ("shared", "wu"),
    ("ssm", "in_x"), ("ssm", "in_z"),
    ("tm", "wr"), ("tm", "wk"), ("tm", "wv"), ("tm", "wg"),
    ("cm", "wk"), ("cm", "wr"),
}
_ROW = {
    ("attn", "wo"), ("mlp", "wd"), ("shared", "wd"), ("ssm", "out"),
    ("tm", "wo"), ("cm", "wv"),
}
# norm/gain vectors: their gradient is reduced from "model"-sharded
# activations, so GSPMD propagation lands their D dim on "model"; placing
# them there keeps state_specs a fixed point of the compiled step (a
# committed arg whose sharding drifts from in_shardings is a hard error)
_NORM = {"ln1", "ln2", "final_norm", "gn"}
# leaf attributes of container pytrees (Packed / QDQ / QTensor) that
# inherit the parent matrix's rule rather than naming a matrix themselves
_CONTAINER_ATTRS = ("planes", "scale", "w", "codes")


def _key_name(entry) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def _mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _fit(dim: int, axes, sizes):
    """Largest suffix of ``axes`` whose size product divides ``dim``
    (same fallback constrain() uses), or None -> replicate this dim."""
    axes = tuple(a for a in axes if a in sizes)
    for start in range(len(axes)):
        sub = axes[start:]
        if dim % math.prod(sizes[a] for a in sub) == 0:
            return sub[0] if len(sub) == 1 else sub
    return None


def _batch_mesh_axes(mesh) -> tuple[str, ...]:
    axes = (("pod", "data", "model") if shard_profile() == "fsdp"
            else ("pod", "data"))
    return tuple(a for a in axes if a in mesh.axis_names)


def _leaf_spec(names: list[str], shape, mesh) -> P:
    """Sharding rule for one leaf, by path names + shape."""
    sizes = _mesh_sizes(mesh)
    nd = len(shape)
    if nd == 0:
        return P()
    core = [n for n in names if not n.isdigit() and n not in _CONTAINER_ATTRS]
    mat = core[-1] if core else ""
    parent = core[-2] if len(core) >= 2 else ""
    leaf_attr = names[-1] if names else ""

    if mat in _NORM:
        dim, axes = nd - 1, ("model",)
    elif mat == "router":
        # router (L, D, E): D over the combined axes (what propagation
        # picks — E is routing-critical and tiny, never sharded)
        dim, axes = max(nd - 2, 0), ("data", "model")
    elif parent == "moe" and mat in ("wg", "wu", "wd"):
        # expert-parallel bank: E axis on "model" (feeds _moe_ep's a2a).
        # raw (E,D,F) / packed planes (E,bits,K8,N) / scale (E,1,N): E=0;
        # scan-stacked training bank (L_super, E, D, F): E=1.
        dim = 1 if (leaf_attr not in _CONTAINER_ATTRS and nd == 4) else 0
        axes = ("model",)
    elif mat == "embed":
        dim, axes = max(nd - 2, 0), ("model",)      # vocab rows
    elif mat == "lm_head":
        dim, axes = nd - 1, ("model",)              # vocab-parallel readout
    elif (parent, mat) in _COL and nd >= 2:
        dim, axes = nd - 1, ("model",)
    elif (parent, mat) in _ROW and nd >= 2:
        dim, axes = nd - 2, ("model",)
    else:
        # norms, routers, decay LoRAs, token-shift mixes, step counters:
        # tiny and sensitivity-critical — replicated in every profile
        return P()

    if shard_profile() == "fsdp":
        # ZeRO-3: shard over ALL axes — and when the rule dim doesn't
        # divide the full device count (glm4's d_ff = 13696 on 256 chips
        # degrades to 16-way), fall back to whichever dim shards widest:
        # the weight/grad/moment bytes per device are what fsdp exists to
        # bound, not which dim they split on
        axes = tuple(mesh.axis_names)

        def width(fit):
            names = (fit,) if isinstance(fit, str) else tuple(fit or ())
            return math.prod(sizes[a] for a in names)

        fit = _fit(shape[dim], axes, sizes)
        if width(fit) < math.prod(sizes[a] for a in axes):
            for d in sorted(range(nd), key=lambda d: -shape[d]):
                alt = _fit(shape[d], axes, sizes)
                if width(alt) > width(fit):
                    dim, fit = d, alt
        spec = [None] * nd
        spec[dim] = fit
        return P(*spec)
    spec = [None] * nd
    spec[dim] = _fit(shape[dim], axes, sizes)
    return P(*spec)


def leaf_spec(path_names: list, shape, mesh) -> P:
    """Public single-leaf rule lookup (``quant/qat.py`` uses it to anchor
    the QDQ scale/output sharding inside the train step)."""
    return _leaf_spec([str(n) for n in path_names], shape, mesh)


def param_specs(params, mesh):
    """Pytree of PartitionSpec, one per array leaf of ``params``.

    ``params`` may hold real arrays or ``ShapeDtypeStruct``s (the dry-run
    lowers against ``launch/specs.py`` structs), in training, serving
    (Packed/QDQ) or optimizer (QTensor) layout.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_leaf_spec([_key_name(k) for k in path], leaf.shape, mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_specs(state, mesh):
    """Specs for a train state ``{"params": ..., "opt": ...}``.

    Optimizer moments mirror their parameter's spec through the shared
    path suffix (``opt/m/blocks/0/attn/wq`` matches the same rule as
    ``params/blocks/0/attn/wq``); the step counter replicates.
    """
    return param_specs(state, mesh)


def batch_specs(batch, mesh, *, seq_shard: bool = False):
    """Specs for an input batch dict (tokens/labels/embeds/positions).

    The batch dim shards over the profile's batch axes; ``seq_shard=True``
    additionally puts the sequence dim on "model" (long-context prefill)
    when the profile keeps "model" free of batch.
    """
    sizes = _mesh_sizes(mesh)
    baxes = _batch_mesh_axes(mesh)
    seq_ax = ("model" if seq_shard and "model" not in baxes
              and "model" in sizes else None)

    def spec(key, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        bdim = 1 if key == "positions" and nd == 3 else 0
        s = [None] * nd
        s[bdim] = _fit(leaf.shape[bdim], baxes, sizes)
        if seq_ax and nd > bdim + 1 and leaf.shape[bdim + 1] % sizes["model"] == 0:
            s[bdim + 1] = seq_ax
        return P(*s)

    return {k: spec(k, v) for k, v in batch.items()}


def cache_specs(cache, mesh):
    """Specs for a decode cache: the slot/batch axis (per-leaf position
    from ``models.model.cache_batch_axis``) shards over the data axes;
    heads/state dims stay local.  The paged pool reuses the same rule —
    its block axis sits exactly where the slot axis does (axis 1 of every
    paged ``(L, NB, bs, ...)`` leaf), so KV *blocks* spread over the data
    axes; the tiny per-sequence ``block_tables`` replicate (every shard
    needs the full table to resolve its gathers).

    Prefix sharing changes none of this: refcounts and the prefix trie
    are host-side bookkeeping over *block ids*, sharing is just two
    table rows naming the same block (tables are replicated either way),
    and the COW copy (``serve.cache._cow_jit``) is a block-row
    gather/scatter whose donated output keeps each leaf's sharding —
    the sharded pool leaves are unchanged by this feature."""
    sizes = _mesh_sizes(mesh)
    daxes = tuple(a for a in ("pod", "data") if a in sizes)

    def spec(key, leaf):
        nd = len(leaf.shape)
        if nd == 0 or key == "block_tables":
            return P()
        ax = cache_batch_axis(key)
        if ax < 0:  # no per-sequence axis (kv_qmax): replicate
            return P()
        s = [None] * nd
        s[ax] = _fit(leaf.shape[ax], daxes, sizes)
        return P(*s)

    return {k: spec(k, v) for k, v in cache.items()}


def to_named(specs, mesh):
    """PartitionSpec pytree -> NamedSharding pytree (device_put /
    in_shardings-ready)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
