"""Compressed all-reduce: fp8 all-gather phase with error feedback.

The data-parallel gradient all-reduce moves 4 bytes/element per step; this
module moves fp8 *planes* instead — the communication analogue of the
repo's bitplane-packed weights (``quant/pack.py``): each shard compresses
its local array into ``planes`` successive e4m3 payloads (value, then the
residual of that rounding, ...), all-gathers the planes + their scalar
scales, and reduces the dequantized sum.  Wire bytes: ``planes + 4/n_dev``
per element vs 4 for an exact fp32 psum — 2x at the default 2 planes.

Error feedback: what even the last plane could not represent is returned
as the local residual ``fb`` for the caller to fold into the *next* step's
input (``compressed_allreduce(g, axis, residual=fb)``), the standard EF
construction that keeps compressed SGD unbiased over time.  With 2 planes
the per-call relative error is ~0.1%% (bounded by the second plane's fp8
step), comfortably inside the 5%% budget ``tests/test_collectives.py``
pins against an exact psum.

Must be called inside ``jax.shard_map`` (it uses named-axis collectives);
payloads cross the wire as uint8 bitcasts so the fp8 dtype never has to
be supported by the backend's collective kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_E4M3_MAX = 448.0


def _fp8_planes(x: jax.Array, planes: int):
    """x f32 -> (quantized planes [(q_u8, scale)], residual).

    Plane 0 carries the value, plane i the rounding residual of planes
    <i, each at its own per-plane scalar scale mapping max|.| -> e4m3 max.
    """
    qs, ss = [], []
    r = x
    for _ in range(planes):
        s = jnp.maximum(jnp.max(jnp.abs(r)), 1e-30) / _E4M3_MAX
        q = (r / s).astype(jnp.float8_e4m3fn)
        qs.append(jax.lax.bitcast_convert_type(q, jnp.uint8))
        ss.append(s)
        r = r - q.astype(jnp.float32) * s
    return jnp.stack(qs), jnp.stack(ss), r


def compressed_allreduce(x: jax.Array, axis_name: str, *,
                         residual: jax.Array | None = None,
                         planes: int = 2, mean: bool = True,
                         axis_size: int | None = None):
    """All-reduce ``x`` over ``axis_name`` through an fp8 wire format.

    Returns ``(reduced, fb)``: the (mean by default) reduction of every
    shard's *dequantized* planes, and this shard's local error-feedback
    residual.  Pass ``fb`` back as ``residual`` on the next call so the
    compression error averages out instead of accumulating.

    Two constructions:

    - ``axis_size=None`` (PR-2 original): all-gather every shard's planes
      and reduce locally.  Simple, but each device *receives* ``n·planes``
      bytes/element — it loses to an exact ring all-reduce beyond n ≈ 4.
    - ``axis_size=n`` (static shard count): bandwidth-optimal two-phase
      reduce-scatter/all-gather analogue.  Compress → all-to-all chunk
      exchange → decompress-and-reduce own chunk → re-compress → all-gather
      — ``≈ 2·planes·(n-1)/n`` send bytes/element, n-independent, vs
      ``8·(n-1)/n`` for the exact fp32 ring (2.0x at the default 2 planes;
      measured from HLO by ``launch/dryrun.py --dp-collectives``).  The
      stage-2 (reduced-chunk) quantization error is folded into this
      shard's slice of ``fb`` alongside the stage-1 residual.
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    if axis_size is not None:
        return _compressed_rs_ag(x, xf, axis_name, planes, mean, axis_size)
    q_u8, scales, fb = _fp8_planes(xf, planes)

    # --- fp8 all-gather phase: planes as uint8 + scalar scales ---
    gq = jax.lax.all_gather(q_u8, axis_name)      # (n, planes, ...)
    gs = jax.lax.all_gather(scales, axis_name)    # (n, planes)
    vals = jax.lax.bitcast_convert_type(
        gq, jnp.float8_e4m3fn).astype(jnp.float32)
    vals = vals * gs.reshape(gs.shape + (1,) * x.ndim)
    out = jnp.sum(vals, axis=(0, 1))
    if mean:
        out = out / jax.lax.psum(1, axis_name)
    return out.astype(x.dtype), fb.astype(x.dtype)


def _compressed_rs_ag(x, xf, axis_name: str, planes: int, mean: bool,
                      n: int):
    """Two-phase compressed all-reduce (see compressed_allreduce)."""
    shape = x.shape
    flat = xf.reshape(-1)
    c = -(-flat.size // n)
    flatp = jnp.pad(flat, (0, n * c - flat.size))
    q_u8, scales, fb1 = _fp8_planes(flatp.reshape(n, c), planes)
    # phase 1: chunk j of every shard travels to device j (compressed)
    gq = jax.lax.all_to_all(q_u8, axis_name, split_axis=1, concat_axis=1,
                            tiled=True)            # (planes, n, c): peer-major
    gs = jax.lax.all_gather(scales, axis_name)     # (n, planes)
    vals = jax.lax.bitcast_convert_type(
        gq, jnp.float8_e4m3fn).astype(jnp.float32)
    mine = jnp.sum(vals * gs.T[:, :, None], axis=(0, 1))   # (c,) reduced
    if mean:
        mine = mine / n
    # phase 2: re-compress the reduced chunk, all-gather all chunks
    q2, s2, fb2 = _fp8_planes(mine, planes)
    gq2 = jax.lax.all_gather(q2, axis_name)        # (n, planes, c)
    gs2 = jax.lax.all_gather(s2, axis_name)        # (n, planes)
    out = jnp.sum(jax.lax.bitcast_convert_type(
        gq2, jnp.float8_e4m3fn).astype(jnp.float32)
        * gs2[..., None], axis=1)                  # (n, c)
    out = out.reshape(-1)[:flat.size].reshape(shape)
    # error feedback: stage-1 residual everywhere + this shard's stage-2
    # residual at its own chunk (scaled back up if the wire carried means)
    me = jax.lax.axis_index(axis_name)
    fb = fb1.at[me].add(fb2 * (n if mean else 1))
    fb = fb.reshape(-1)[:flat.size].reshape(shape)
    return out.astype(x.dtype), fb.astype(x.dtype)


def compressed_allreduce_tree(tree, axis_name: str, *, residuals=None,
                              planes: int = 2, mean: bool = True,
                              axis_size: int | None = None):
    """Per-leaf ``compressed_allreduce`` over a gradient pytree.

    ``residuals`` is the matching error-feedback pytree from the previous
    step (or None on step 0).  Returns ``(reduced_tree, residual_tree)``
    — thread the residuals through the train step's carried state
    (``train_step.make_dp_train_step`` carries them as ``state["ef"]``).
    ``axis_size`` selects the two-phase wire-optimal construction.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    res = (jax.tree_util.tree_leaves(residuals) if residuals is not None
           else [None] * len(leaves))
    outs, fbs = [], []
    for leaf, r in zip(leaves, res):
        o, f = compressed_allreduce(leaf, axis_name, residual=r,
                                    planes=planes, mean=mean,
                                    axis_size=axis_size)
        outs.append(o)
        fbs.append(f)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, fbs))
