"""Elastic reshard: restore a checkpoint onto whatever mesh exists now.

``repro.ckpt`` stores leaves host-gathered (full arrays, no per-device
files), so elasticity is purely a *placement* problem: read the tree,
cast each leaf to the template's dtype, and ``device_put`` it under the
specs ``dist/sharding.py`` derives for the current mesh.  A 4-chip
checkpoint restores onto 8 chips (or 256 -> 512) with no resharding
pass — the cost is one host->device scatter, which a restart pays anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import ckpt as ckpt_lib
from repro.dist import sharding as shd


def place(state, mesh, specs=None):
    """device_put ``state`` under ``specs`` (derived when None)."""
    specs = specs if specs is not None else shd.state_specs(state, mesh)
    return jax.device_put(state, shd.to_named(specs, mesh))


def restore_elastic(directory: str, like, mesh, *, specs=None,
                    step: int | None = None):
    """-> (state placed on ``mesh``, meta, step).

    ``like`` is a template pytree (arrays or ShapeDtypeStructs) giving the
    target structure and dtypes; the checkpoint may have been written
    under any device count.  Raises FileNotFoundError when no checkpoint
    exists — callers fall back to a fresh init.
    """
    tree, meta, step = ckpt_lib.restore(directory, step)
    cast = jax.tree.map(lambda ref, a: jnp.asarray(a, ref.dtype), like, tree)
    return place(cast, mesh, specs), meta, step
