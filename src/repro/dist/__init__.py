"""repro.dist — the distribution layer: specs, collectives, elasticity.

Why a package
-------------
ReLeQ's payoff is layer-wise low-bit policies executing fast on real
hardware; at production scale that execution is *sharded*.  Three concerns
live here, one file per concern:

- ``sharding.py``   PartitionSpec rules for every leaf of every arch in
  ``repro.configs`` — params, optimizer state, batches, decode caches —
  profile-aware (tp / tp_sp / fsdp, see ``models.common.shard_profile``)
  and divisibility-guarded, so the same rules serve the 1-device smoke
  mesh, an 8-fake-device test mesh and the 512-chip multi-pod dry-run.
- ``collectives.py`` ``compressed_allreduce``: gradient all-reduce whose
  wire format is fp8 bitplanes (the communication analogue of the repo's
  bitplane-packed weights) with error feedback, ≤5%% relative error vs an
  exact psum.
- ``elastic.py``    Restore a checkpoint written under any device count
  onto the current mesh (4-chip save -> 8-chip restore and vice versa),
  wrapping ``repro.ckpt``'s host-gathered layout.

Consumers: ``launch/dryrun.py`` (compile-only roofline over every
(arch x shape x mesh) cell), ``launch/train.py`` / ``train.trainer``
(elastic restart), ``serve.cache.SlotCachePool`` (data-axis slot
sharding), and the tier-1 tests ``tests/test_distributed.py`` /
``tests/test_collectives.py``.
"""
from repro.dist.collectives import compressed_allreduce, compressed_allreduce_tree
from repro.dist.elastic import restore_elastic
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    state_specs,
    to_named,
)

__all__ = [
    "batch_specs",
    "cache_specs",
    "compressed_allreduce",
    "compressed_allreduce_tree",
    "param_specs",
    "restore_elastic",
    "state_specs",
    "to_named",
]
