"""repro.serve — continuous-batching serving for ReLeQ-quantized models.

Why
---
The paper's payoff is inference: a learned mixed-precision policy buys
~2.2x over 8-bit execution, but only if the deployment path keeps the
hardware busy.  A static batch loop (the old ``launch/serve.py``) admits
a fixed batch, decodes until the *longest* sequence finishes, and leaves
every early-finishing slot idle — at heterogeneous output lengths most of
the speedup the packed kernels buy is burned on padding.  This package is
an iteration-level (Orca-style) engine: requests are admitted the moment
a slot frees up, mid-decode, and every step packs all running sequences
into one jit'd decode over the bit-packed weights.

Architecture (one file per concern)
-----------------------------------
- ``request.py``   Request / SamplingParams / token selection.  A request
  is a prompt + ``max_new_tokens`` budget + sampling params; greedy
  (temperature 0) is the parity-critical default.
- ``queue.py``     FIFO admission queue with optional backpressure.
- ``cache.py``     ``SlotCachePool`` — ONE preallocated decode cache of
  ``num_slots`` sequences.  Admission splices a batch-1 prefill cache
  into a free slot (``models.model.cache_batch_axis`` gives the slot axis
  per leaf, so the same pool code serves transformer KV, Mamba state and
  RWKV wkv caches); finished sequences free their slot immediately.
- ``scheduler.py`` ``ContinuousScheduler`` — host-side admit/advance/
  finish bookkeeping; the device-side decode stays one fixed-shape
  executable regardless of traffic.
- ``engine.py``    ``ServeEngine`` — ``submit()`` / ``step()`` /
  ``run_until_drained()`` + per-request (TTFT, latency) and aggregate
  (tokens/s, slot occupancy) metrics.  ``ServeEngine.from_params`` packs
  training params at a ReLeQ ``QuantPolicy`` once, at construction.

Use
---
    from repro.serve import ServeEngine, SamplingParams
    engine = ServeEngine.from_params(model, params, policy, num_slots=8,
                                     max_len=256)
    rid = engine.submit(prompt_ids, max_new_tokens=64)
    engine.run_until_drained()
    tokens, stats = engine.output(rid), engine.metrics()

CLI: ``python -m repro.launch.serve --mode continuous`` (``--mode
static`` keeps the legacy one-shot loop).  Benchmark: ``python -m
benchmarks.serve_bench`` compares the two at several bitwidth policies.

Guarantees
----------
- A single request's tokens are bit-identical to the legacy static loop
  at the same ``QuantPolicy`` (decode is row-independent; pinned by
  ``tests/test_serve_engine.py``).
- Slot alloc/free is exact: no double-alloc, no double-free, finished
  slots reusable the next step.

Sharding: pass ``mesh=`` to ``ServeEngine`` (or ``SlotCachePool``) and the
slot pool is placed over the mesh's data axes via ``repro.dist`` — decode
cache updates stay shard-local (parity pinned in
``tests/test_distributed.py::test_sharded_slot_pool_parity``).  Admission
is still a single-host decision; making it collective across hosts is the
recorded ROADMAP follow-up.

Known limits (ROADMAP "Open items"): greedy/temperature sampling only,
prefill recompiles per distinct prompt length (no bucketing yet),
single-host admission.
"""
from repro.serve.cache import SlotCachePool
from repro.serve.engine import ServeEngine
from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, RequestState, SamplingParams
from repro.serve.scheduler import ContinuousScheduler

__all__ = [
    "AdmissionQueue", "ContinuousScheduler", "Request", "RequestState",
    "SamplingParams", "ServeEngine", "SlotCachePool",
]
