"""repro.serve — continuous batching over a PAGED KV cache for
ReLeQ-quantized models.

Why
---
The paper's payoff is inference: a learned mixed-precision policy buys
~2.2x over 8-bit execution, but only if the deployment path keeps the
hardware busy.  Iteration-level (Orca-style) batching fixes the padding
waste of static batches; block-granular (vLLM-style) paging fixes the
two costs that remained:

- **memory**: a slot pool gives every sequence a ``max_len``-sized cache
  row, so mixed-length traffic wastes most of the pool.  The paged pool
  hands out fixed-size KV *blocks* on demand — at equal cache bytes it
  runs strictly more concurrent sequences (pinned in the benchmark).
- **compile churn**: full-prompt prefill compiles one executable per
  distinct prompt length.  Chunked prefill feeds fixed-shape chunks with
  (seq, start, valid) as data — ONE prefill + ONE decode executable for
  any traffic mix (pinned via jit cache counters).

Architecture (one file per concern)
-----------------------------------
- ``request.py``   Request / SamplingParams (greedy / temperature /
  top-k / top-p nucleus) / token selection; replay bookkeeping for
  preemption resume.
- ``queue.py``     FIFO admission queue with optional backpressure;
  ``push_front`` requeues preempted sequences at the head.
- ``cache.py``     ``PagedCachePool`` — transformer K/V as a
  ``(L, num_blocks, block_size, KV, hd)`` block pool + per-sequence block
  tables (physical block 0 is a reserved garbage sink for idle decode
  rows); O(1)-state leaves (Mamba ``ssm_*``, RWKV ``wkv``/token-shift)
  keep slot semantics behind the same interface via
  ``models.model.cache_batch_axis``.  Sliding-window archs keep their
  ring layout — the block size shrinks to divide the ring length.
  ``SlotCachePool`` is the legacy slot pool, kept one release behind
  ``--cache slot`` as the parity baseline.
- ``scheduler.py`` ``ContinuousScheduler`` — admits on free row + free
  blocks for the whole prompt, reserves one token of growth per running
  sequence before each decode, and on block exhaustion *preempts and
  requeues the youngest sequence* (recompute-style: re-admission replays
  prompt + emitted tokens; greedy decode makes the replay exact, so the
  client-visible stream is unchanged).
- ``engine.py``    ``ServeEngine(cache="paged"|"slot")`` — ``submit()`` /
  ``step()`` / ``run_until_drained()`` + per-request (TTFT, latency,
  preemptions) and aggregate (tokens/s, row + block occupancy) metrics.
  ``ServeEngine.from_params`` packs training params at a ReLeQ
  ``QuantPolicy`` once, at construction.  ``spec=SpecConfig(...)`` turns
  on speculative decoding with a quantized self-draft (``repro.spec``):
  the same packed weights re-read at fewer bitplanes roll k tokens per
  window through the SAME paged block tables (zero extra KV blocks), one
  fixed-shape ``verify_chunk`` call scores all k+1 positions at the
  serving policy, and exact rejection sampling keeps the emitted stream
  distribution-identical to non-speculative serving.

Decode attends by block table through ``kernels.ops.paged_attention``: a
Pallas kernel whose BlockSpec index map IS the block table (each live
block DMA'd exactly once, scalar-prefetched — ``kernels/
paged_attention.py``), with a gather + ``decode_attention`` oracle in
``kernels/ref.py`` as the CPU path.

Use
---
    from repro.serve import ServeEngine, SamplingParams
    engine = ServeEngine.from_params(model, params, policy, num_slots=8,
                                     max_len=256, block_size=16)
    rid = engine.submit(prompt_ids, max_new_tokens=64)
    engine.run_until_drained()
    tokens, stats = engine.output(rid), engine.metrics()

CLI: ``python -m repro.launch.serve --mode continuous [--cache slot]``.
Benchmark: ``python -m benchmarks.serve_bench`` (static vs slot vs paged
per bitwidth + the mixed-prompt-length paged section; CI uploads its
``BENCH_serve.json``).

Guarantees
----------
- Paged output is token-for-token identical to the slot engine — and the
  slot engine to the legacy static loop — for the same request stream
  (greedy, all three model families; pinned in
  ``tests/test_serve_paged.py`` / ``tests/test_serve_engine.py``).
- Speculative output is token-identical to non-speculative under greedy
  and distribution-exact at temperature>0 (chi-square gated), for ANY
  draft policy — acceptance only moves speed, never the stream.
- Allocator exactness (hypothesis-tested): no double-alloc, no leak,
  free-list exhaustion surfaces as preemption, never a crash.

Sharding: pass ``mesh=`` and the pool is placed over the mesh's data
axes via ``repro.dist`` — the paged pool's *block* axis sits where the
slot axis did, so ``cache_specs`` covers both (parity pinned in
``tests/test_distributed.py::test_sharded_pool_parity``).  Admission is
still a single-host decision; making it collective across hosts is the
recorded ROADMAP follow-up.

Known limits (ROADMAP "Open items"): no beam search / logit bias,
single-host admission, no block sharing between sequences (prefix
caching) yet.
"""
from repro.serve.cache import PagedCachePool, SlotCachePool
from repro.serve.engine import ServeEngine
from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, RequestState, SamplingParams
from repro.serve.scheduler import ContinuousScheduler

__all__ = [
    "AdmissionQueue", "ContinuousScheduler", "PagedCachePool", "Request",
    "RequestState", "SamplingParams", "ServeEngine", "SlotCachePool",
]
