"""ServeEngine: the top-level continuous-batching serve loop.

``submit()`` enqueues a request; ``step()`` runs one engine iteration
(admit -> prefill new sequences -> one packed decode step over every
running row); ``run_until_drained()`` steps until queue and rows are
empty.  Weights stay bit-packed (``quant.pack``) at a ReLeQ
``QuantPolicy`` for the whole lifetime of the engine — quantization cost
is paid once at construction, not per request.

Two cache backends (``cache=`` / ``launch/serve.py --cache``):

- ``"paged"`` (default): block-granular ``PagedCachePool``.  Admission
  runs *fixed-shape chunked prefill* directly into the sequence's blocks
  — any mix of prompt lengths compiles exactly ONE prefill executable and
  ONE decode executable (the slot path compiles a prefill per distinct
  prompt length).  Before each decode the scheduler reserves one token of
  growth per running sequence; block exhaustion preempts-and-requeues the
  youngest sequence, whose re-admission replays prompt + emitted tokens
  (deterministic greedy decode ⇒ the client-visible stream is unchanged).
- ``"slot"``: the legacy slot pool (full-prompt prefill + splice), kept
  one release as the parity baseline.

Numerics: the decode step is row-independent (per-sequence attention/SSM
state, drop-free MoE routing in decode), so a request's tokens are
bit-identical whether it shares the batch with 0 or ``num_slots - 1``
other sequences — and the paged decode gathers each sequence's pages into
exactly the contiguous rows the slot pool stores, which is what pins
paged-vs-slot token parity (tests/test_serve_paged.py).

Metrics: per-request TTFT (seconds *and* engine steps), wall latency,
token counts and preemptions, plus aggregate tokens/s, mean row occupancy
and (paged) mean block occupancy over decode steps.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.quant.policy import QuantPolicy
from repro.serve.cache import PagedCachePool, SlotCachePool
from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, SamplingParams
from repro.serve.scheduler import ContinuousScheduler
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_prefill,
)


class ServeEngine:
    def __init__(self, model, sparams, *, num_slots: int = 8,
                 max_len: int = 256, cache: str = "paged",
                 block_size: int = 16, num_blocks: int | None = None,
                 prefill_chunk: int = 16, max_pending: int = 0,
                 decode_fn=None, prefill_fn=None, mesh=None):
        if cache not in ("paged", "slot"):
            raise ValueError(f"cache={cache!r} (want 'paged' or 'slot')")
        self.model = model
        self.sparams = sparams
        self.cache_kind = cache
        # mesh != None places the KV pool over the mesh's data axes
        # (repro.dist sharding hook) — decode updates stay shard-local
        if cache == "paged":
            self.pool = PagedCachePool(model, num_slots, max_len,
                                       block_size=block_size,
                                       num_blocks=num_blocks, mesh=mesh)
            self._prefill = prefill_fn or make_chunked_prefill(model)
            self.prefill_chunk = prefill_chunk
        else:
            self.pool = SlotCachePool(model, num_slots, max_len, mesh=mesh)
            self._prefill = prefill_fn or make_prefill(model)
        self.queue = AdmissionQueue(max_pending)
        self.scheduler = ContinuousScheduler(self.pool, self.queue)
        # decode_fn/prefill_fn let callers share one jit cache across
        # engines (the benchmark warms up on a throwaway engine).  The
        # default decode donates the pool cache — step() immediately
        # replaces it, so XLA updates the KV buffers in place
        self._decode = decode_fn or make_decode_step(model, donate=True)
        # attention caches without a sliding window hold exactly max_len
        # tokens; SSM/windowed state is O(1)/O(window) so any length fits
        self._length_bound = (
            max_len if "k" in self.pool.cache
            and model.cfg.sliding_window is None else None)
        self._next_id = 0
        self._step_idx = 0
        self._tokens_total = 0
        self._decode_steps = 0
        self._occupancy_sum = 0.0
        self._block_occupancy_sum = 0.0
        self._run_seconds = 0.0
        self.requests: dict[int, Request] = {}

    @classmethod
    def from_params(cls, model, params, policy: QuantPolicy, **kw):
        """Quantize + bit-pack training params at ``policy`` and serve."""
        from repro.train.serve import quantize_for_serving

        return cls(model, quantize_for_serving(model, params, policy), **kw)

    # ------------------------------------------------------------- frontend
    def submit(self, prompt, max_new_tokens: int,
               sampling: SamplingParams | None = None,
               eos_id: int | None = None) -> int:
        req = Request(self._next_id, np.asarray(prompt), max_new_tokens,
                      sampling or SamplingParams(), eos_id)
        if self._length_bound is not None and req.total_len() > self._length_bound:
            raise ValueError(
                f"request needs {req.total_len()} cache tokens > pool "
                f"max_len {self._length_bound}")
        req.arrival_step = self._step_idx
        self.queue.push(req)  # may raise (backpressure): nothing registered
        self._next_id += 1
        self.requests[req.request_id] = req
        return req.request_id

    @property
    def steps(self) -> int:
        return self._step_idx

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    @property
    def num_running(self) -> int:
        return self.scheduler.num_running

    # ------------------------------------------------------------- prefill
    def _admit_slot(self, req: Request, slot: int):
        """Legacy path: full-prompt prefill at its exact length + splice."""
        logits, cache1 = self._prefill(
            self.sparams, jnp.asarray(req.prompt)[None, :], self.pool.max_len)
        self.pool.write(slot, cache1)
        return req.select_token(np.asarray(logits)[0, -1]), len(req.prompt), True

    def _admit_paged(self, req: Request, seq: int):
        """Chunked prefill straight into the sequence's blocks.  Every
        chunk call has the same shapes — one executable total.  On resume
        after preemption the prompt + emitted tokens are replayed (exact
        recompute) and no new token is emitted."""
        replay = req.replay_tokens()
        C = self.prefill_chunk
        logits, valid = None, 0
        for lo in range(0, len(replay), C):
            piece = replay[lo:lo + C]
            valid = len(piece)
            buf = np.zeros((1, C), np.int32)
            buf[0, :valid] = piece
            logits, cache = self._prefill(
                self.sparams, self.pool.step_cache(), jnp.asarray(buf),
                seq, lo, valid)
            self.pool.accept(cache)
        if req.output_tokens:  # resume: last emitted token is the next feed
            return req.output_tokens[-1], len(replay), False
        return req.select_token(np.asarray(logits)[0, 0]), len(replay), True

    # ----------------------------------------------------------------- loop
    def step(self) -> dict:
        """One engine iteration.  Returns the step's events:
        ``{"admitted": [ids], "tokens": [(id, tok)], "finished": [ids],
        "preempted": [ids]}``.
        """
        t0 = time.perf_counter()
        events = {"admitted": [], "tokens": [], "finished": [],
                  "preempted": []}

        # 1) admit queued requests into free rows (mid-decode is fine:
        #    running sequences are untouched, their blocks never move)
        for req, slot in self.scheduler.admissions():
            if self.cache_kind == "paged":
                tok, cached, emitted = self._admit_paged(req, slot)
            else:
                tok, cached, emitted = self._admit_slot(req, slot)
            if emitted:
                self._emit(req, tok, events)
            events["admitted"].append(req.request_id)
            self.scheduler.start(req, slot, tok, cached_len=cached)
            if req.done:  # 1-token budget (or instant EOS): row back now
                self._finish(self.scheduler.finish(slot), events)

        # 2) reserve next-token blocks; exhaustion preempts youngest
        if self.cache_kind == "paged":
            for req in self.scheduler.reserve_for_decode():
                events["preempted"].append(req.request_id)

        # 3) one packed decode step over every running row
        if self.scheduler.running:
            self._occupancy_sum += self.pool.occupancy()
            if self.cache_kind == "paged":
                self._block_occupancy_sum += self.pool.block_occupancy()
            self._decode_steps += 1
            toks = np.zeros((self.pool.num_slots, 1), np.int32)
            for slot, seq in self.scheduler.running.items():
                toks[slot, 0] = seq.last_token
            logits, cache = self._decode(
                self.sparams, self.pool.step_cache(), jnp.asarray(toks))
            self.pool.accept(cache)
            rows = np.asarray(logits[:, -1])  # (num_slots, V)
            for slot, seq in list(self.scheduler.running.items()):
                tok = seq.request.select_token(rows[slot])
                self._emit(seq.request, tok, events)
                if seq.request.done:
                    self._finish(self.scheduler.finish(slot), events)
                else:
                    self.scheduler.advance(slot, tok)

        self._step_idx += 1
        self._run_seconds += time.perf_counter() - t0
        return events

    def run_until_drained(self, max_steps: int = 100_000) -> dict:
        steps = 0
        while self.scheduler.has_work():
            if steps >= max_steps:
                raise RuntimeError(f"not drained after {max_steps} steps")
            self.step()
            steps += 1
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def _emit(self, req: Request, tok: int, events: dict) -> None:
        if not req.output_tokens:
            req.first_token_time = time.perf_counter()
            req.first_token_step = self._step_idx
        req.output_tokens.append(tok)
        self._tokens_total += 1
        events["tokens"].append((req.request_id, tok))

    def _finish(self, req: Request, events: dict) -> None:
        req.finish_time = time.perf_counter()
        events["finished"].append(req.request_id)

    def metrics(self) -> dict:
        per_request = []
        for req in self.requests.values():
            per_request.append({
                "id": req.request_id,
                "state": req.state.value,
                "prompt_len": int(req.prompt.size),
                "new_tokens": len(req.output_tokens),
                "preemptions": req.preemptions,
                "ttft_s": req.ttft(),
                "ttft_steps": (None if req.first_token_step is None
                               else req.first_token_step - req.arrival_step),
                "latency_s": (None if req.finish_time is None
                              else req.finish_time - req.arrival_time),
            })
        occ = (self._occupancy_sum / self._decode_steps
               if self._decode_steps else 0.0)
        out = {
            "steps": self._step_idx,
            "decode_steps": self._decode_steps,
            "tokens_total": self._tokens_total,
            "tokens_per_s": (self._tokens_total / self._run_seconds
                             if self._run_seconds > 0 else 0.0),
            "mean_occupancy": occ,
            "num_slots": self.pool.num_slots,
            "cache": self.cache_kind,
            "preemptions": self.scheduler.preemptions,
            "requests": per_request,
        }
        if self.cache_kind == "paged":
            out["mean_block_occupancy"] = (
                self._block_occupancy_sum / self._decode_steps
                if self._decode_steps else 0.0)
            out["block_size"] = self.pool.block_size
            out["num_blocks"] = self.pool.num_blocks
        return out

    def output(self, request_id: int) -> list[int]:
        return list(self.requests[request_id].output_tokens)
