"""ServeEngine: the top-level continuous-batching serve loop.

``submit()`` enqueues a request; ``step()`` runs one engine iteration
(admit -> prefill new sequences -> one packed decode step over every
running row); ``run_until_drained()`` steps until queue and rows are
empty.  Weights stay bit-packed (``quant.pack``) at a ReLeQ
``QuantPolicy`` for the whole lifetime of the engine — quantization cost
is paid once at construction, not per request.

Two cache backends (``cache=`` / ``launch/serve.py --cache``):

- ``"paged"`` (default): block-granular ``PagedCachePool``.  Admission
  runs *fixed-shape chunked prefill* directly into the sequence's blocks
  — any mix of prompt lengths compiles exactly ONE prefill executable and
  ONE decode executable (the slot path compiles a prefill per distinct
  prompt length).  Before each decode the scheduler reserves one token of
  growth per running sequence; block exhaustion preempts-and-requeues the
  youngest sequence, whose re-admission replays prompt + emitted tokens
  (deterministic greedy decode ⇒ the client-visible stream is unchanged).
- ``"slot"``: the legacy slot pool (full-prompt prefill + splice), kept
  one release as the parity baseline.

Prefix caching (paged, ``prefix_cache=True`` default): admission consults
the pool's refcounted trie (serve/cache.py) and maps a shared prompt
prefix into the new sequence's block table with increfs — the chunked
prefill then runs only over the tail, so N tenants sharing a system
prompt prefill it once.  Divergent writes copy-on-write, the admission
gate counts new blocks only (higher admitted concurrency at equal cache
bytes), and a cache-hit sequence is token-identical to a cold one
(parity-gated in tests/test_prefix_cache.py).  Disabled automatically
for ring/recurrent families where paged KV is not the whole state.

Numerics: the decode step is row-independent (per-sequence attention/SSM
state, drop-free MoE routing in decode), so a request's tokens are
bit-identical whether it shares the batch with 0 or ``num_slots - 1``
other sequences — and the paged decode gathers each sequence's pages into
exactly the contiguous rows the slot pool stores, which is what pins
paged-vs-slot token parity (tests/test_serve_paged.py).

Speculative decoding (``spec=SpecConfig(...)``, paged cache only): each
step the engine rolls up to ``spec.k`` tokens per row with a *quantized
self-draft* — the same packed weights re-packed at fewer bitplanes
(``repro.spec``), reading and writing the SAME ``PagedCachePool`` blocks
through the row's block table, so speculation allocates zero extra KV —
then scores all ``k + 1`` positions of every row in ONE batched
``verify_chunk`` call and resolves each window with the
distribution-exact rejection sampler (``repro.spec.sampler``).  Greedy
spec output is token-identical to non-spec decode; sampled output is
exactly target-distributed.  EOS / ``max_new_tokens`` can land anywhere
inside a window (multi-token emission per step).

One-token hotpath (``sample_device=True`` / ``pipeline=True``, both
default): token selection runs ON DEVICE (``serve/sampler.py``) so each
decode step fetches a ``(num_slots,) int32`` token vector instead of the
``(num_slots, V)`` logits matrix, and the sampled vector is itself the
next step's input — ``last_token`` lives in a device-resident buffer.
On top of that sits a ONE-STEP-LOOKAHEAD pipeline.  Timeline, one row::

    synchronous (host sampling, pre-PR-9):
        [dispatch t][--device t--][fetch (B,V)][sample/bookkeep t] ->
        [dispatch t+1][--device t+1--][fetch][sample/bookkeep t+1] ...
        host work sits on the critical path every step.

    pipelined (device sampling + lookahead):
        [dispatch t][dispatch t+1][fetch tokens t][bookkeep t]
                     (device runs t, then t+1, back to back)
        step t+1 is dispatched BEFORE step t's tokens are fetched, so
        the fetch + Python bookkeeping of step t overlap step t+1's
        device compute.  Steady-state host work is off the critical
        path; ``decode.device`` (the blocking token fetch) absorbs the
        wait and ``decode.host`` shrinks toward zero.

    The lookahead only launches when the next step is *composition-
    stable*: nothing queued to admit, every running request has budget
    for one more token after this step, and the scheduler can reserve
    the extra write position without preempting
    (``scheduler.reserve_lookahead``).  Any other step falls back to
    the synchronous order and counts ``pipeline.bubbles``.  Arrivals
    are never delayed by an in-flight step: ``step()`` admits BEFORE
    syncing it (admission touches only free rows and free blocks), and
    the composition change just bubbles that step's chain.  A realized
    EOS inside a lookahead only invalidates that row's phantom token
    (decode is row-independent): the token is discarded at sync, the
    phantom KV write at ``cached_len`` lands in a block that is never
    full (so never published to the prefix trie) and is fully rewritten
    by the next occupant's prefill before it is read.  Escape hatches:
    ``--host-sampling`` / ``--no-pipeline`` on ``launch/serve.py``.

Metrics: per-request TTFT (seconds *and* engine steps), wall latency,
token counts and preemptions, plus aggregate tokens/s, p50/p99 per-step
decode latency, mean row occupancy, (paged) mean block occupancy, and
(spec) windows/proposed/accepted counts with the acceptance rate.

Observability (``repro.obs``): every aggregate above lives in a typed
instrument on the engine's metrics :class:`~repro.obs.Registry`
(``registry=`` to share one across engines; ``engine.obs.snapshot()``
is the JSON view) — ``metrics()`` is rebuilt on the registry with
byte-compatible keys and the same ``metrics_window`` sliding-window
percentile semantics.  A :class:`~repro.obs.Tracer` (``tracer=``)
records the full request lifecycle as Chrome-trace spans: queue wait
(retro-dated to enqueue), admission with prefix hit/replay counts,
every prefill chunk, each decode step split into **device time**
(dispatch + logits fetch) vs **host overhead** (sampling/bookkeeping),
speculative draft/verify/fix-up phases with per-window acceptance,
preempt instants, pool COW/eviction/flush instants, and an
``xla.compile`` instant whenever a jit cache grows (``_cache_size``
delta — steady state must show zero).  Disabled tracing costs one
attribute check per call site (<= 3%% tokens/s, gated in
``benchmarks/serve_bench.py``).  See ``docs/metrics.md``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.obs import Registry
from repro.obs.trace import NULL_TRACER
from repro.quant.policy import QuantPolicy
from repro.serve.cache import PagedCachePool, SlotCachePool
from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, SamplingParams
from repro.serve.sampler import row_arrays, sample_rows
from repro.serve.scheduler import ContinuousScheduler
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_prefill,
    make_verify_chunk,
)


@dataclass
class _Inflight:
    """One dispatched-but-unsynced decode step: the sampled token vector
    (a ``(num_slots,) int32`` device array, possibly still computing),
    the emission positions it was sampled at, and a snapshot of the rows
    it covered (identity-checked at sync — a row that turned over since
    dispatch carried a phantom token, which is discarded)."""

    tokens: object        # (num_slots,) int32 device array
    positions: object     # (num_slots,) int32 device array
    rows: dict            # slot -> RunningSeq at dispatch time


class ServeEngine:
    def __init__(self, model, sparams, *, num_slots: int = 8,
                 max_len: int = 256, cache: str = "paged",
                 block_size: int = 16, num_blocks: int | None = None,
                 prefill_chunk: int = 16, max_pending: int = 0,
                 decode_fn=None, prefill_fn=None, mesh=None,
                 spec=None, verify_fn=None, kv_bits=None,
                 kv_oracle: bool = False, metrics_window: int = 512,
                 prefix_cache: bool = True, registry=None, tracer=None,
                 sample_device: bool = True, pipeline: bool = True):
        if cache not in ("paged", "slot"):
            raise ValueError(f"cache={cache!r} (want 'paged' or 'slot')")
        if (kv_bits is not None or kv_oracle) and cache != "paged":
            raise ValueError("kv_bits / kv_oracle require cache='paged' "
                             "(the slot pool stores fp KV only)")
        if metrics_window < 1:
            raise ValueError("metrics_window must be >= 1")
        self.model = model
        self.sparams = sparams
        self.cache_kind = cache
        # observability: a private registry/disabled tracer by default —
        # pass shared ones to aggregate across engines or record a trace
        self.obs = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # mesh != None places the KV pool over the mesh's data axes
        # (repro.dist sharding hook) — decode updates stay shard-local
        if cache == "paged":
            self.pool = PagedCachePool(model, num_slots, max_len,
                                       block_size=block_size,
                                       num_blocks=num_blocks, mesh=mesh,
                                       kv_bits=kv_bits, kv_oracle=kv_oracle,
                                       prefix_cache=prefix_cache)
            self._prefill = prefill_fn or make_chunked_prefill(model)
            self.prefill_chunk = prefill_chunk
        else:
            self.pool = SlotCachePool(model, num_slots, max_len, mesh=mesh)
            self._prefill = prefill_fn or make_prefill(model)
        self.pool.tracer = self.tracer  # COW / eviction / flush instants
        self.queue = AdmissionQueue(max_pending)
        self.scheduler = ContinuousScheduler(self.pool, self.queue,
                                             registry=self.obs)
        # decode_fn/prefill_fn let callers share one jit cache across
        # engines (the benchmark warms up on a throwaway engine).  The
        # default decode donates the pool cache — step() immediately
        # replaces it, so XLA updates the KV buffers in place
        self._decode = decode_fn or make_decode_step(model, donate=True)
        # per-sequence token bound now lives on the pool (None for
        # recurrent/ring state, where any length fits)
        self._length_bound = self.pool.length_bound
        # speculative decoding: draft = the target's own packed weights at
        # a lower-bit policy, sharing this pool's blocks (repro.spec)
        self.spec = spec
        if spec is not None:
            if cache != "paged":
                raise ValueError("speculative decoding requires "
                                 "cache='paged'")
            self._verify = verify_fn or make_verify_chunk(model)
            self._draft_sparams = self._resolve_draft(spec)
        # one-token hotpath: device-side sampling feeds a device-resident
        # token buffer; the lookahead pipeline additionally dispatches
        # step t+1 before syncing step t (device sampling only — the
        # pipeline's whole point is not fetching logits, and spec already
        # amortizes host work over k+1 tokens per step)
        self._sample_device = bool(sample_device)
        self._pipeline_on = bool(pipeline and sample_device
                                 and spec is None)
        self._inflight: _Inflight | None = None
        self._row_sig = None      # batch-composition key for _row_params
        self._row_dev = None      # cached device sampling-param arrays
        self._next_id = 0
        self._step_idx = 0
        # every aggregate lives on the registry; ``metrics()`` reads the
        # instruments back with byte-compatible keys.  Latency series are
        # windowed histograms — bounded host memory on a long-lived
        # engine, and percentiles are a sliding window over the last
        # ``metrics_window`` decode steps, identical to the full history
        # on runs shorter than the window (the old deque semantics)
        obs = self.obs
        TOK = (1, 4, 16, 64, 256, 1024, 4096)   # token-count boundaries
        self._c_tokens = obs.counter("serve.tokens_total", unit="tokens")
        self._c_decode_steps = obs.counter("serve.decode_steps", unit="steps")
        self._c_run_seconds = obs.counter("serve.run_seconds", unit="s")
        self._c_occ_sum = obs.counter("serve.occupancy_sum")
        self._c_block_occ_sum = obs.counter("serve.block_occupancy_sum")
        self._c_prefill_launches = obs.counter("serve.prefill_launches")
        self._c_recompiles = obs.counter(
            "serve.recompiles", desc="jit cache growth after construction")
        self._h_decode = obs.histogram("serve.decode_step_seconds", unit="s",
                                       window=metrics_window)
        self._h_decode_tok = obs.histogram("serve.decode_tok_seconds",
                                           unit="s", window=metrics_window)
        self._h_device = obs.histogram("serve.decode_device_seconds",
                                       unit="s", window=metrics_window)
        self._h_host = obs.histogram("serve.decode_host_seconds", unit="s",
                                     window=metrics_window)
        self._h_queue_wait = obs.histogram("serve.queue_wait_seconds",
                                           unit="s", window=metrics_window)
        # prefix-cache observability, same bounded-window discipline:
        # (hit, replay) token pairs per admission -> windowed hit rate
        # (appended together, so the two windows stay aligned); a
        # per-step sample of the pool's shared-block gauge -> window mean
        self._h_admit_hit = obs.histogram("prefix.admit_hit_tokens",
                                          unit="tokens", buckets=TOK,
                                          window=metrics_window)
        self._h_admit_total = obs.histogram("prefix.admit_replay_tokens",
                                            unit="tokens", buckets=TOK,
                                            window=metrics_window)
        self._h_shared = obs.histogram("prefix.blocks_shared", unit="blocks",
                                       buckets=TOK, window=metrics_window)
        self._g_queue = obs.gauge("serve.queue_depth", unit="requests")
        self._g_running = obs.gauge("serve.running_rows", unit="rows")
        self._c_spec_windows = obs.counter("spec.windows")
        self._c_spec_proposed = obs.counter("spec.proposed", unit="tokens")
        self._c_spec_accepted = obs.counter("spec.accepted", unit="tokens")
        # hotpath observability: lookahead dispatches vs bubbles (steps
        # that fell back to the synchronous order while the pipeline was
        # on), and spec steps that fell back to the full-logits host
        # resolve because not every window was greedy
        self._c_lookahead = obs.counter(
            "pipeline.lookahead", unit="steps",
            desc="decode steps dispatched before the previous sync")
        self._c_bubbles = obs.counter(
            "pipeline.bubbles", unit="steps",
            desc="pipeline-on steps that ran synchronously")
        self._c_fallbacks = obs.counter(
            "sampler.fallbacks", unit="steps",
            desc="device-sampling steps resolved via host logits fetch")
        # device time inside the current step, accumulated by the decode/
        # spec paths and split out of the step wall time by ``step()``
        self._device_seconds = 0.0
        # jit-cache baselines for compile/recompile detection (a shared
        # pre-warmed fn starts above zero; only *growth* is an event)
        self._exec_sizes: dict[str, int] = {}
        for kind, fn in (("prefill", self._prefill), ("decode", self._decode),
                         ("verify", getattr(self, "_verify", None)),
                         ("sample", sample_rows if sample_device else None)):
            size_fn = getattr(fn, "_cache_size", None)
            if size_fn is not None:
                self._exec_sizes[kind] = size_fn()
        self.requests: dict[int, Request] = {}

    @classmethod
    def from_params(cls, model, params, policy: QuantPolicy, **kw):
        """Quantize + bit-pack training params at ``policy`` and serve."""
        from repro.train.serve import quantize_for_serving

        return cls(model, quantize_for_serving(model, params, policy), **kw)

    def _resolve_draft(self, spec):
        """SpecConfig -> draft serving params (most-specific source wins:
        pre-packed sparams > per-group policy > uniform draft_bits)."""
        if spec.draft_sparams is not None:
            return spec.draft_sparams
        from repro.spec.draft import low_bit_view

        return low_bit_view(self.model, self.sparams,
                            bits=spec.draft_bits, policy=spec.draft_policy)

    # ------------------------------------------------------------- frontend
    def submit(self, prompt, max_new_tokens: int,
               sampling: SamplingParams | None = None,
               eos_id: int | None = None) -> int:
        req = Request(self._next_id, np.asarray(prompt), max_new_tokens,
                      sampling or SamplingParams(), eos_id)
        if self._length_bound is not None and req.total_len() > self._length_bound:
            raise ValueError(
                f"request needs {req.total_len()} cache tokens > pool "
                f"max_len {self._length_bound}")
        req.arrival_step = self._step_idx
        self.queue.push(req)  # may raise (backpressure): nothing registered
        self._next_id += 1
        self.requests[req.request_id] = req
        return req.request_id

    @property
    def steps(self) -> int:
        return self._step_idx

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    @property
    def num_running(self) -> int:
        return self.scheduler.num_running

    def _note_exec(self, kind: str, fn) -> None:
        """Emit an ``xla.compile`` instant + counter bump when a jit cache
        grew past its last observed size — steady-state serving must show
        zero of these after warmup (acceptance-gated in serve_bench)."""
        size_fn = getattr(fn, "_cache_size", None)
        if size_fn is None:
            return
        sz = size_fn()
        prev = self._exec_sizes.get(kind, 0)
        if sz > prev:
            self._exec_sizes[kind] = sz
            self._c_recompiles.inc(sz - prev)
            self.tracer.instant("xla.compile", kind=kind, cache_size=sz,
                                step=self._step_idx)

    # ------------------------------------------------------------- prefill
    def _admit_slot(self, req: Request, slot: int):
        """Legacy path: full-prompt prefill at its exact length + splice."""
        with self.tracer.span("prefill.full", request=req.request_id,
                              tokens=len(req.prompt)):
            logits, cache1 = self._prefill(
                self.sparams, jnp.asarray(req.prompt)[None, :],
                self.pool.max_len)
            self.pool.write(slot, cache1)
        self._note_exec("prefill", self._prefill)
        self._c_prefill_launches.inc()
        return req.select_token(np.asarray(logits)[0, -1]), len(req.prompt), True

    def _admit_paged(self, req: Request, seq: int, hit: int = 0):
        """Chunked prefill straight into the sequence's blocks.  Every
        chunk call has the same shapes — one executable total (``start``
        is data, so a prefix-cache tail starting mid-prompt reuses it
        too).  ``hit`` tokens were already mapped from the prefix trie by
        the scheduler (``pool.map_shared``): only the tail is prefilled,
        beginning at the shared boundary — block-aligned, or one token
        shy of it when the whole prompt hit and the last block was COW'd
        at admission (the tail token's logits seed sampling).  On resume
        after preemption the prompt + emitted tokens are replayed (exact
        recompute, minus whatever the trie still holds) and no new token
        is emitted."""
        replay = req.replay_tokens()
        C = self.prefill_chunk
        logits = None
        for lo in range(hit, len(replay), C):
            piece = replay[lo:lo + C]
            valid = len(piece)
            buf = np.zeros((1, C), np.int32)
            buf[0, :valid] = piece
            with self.tracer.span("prefill.chunk", seq=seq, start=lo,
                                  valid=valid, request=req.request_id):
                logits, cache = self._prefill(
                    self.sparams, self.pool.step_cache(), jnp.asarray(buf),
                    seq, lo, valid)
                self.pool.accept(cache)
            self._note_exec("prefill", self._prefill)
            self._c_prefill_launches.inc()
        # the whole replay is now fed: record it so completed blocks
        # publish into the trie for the next tenant
        self.pool.record_tokens(seq, replay)
        req.prefix_cached_tokens += hit
        self._h_admit_hit.observe(hit)
        self._h_admit_total.observe(len(replay))
        if req.output_tokens:  # resume: last emitted token is the next feed
            return req.output_tokens[-1], len(replay), False
        return req.select_token(np.asarray(logits)[0, 0]), len(replay), True

    # ----------------------------------------------------------------- loop
    def step(self) -> dict:
        """One engine iteration.  Returns the step's events:
        ``{"admitted": [ids], "tokens": [(id, tok)], "finished": [ids],
        "preempted": [ids]}``.
        """
        t0 = time.perf_counter()
        tr = self.tracer
        events = {"admitted": [], "tokens": [], "finished": [],
                  "preempted": []}

        # 0) a lookahead decode dispatched by the PREVIOUS step is this
        #    step's decode — it is synced below AFTER admissions.  A
        #    fully-stale inflight (every dispatched row finished at the
        #    last sync) is dropped without a fetch: its writes went to
        #    blocks that are rewritten before any read.
        inf = self._inflight
        self._inflight = None
        if inf is not None and not any(
                self.scheduler.running.get(s) is q
                for s, q in inf.rows.items()):
            inf = None

        # 1) admit queued requests into free rows (mid-decode is fine:
        #    running sequences are untouched, their blocks never move.
        #    An in-flight lookahead is no different — it reads and writes
        #    only blocks owned by the rows it was dispatched over, never
        #    the free/cached blocks admission draws from — so arrivals
        #    since its dispatch are admitted NOW, not one step late;
        #    _pipeline_tail sees the composition change and bubbles
        #    instead of chaining the newcomer a garbage feed)
        for req, slot, hit in self.scheduler.admissions():
            wait = time.perf_counter() - req.queued_time
            self._h_queue_wait.observe(wait)
            tr.complete("queue.wait", start=req.queued_time, dur=wait,
                        request=req.request_id,
                        requeued=req.preemptions > 0)
            with tr.span("admit", request=req.request_id, seq=slot,
                         prefix_hit_tokens=hit) as sp:
                if self.cache_kind == "paged":
                    tok, cached, emitted = self._admit_paged(req, slot, hit)
                else:
                    tok, cached, emitted = self._admit_slot(req, slot)
                sp.set(replay_tokens=cached,
                       new_tokens=cached - hit)
            if emitted:
                self._emit(req, tok, events)
            events["admitted"].append(req.request_id)
            self.scheduler.start(req, slot, tok, cached_len=cached)
            if req.done:  # 1-token budget (or instant EOS): row back now
                self._finish(self.scheduler.finish(slot), events)

        if inf is not None:
            # 2/3 pipelined) the in-flight lookahead IS this step's
            #    decode: its write positions were reserved at dispatch,
            #    so no reserve_for_decode — chain-or-bubble, then sync
            self._timed_decode(
                events, tr, lambda ev: self._pipeline_tail(inf, ev),
                mode="pipelined")
        else:
            # 2) reserve next-token blocks; exhaustion preempts youngest
            #    (spec mode reserves per-window inside _spec_step instead)
            if self.cache_kind == "paged" and self.spec is None:
                for req in self.scheduler.reserve_for_decode():
                    events["preempted"].append(req.request_id)
                    tr.instant("preempt", request=req.request_id,
                               step=self._step_idx)

            # 3) one packed decode step (or speculative window) over every
            #    running row
            if self.scheduler.running:
                self._timed_decode(events, tr, self._sync_body,
                                   mode="spec" if self.spec is not None
                                   else "decode")

        self._step_idx += 1
        self._g_queue.set(len(self.queue))
        self._g_running.set(self.scheduler.num_running)
        self._c_run_seconds.inc(time.perf_counter() - t0)
        return events

    def _timed_decode(self, events: dict, tr, body, mode: str) -> None:
        """Run one decode body under the ``decode.step`` span with the
        occupancy counters and the device/host wall-time split.
        Attribution (documented in docs/metrics.md): ``_device_seconds``
        is time spent DRIVING OR AWAITING the device — jit dispatch
        (``decode.dispatch`` span; a near-zero enqueue on async backends,
        the compute itself on synchronous ones) plus the blocking
        token-vector fetch (``decode.device`` span) — and
        ``decode.host`` is the rest of the step wall time: the Python
        serving loop (sampling on the legacy path, emit/advance
        bookkeeping, table uploads).  The pipelined loop times dispatch
        and sync separately, so the next step's dispatch is never folded
        into the current step's fetch wait."""
        self._c_occ_sum.inc(self.pool.occupancy())
        if self.cache_kind == "paged":
            self._c_block_occ_sum.inc(self.pool.block_occupancy())
            if self.pool.prefix_cache:
                self._h_shared.observe(self.pool.blocks_shared)
        self._c_decode_steps.inc()
        self._device_seconds = 0.0
        t_dec = time.perf_counter()
        n_tok = len(events["tokens"])
        with tr.span("decode.step", step=self._step_idx,
                     rows=len(self.scheduler.running), mode=mode) as sp:
            body(events)
            emitted = len(events["tokens"]) - n_tok
            sp.set(tokens=emitted)
        dt = time.perf_counter() - t_dec
        self._h_decode.observe(dt)
        if emitted > 0:  # an all-stale sync can emit 0; see metrics()
            self._h_decode_tok.observe(dt / emitted)
        self._h_device.observe(self._device_seconds)
        self._h_host.observe(max(dt - self._device_seconds, 0.0))

    def _sync_body(self, events: dict) -> None:
        """Decode body for a step with no pipelined predecessor."""
        if self.spec is not None:
            self._spec_step(events)
        elif self._sample_device:
            self._pipeline_tail(self._dispatch_decode(), events)
        else:
            self._decode_once(events)

    # ------------------------------------------------------ device hotpath
    def _row_params(self):
        """Device-resident per-row sampling parameters, re-uploaded only
        when the batch composition changes (slot -> request mapping)."""
        sched = self.scheduler
        sig = tuple(sorted((s, q.request.request_id)
                           for s, q in sched.running.items()))
        if sig != self._row_sig:
            arrs = row_arrays(self.pool.num_slots,
                              ((s, q.request)
                               for s, q in sched.running.items()))
            self._row_dev = tuple(jnp.asarray(a) for a in arrs)
            self._row_sig = sig
        return self._row_dev

    def _dispatch_decode(self, toks_dev=None, positions=None) -> _Inflight:
        """Dispatch one packed decode + fused on-device sampling WITHOUT
        blocking: the returned handle's ``tokens`` is a ``(num_slots,)``
        int32 device array that may still be computing.  The synchronous
        head builds the feed from host ``last_token``s; a chained
        (lookahead) dispatch feeds the previous step's device token
        vector straight back in — zero host round-trip."""
        sched = self.scheduler
        if toks_dev is None:
            toks = np.zeros((self.pool.num_slots, 1), np.int32)
            pos = np.zeros((self.pool.num_slots,), np.int32)
            for slot, seq in sched.running.items():
                toks[slot, 0] = seq.last_token
                pos[slot] = len(seq.request.output_tokens)
            toks_dev, positions = jnp.asarray(toks), jnp.asarray(pos)
        t_dev = time.perf_counter()
        with self.tracer.span("decode.dispatch", rows=len(sched.running)):
            logits, cache = self._decode(
                self.sparams, self.pool.step_cache(), toks_dev)
            self.pool.accept(cache)
            tokens = sample_rows(logits[:, -1], *self._row_params(),
                                 positions)
        # dispatch counts as device time: on an async backend it is a
        # near-zero enqueue, on a synchronous one (CPU) it IS the compute
        # — either way it is time driving the device, not serving-loop
        # Python (see _timed_decode for the full attribution schema)
        self._device_seconds += time.perf_counter() - t_dev
        self._note_exec("decode", self._decode)
        self._note_exec("sample", sample_rows)
        return _Inflight(tokens, positions, dict(sched.running))

    def _sync_inflight(self, inf: _Inflight, events: dict) -> None:
        """Block on the in-flight token vector, then emit/advance.  Rows
        whose sequence turned over since dispatch (finished at the last
        sync while the lookahead was already running) carried a phantom
        token, which is discarded here."""
        t_dev = time.perf_counter()
        with self.tracer.span("decode.device", rows=len(inf.rows)):
            toks = np.asarray(inf.tokens)  # blocks until compute lands
        self._device_seconds += time.perf_counter() - t_dev
        with self.tracer.span("decode.host"):
            for slot, seq in inf.rows.items():
                if self.scheduler.running.get(slot) is not seq:
                    continue
                tok = int(toks[slot])
                self._emit(seq.request, tok, events)
                if seq.request.done:
                    self._finish(self.scheduler.finish(slot), events)
                else:
                    self.scheduler.advance(slot, tok)

    def _pipeline_tail(self, inf: _Inflight, events: dict) -> None:
        """Dispatch the NEXT step's decode (when safe) BEFORE syncing the
        current one — the blocking fetch + Python bookkeeping below then
        overlap the device's next step.  Ineligible steps fall back to
        plain sync order and count ``pipeline.bubbles``.

        Chaining feeds ``inf.tokens`` back in for EVERY slot, so it is
        only valid while the running composition is exactly the rows the
        in-flight step was dispatched over — a row admitted since (step()
        admits before this sync) has no token in that vector and must
        wait for the next synchronous head."""
        nxt = None
        same_rows = (len(self.scheduler.running) == len(inf.rows) and all(
            inf.rows.get(s) is q for s, q in self.scheduler.running.items()))
        if self._pipeline_on:
            if same_rows and self._lookahead_ok():
                nxt = self._dispatch_decode(inf.tokens[:, None],
                                            inf.positions + 1)
                self._c_lookahead.inc()
            else:
                self._c_bubbles.inc()
        self._sync_inflight(inf, events)
        self._inflight = nxt

    def _lookahead_ok(self) -> bool:
        """Can step t+1 be dispatched before step t's tokens land?
        Requires: nothing queued to admit, every running request with
        budget for at least one more token after this step (an EOS can
        still land — that row's phantom token is discarded at sync), and
        a non-preempting reservation of the t+1 write position."""
        if len(self.queue):
            return False
        for seq in self.scheduler.running.values():
            req = seq.request
            if len(req.output_tokens) + 2 > req.max_new_tokens:
                return False
        return self.scheduler.reserve_lookahead()

    def _decode_sync(self, events: dict) -> None:
        """One synchronous decode step, no lookahead (the spec path's
        ``max_k == 0`` fallback).  Device sampling only when every row is
        greedy — there device and host draws are bitwise-identical, so the
        fallback composes with spec windows.  Any sampled row must draw
        from the HOST streams (``Request.rng_for``): window size depends
        on pool pressure i.e. batch composition, and a ``k == 0`` window
        emitting from the device threefry stream while a ``k > 0`` window
        emits the same position from the numpy stream would break the
        windowing-invariance contract."""
        if self._sample_device and all(
                seq.request.sampling.temperature <= 0.0
                for seq in self.scheduler.running.values()):
            self._sync_inflight(self._dispatch_decode(), events)
        else:
            self._decode_once(events)

    def _decode_once(self, events: dict) -> None:
        """One packed single-token decode over every running row, host
        sampling (``sample_device=False`` — the bisectable legacy path).
        Here ``_device_seconds`` keeps the pre-pipeline semantics:
        dispatch + the blocking (num_slots, V) logits fetch."""
        toks = np.zeros((self.pool.num_slots, 1), np.int32)
        for slot, seq in self.scheduler.running.items():
            toks[slot, 0] = seq.last_token
        t_dev = time.perf_counter()
        with self.tracer.span("decode.device",
                              rows=len(self.scheduler.running)):
            logits, cache = self._decode(
                self.sparams, self.pool.step_cache(), jnp.asarray(toks))
            self.pool.accept(cache)
            rows = np.asarray(logits[:, -1])  # (num_slots, V) — blocks here
        self._device_seconds += time.perf_counter() - t_dev
        self._note_exec("decode", self._decode)
        with self.tracer.span("decode.host"):
            for slot, seq in list(self.scheduler.running.items()):
                tok = seq.request.select_token(rows[slot])
                self._emit(seq.request, tok, events)
                if seq.request.done:
                    self._finish(self.scheduler.finish(slot), events)
                else:
                    self.scheduler.advance(slot, tok)

    # ------------------------------------------------------------ spec path
    def _spec_step(self, events: dict) -> None:
        """One speculative window: draft-roll k tokens per row with the
        low-bit self-draft, verify all k + 1 positions of every row in ONE
        batched chunk call, resolve by exact rejection sampling, emit.

        Cache discipline (the no-extra-KV contract): the draft reads and
        writes the SAME pool blocks through each row's block table; rows
        not drafting a given depth have their block-table row pointed at
        the garbage block for that call, so no live block is ever touched
        on their behalf.  Recurrent (non-paged) state is snapshotted
        before the draft roll and restored for the verifier, whose
        padding-masked chunk pass recomputes it exactly; a rejection
        triggers one fix-up verify at the accepted length (same shapes —
        same executable).  ``length`` is host-authoritative and rewritten
        after emission, so rejected positions' stale KV sits beyond every
        attention mask until genuinely overwritten.

        Greedy fast path (``sample_device`` and every running request at
        temperature 0 — the parity-critical default): the draft roll
        keeps its token argmaxes on device, and verify/resolve fetches
        only the ``(B, C)`` target-argmax and ``(B, max_k)`` draft
        vectors instead of the ``(B, C, V)`` logits tensor; each window
        resolves with :func:`repro.spec.sampler.greedy_window`
        (bitwise-equal to ``spec_window`` for greedy).  Mixed or sampled
        batches keep the exact rejection sampler on the full logits and
        count ``sampler.fallbacks``.
        """
        from repro.spec.sampler import (
            KIND_DRAFT,
            draft_token,
            greedy_window,
            spec_window,
        )

        pool, sched, spec = self.pool, self.scheduler, self.spec
        B = pool.num_slots
        ring_cap = None
        if pool.paged_keys and self.model.cfg.sliding_window is not None:
            # ring caches: a window must never wrap — a wrapped draft
            # write would clobber live in-window KV that a rejection
            # cannot restore.  Rows near the wrap point fall back to
            # k = 0 (still 1 token/step via the verifier).
            ring_cap = pool.blocks_per_seq * pool.block_size

        want: dict[int, int] = {}
        for slot, seq in sched.running.items():
            req = seq.request
            k = min(spec.k, req.max_new_tokens - len(req.output_tokens) - 1)
            if ring_cap is not None:
                k = min(k, ring_cap - 1 - seq.cached_len)
            want[slot] = max(k, 0)
        granted, preempted = sched.reserve_for_spec(want)
        for req in preempted:
            events["preempted"].append(req.request_id)
            self.tracer.instant("preempt", request=req.request_id,
                                step=self._step_idx)
        if not sched.running:
            return
        max_k = max(granted.values())
        if max_k == 0:
            self._decode_sync(events)  # nothing to speculate this step
            return
        greedy_fast = (self._sample_device and
                       all(seq.request.sampling.temperature <= 0.0
                           for seq in sched.running.values()))
        if self._sample_device and not greedy_fast:
            self._c_fallbacks.inc()

        lengths0 = {s: seq.cached_len for s, seq in sched.running.items()}
        # snapshot O(1) recurrent leaves (explicit copies: the decode and
        # verify calls donate the cache dict, invalidating originals)
        snap_keys = [key for key in pool.cache
                     if key not in pool.paged_keys and key != "length"]
        snap = {key: jnp.copy(pool.cache[key]) for key in snap_keys}

        # --- draft roll: k low-bit decode steps through the shared pool
        draft_toks: dict[int, list[int]] = {s: [] for s in granted}
        q_probs: dict[int, list] = {s: [] for s in granted}
        cur = np.zeros((B, 1), np.int32)
        for slot, seq in sched.running.items():
            cur[slot, 0] = seq.last_token
        # greedy-fast roll state: the fed token never leaves the device,
        # and the per-depth draft columns accumulate for ONE batched
        # fetch after the verify dispatch
        if greedy_fast:
            granted_arr = np.zeros((B,), np.int32)
            for slot, k in granted.items():
                granted_arr[slot] = k
            granted_dev = jnp.asarray(granted_arr)
            cur_dev = first_dev = jnp.asarray(cur)
        draft_cols: list = []
        # masked tables are nested (grants only expire as depth grows), so
        # upload one device array per DISTINCT mask, not one per depth —
        # and the common all-rows-full-window mask IS the pool's mirror,
        # already resident
        bt_key, bt_dev = None, None
        with self.tracer.span("spec.draft", max_k=max_k,
                              rows=len(sched.running)):
            for depth in range(1, max_k + 1):
                cache_d = dict(pool.cache)
                bt = pool.block_tables.copy()
                masked = False
                for slot in range(B):
                    if granted.get(slot, 0) < depth:
                        bt[slot] = 0  # garbage sink: row sits this one out
                        masked = masked or pool.block_tables[slot].any()
                key = bt.tobytes()
                # re-upload if the mask changed OR a donating backend ate
                # the previous buffer (CPU ignores donation; accelerators
                # don't)
                if key != bt_key or bt_dev.is_deleted():
                    bt_key = key
                    bt_dev = (jnp.asarray(bt) if masked
                              else pool.block_tables_dev())
                cache_d["block_tables"] = bt_dev
                t_dev = time.perf_counter()
                if greedy_fast:
                    # greedy draft == argmax, taken on device — no fetch;
                    # rows past their window carry their last token
                    logits, cache = self._decode(self._draft_sparams,
                                                 cache_d, cur_dev)
                    pool.accept(cache)
                    nxt = jnp.argmax(logits[:, -1], axis=-1)
                    col = jnp.where(granted_dev >= depth,
                                    nxt.astype(jnp.int32), cur_dev[:, 0])
                    cur_dev = col[:, None]
                    draft_cols.append(col)
                    self._device_seconds += time.perf_counter() - t_dev
                    continue
                logits, cache = self._decode(self._draft_sparams, cache_d,
                                             jnp.asarray(cur))
                pool.accept(cache)
                rows = np.asarray(logits[:, -1])
                self._device_seconds += time.perf_counter() - t_dev
                for slot, seq in sched.running.items():
                    if granted[slot] < depth:
                        continue
                    req = seq.request
                    pos = len(req.output_tokens) + depth - 1
                    tok, q = draft_token(rows[slot], req.sampling,
                                         req.rng_for(pos, KIND_DRAFT))
                    draft_toks[slot].append(tok)
                    q_probs[slot].append(q)
                    cur[slot, 0] = tok
        self._note_exec("decode", self._decode)

        # --- verify: ONE batched fixed-shape chunk over every pool row.
        # Width is always spec.k + 1 (short windows pad with valid < C),
        # so every step reuses one executable.
        C = spec.k + 1
        ver_toks = np.zeros((B, C), np.int32)
        starts = np.zeros((B,), np.int32)
        valids = np.zeros((B,), np.int32)
        for slot, seq in sched.running.items():
            k = granted[slot]
            ver_toks[slot, 0] = seq.last_token
            if not greedy_fast:
                ver_toks[slot, 1:1 + k] = draft_toks[slot]
            starts[slot] = lengths0[slot]
            valids[slot] = k + 1
        if greedy_fast:
            # the feed stays on device: [last, draft_1..draft_max_k],
            # padded to the fixed verify width (the tail beyond a row's
            # window is masked by ``valids`` — its values are never read)
            body = jnp.concatenate(
                [first_dev] + [c[:, None] for c in draft_cols], axis=1)
            ver_toks_dev = jnp.pad(body, ((0, 0), (0, C - body.shape[1])))
        else:
            ver_toks_dev = jnp.asarray(ver_toks)
        bt_full = pool.block_tables_dev()  # mirror, shared with the fix-up
        cache_v = dict(pool.cache)
        for key in snap_keys:  # keep `snap` alive for a possible fix-up
            cache_v[key] = jnp.copy(snap[key])
        cache_v["block_tables"] = bt_full
        starts_dev = jnp.asarray(starts)
        target = tops = drafts = None
        t_dev = time.perf_counter()
        with self.tracer.span("spec.verify", rows=len(sched.running),
                              width=C):
            logits, cache = self._verify(
                self.sparams, cache_v, ver_toks_dev, starts_dev,
                jnp.asarray(valids))
            pool.accept(cache)
            if greedy_fast:
                # fetch per-position target argmaxes + the draft columns
                # — (B, C) + (B, max_k) int32, not (B, C, V) float32
                tops = np.asarray(jnp.argmax(logits, axis=-1)
                                  .astype(jnp.int32))
                drafts = np.asarray(jnp.stack(draft_cols, axis=1))
                ver_toks[:, 1:1 + max_k] = drafts  # host copy for fix-up
            else:
                target = np.asarray(logits)  # (B, C, V) float32
        self._device_seconds += time.perf_counter() - t_dev
        self._note_exec("verify", self._verify)

        # --- resolve each window on the host: greedy argmax comparison
        # on the fast path, exact rejection sampling otherwise
        emitted_by_slot: dict[int, list[int]] = {}
        with self.tracer.span("spec.resolve") as sp_res:
            proposed = accepted_total = 0
            for slot, seq in sched.running.items():
                req = seq.request
                k = granted[slot]
                if greedy_fast:
                    emitted, accepted = greedy_window(drafts[slot, :k],
                                                      tops[slot])
                else:
                    emitted, accepted = spec_window(
                        draft_toks[slot], target[slot, :k + 1],
                        req.sampling, req.rng_for,
                        base_pos=len(req.output_tokens),
                        q_probs=q_probs[slot])
                emitted_by_slot[slot] = emitted
                self._c_spec_windows.inc()
                proposed += k
                accepted_total += accepted
            self._c_spec_proposed.inc(proposed)
            self._c_spec_accepted.inc(accepted_total)
            sp_res.set(proposed=proposed, accepted=accepted_total)

        # --- recurrent fix-up: a rejection means the verifier advanced
        # wkv/SSM state through tokens that were never emitted; re-run the
        # same chunk at the accepted lengths (identical prefix => exact)
        if snap and any(len(emitted_by_slot[s]) < int(valids[s])
                        for s in emitted_by_slot):
            valids2 = np.zeros((B,), np.int32)
            for slot in emitted_by_slot:
                valids2[slot] = len(emitted_by_slot[slot])
            cache_f = dict(pool.cache)
            for key in snap_keys:
                cache_f[key] = snap[key]
            # the mirror re-uploads itself if a donating verify consumed
            # the buffer (CPU ignores donation; accelerators don't)
            cache_f["block_tables"] = pool.block_tables_dev()
            if ver_toks_dev.is_deleted():
                ver_toks_dev, starts_dev = (jnp.asarray(ver_toks),
                                            jnp.asarray(starts))
            t_dev = time.perf_counter()
            with self.tracer.span("spec.fixup"):
                _, cache = self._verify(
                    self.sparams, cache_f, ver_toks_dev, starts_dev,
                    jnp.asarray(valids2))
                pool.accept(cache)
            self._device_seconds += time.perf_counter() - t_dev

        # --- emit (EOS / budget can land mid-window), then restore the
        # host-authoritative lengths: the verifier wrote start + valid
        lengths1 = np.zeros((B,), np.int32)
        for slot, seq in list(sched.running.items()):
            req = seq.request
            finished = False
            for tok in emitted_by_slot[slot]:
                self._emit(req, tok, events)
                if req.done:
                    self._finish(sched.finish(slot), events)
                    finished = True
                    break
                sched.advance(slot, tok)
            if not finished:
                lengths1[slot] = seq.cached_len
        pool.cache["length"] = jnp.asarray(lengths1)

    def run_until_drained(self, max_steps: int = 100_000) -> dict:
        steps = 0
        while self.scheduler.has_work():
            if steps >= max_steps:
                raise RuntimeError(f"not drained after {max_steps} steps")
            self.step()
            steps += 1
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def _emit(self, req: Request, tok: int, events: dict) -> None:
        if not req.output_tokens:
            req.first_token_time = time.perf_counter()
            req.first_token_step = self._step_idx
        req.output_tokens.append(tok)
        self._c_tokens.inc()
        events["tokens"].append((req.request_id, tok))

    def _finish(self, req: Request, events: dict) -> None:
        req.finish_time = time.perf_counter()
        events["finished"].append(req.request_id)

    def metrics(self) -> dict:
        per_request = []
        for req in self.requests.values():
            per_request.append({
                "id": req.request_id,
                "state": req.state.value,
                "prompt_len": int(req.prompt.size),
                "new_tokens": len(req.output_tokens),
                "preemptions": req.preemptions,
                "ttft_s": req.ttft(),
                "ttft_steps": (None if req.first_token_step is None
                               else req.first_token_step - req.arrival_step),
                "latency_s": (None if req.finish_time is None
                              else req.finish_time - req.arrival_time),
                "prefix_cached_tokens": req.prefix_cached_tokens,
            })
        # every aggregate below reads the registry instruments — keys are
        # byte-compatible with the pre-registry dict, windowed series keep
        # the exact ``metrics_window`` percentile semantics (Histogram
        # windows reproduce np.percentile over the last N samples)
        decode_steps = int(self._c_decode_steps.value)
        tokens_total = int(self._c_tokens.value)
        run_seconds = self._c_run_seconds.value
        occ = (self._c_occ_sum.value / decode_steps
               if decode_steps else 0.0)
        out = {
            "steps": self._step_idx,
            "decode_steps": decode_steps,
            "tokens_total": tokens_total,
            "tokens_per_s": (tokens_total / run_seconds
                             if run_seconds > 0 else 0.0),
            "mean_occupancy": occ,
            "num_slots": self.pool.num_slots,
            "cache": self.cache_kind,
            "preemptions": self.scheduler.preemptions,
            "recompiles": int(self._c_recompiles.value),
            "requests": per_request,
            # one-token hotpath counters (docs/metrics.md): lookahead =
            # steps whose decode was dispatched before the previous sync;
            # bubbles = pipeline-on steps that ran synchronously;
            # fallbacks = device-sampling steps resolved via a host
            # logits fetch (non-greedy speculative windows)
            "sampler": {
                "device": self._sample_device,
                "fallbacks": int(self._c_fallbacks.value),
            },
            "pipeline": {
                "enabled": self._pipeline_on,
                "lookahead_steps": int(self._c_lookahead.value),
                "bubbles": int(self._c_bubbles.value),
            },
        }
        if self._h_decode.count:
            out["decode_step_p50_ms"] = self._h_decode.percentile(50) * 1e3
            out["decode_step_p99_ms"] = self._h_decode.percentile(99) * 1e3
            # device/host attribution of the same steps (spans carry the
            # per-step values; these are the windowed medians)
            out["decode_device_p50_ms"] = self._h_device.percentile(50) * 1e3
            out["decode_host_p50_ms"] = self._h_host.percentile(50) * 1e3
            if self._h_decode_tok.count:  # step cost / tokens delivered
                out["decode_tok_p50_ms"] = (
                    self._h_decode_tok.percentile(50) * 1e3)
        if self._h_queue_wait.count:
            out["queue_wait_p50_ms"] = self._h_queue_wait.percentile(50) * 1e3
        if self.cache_kind == "paged":
            out["mean_block_occupancy"] = (
                self._c_block_occ_sum.value / decode_steps
                if decode_steps else 0.0)
            out["block_size"] = self.pool.block_size
            out["num_blocks"] = self.pool.num_blocks
            out["prefill_launches"] = int(self._c_prefill_launches.value)
            # windowed (metrics_window-bounded, like the latency deques):
            # hit rate over the last admissions, shared-block gauge mean
            # over the last decode steps.  ``prefix_hit_rate`` is that
            # *windowed token ratio*; the unambiguous raw lifetime
            # counters ride alongside as prefix_hits / prefix_lookups
            total = self._h_admit_total.window_sum()
            out["prefix_hit_rate"] = (
                self._h_admit_hit.window_sum() / total if total else 0.0)
            out["prefix_hits"] = self.pool.prefix_hits
            out["prefix_lookups"] = self.pool.prefix_lookups
            out["blocks_shared"] = self._h_shared.window_mean()
            out["prefix_cache"] = {
                "enabled": self.pool.prefix_cache,
                "lookups": self.pool.prefix_lookups,
                "hits": self.pool.prefix_hits,
                "hit_tokens": self.pool.prefix_hit_tokens,
                "cow_copies": self.pool.cow_copies,
                "evictions": self.pool.prefix_evictions,
                "cached_blocks": self.pool.prefix_cached_blocks,
            }
            if self.pool.kv_bits is not None:
                out["kv_bits"] = list(self.pool.kv_bits)
                out["kv_oracle"] = self.pool.kv_oracle
        if self.spec is not None:
            windows = int(self._c_spec_windows.value)
            proposed = int(self._c_spec_proposed.value)
            accepted = int(self._c_spec_accepted.value)
            out["spec"] = {
                "k": self.spec.k,
                "windows": windows,
                "proposed": proposed,
                "accepted": accepted,
                "acceptance_rate": (accepted / proposed
                                    if proposed else 0.0),
            }
        return out

    def output(self, request_id: int) -> list[int]:
        return list(self.requests[request_id].output_tokens)
