"""ServeEngine: the top-level continuous-batching serve loop.

``submit()`` enqueues a request; ``step()`` runs one engine iteration
(admit -> prefill new sequences -> one packed decode step over every
running row); ``run_until_drained()`` steps until queue and rows are
empty.  Weights stay bit-packed (``quant.pack``) at a ReLeQ
``QuantPolicy`` for the whole lifetime of the engine — quantization cost
is paid once at construction, not per request.

Two cache backends (``cache=`` / ``launch/serve.py --cache``):

- ``"paged"`` (default): block-granular ``PagedCachePool``.  Admission
  runs *fixed-shape chunked prefill* directly into the sequence's blocks
  — any mix of prompt lengths compiles exactly ONE prefill executable and
  ONE decode executable (the slot path compiles a prefill per distinct
  prompt length).  Before each decode the scheduler reserves one token of
  growth per running sequence; block exhaustion preempts-and-requeues the
  youngest sequence, whose re-admission replays prompt + emitted tokens
  (deterministic greedy decode ⇒ the client-visible stream is unchanged).
- ``"slot"``: the legacy slot pool (full-prompt prefill + splice), kept
  one release as the parity baseline.

Prefix caching (paged, ``prefix_cache=True`` default): admission consults
the pool's refcounted trie (serve/cache.py) and maps a shared prompt
prefix into the new sequence's block table with increfs — the chunked
prefill then runs only over the tail, so N tenants sharing a system
prompt prefill it once.  Divergent writes copy-on-write, the admission
gate counts new blocks only (higher admitted concurrency at equal cache
bytes), and a cache-hit sequence is token-identical to a cold one
(parity-gated in tests/test_prefix_cache.py).  Disabled automatically
for ring/recurrent families where paged KV is not the whole state.

Numerics: the decode step is row-independent (per-sequence attention/SSM
state, drop-free MoE routing in decode), so a request's tokens are
bit-identical whether it shares the batch with 0 or ``num_slots - 1``
other sequences — and the paged decode gathers each sequence's pages into
exactly the contiguous rows the slot pool stores, which is what pins
paged-vs-slot token parity (tests/test_serve_paged.py).

Speculative decoding (``spec=SpecConfig(...)``, paged cache only): each
step the engine rolls up to ``spec.k`` tokens per row with a *quantized
self-draft* — the same packed weights re-packed at fewer bitplanes
(``repro.spec``), reading and writing the SAME ``PagedCachePool`` blocks
through the row's block table, so speculation allocates zero extra KV —
then scores all ``k + 1`` positions of every row in ONE batched
``verify_chunk`` call and resolves each window with the
distribution-exact rejection sampler (``repro.spec.sampler``).  Greedy
spec output is token-identical to non-spec decode; sampled output is
exactly target-distributed.  EOS / ``max_new_tokens`` can land anywhere
inside a window (multi-token emission per step).

Metrics: per-request TTFT (seconds *and* engine steps), wall latency,
token counts and preemptions, plus aggregate tokens/s, p50/p99 per-step
decode latency, mean row occupancy, (paged) mean block occupancy, and
(spec) windows/proposed/accepted counts with the acceptance rate.
"""
from __future__ import annotations

import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.quant.policy import QuantPolicy
from repro.serve.cache import PagedCachePool, SlotCachePool
from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, SamplingParams
from repro.serve.scheduler import ContinuousScheduler
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_prefill,
    make_verify_chunk,
)


class ServeEngine:
    def __init__(self, model, sparams, *, num_slots: int = 8,
                 max_len: int = 256, cache: str = "paged",
                 block_size: int = 16, num_blocks: int | None = None,
                 prefill_chunk: int = 16, max_pending: int = 0,
                 decode_fn=None, prefill_fn=None, mesh=None,
                 spec=None, verify_fn=None, kv_bits=None,
                 kv_oracle: bool = False, metrics_window: int = 512,
                 prefix_cache: bool = True):
        if cache not in ("paged", "slot"):
            raise ValueError(f"cache={cache!r} (want 'paged' or 'slot')")
        if (kv_bits is not None or kv_oracle) and cache != "paged":
            raise ValueError("kv_bits / kv_oracle require cache='paged' "
                             "(the slot pool stores fp KV only)")
        if metrics_window < 1:
            raise ValueError("metrics_window must be >= 1")
        self.model = model
        self.sparams = sparams
        self.cache_kind = cache
        # mesh != None places the KV pool over the mesh's data axes
        # (repro.dist sharding hook) — decode updates stay shard-local
        if cache == "paged":
            self.pool = PagedCachePool(model, num_slots, max_len,
                                       block_size=block_size,
                                       num_blocks=num_blocks, mesh=mesh,
                                       kv_bits=kv_bits, kv_oracle=kv_oracle,
                                       prefix_cache=prefix_cache)
            self._prefill = prefill_fn or make_chunked_prefill(model)
            self.prefill_chunk = prefill_chunk
        else:
            self.pool = SlotCachePool(model, num_slots, max_len, mesh=mesh)
            self._prefill = prefill_fn or make_prefill(model)
        self.queue = AdmissionQueue(max_pending)
        self.scheduler = ContinuousScheduler(self.pool, self.queue)
        # decode_fn/prefill_fn let callers share one jit cache across
        # engines (the benchmark warms up on a throwaway engine).  The
        # default decode donates the pool cache — step() immediately
        # replaces it, so XLA updates the KV buffers in place
        self._decode = decode_fn or make_decode_step(model, donate=True)
        # per-sequence token bound now lives on the pool (None for
        # recurrent/ring state, where any length fits)
        self._length_bound = self.pool.length_bound
        # speculative decoding: draft = the target's own packed weights at
        # a lower-bit policy, sharing this pool's blocks (repro.spec)
        self.spec = spec
        if spec is not None:
            if cache != "paged":
                raise ValueError("speculative decoding requires "
                                 "cache='paged'")
            self._verify = verify_fn or make_verify_chunk(model)
            self._draft_sparams = self._resolve_draft(spec)
        self._next_id = 0
        self._step_idx = 0
        self._tokens_total = 0
        self._decode_steps = 0
        self._occupancy_sum = 0.0
        self._block_occupancy_sum = 0.0
        self._run_seconds = 0.0
        # per-step latency samples for the percentile metrics: bounded ring
        # buffers (a long-lived engine must not grow host memory without
        # bound; the percentiles become a sliding window over the last
        # ``metrics_window`` decode steps, identical to the full history
        # on runs shorter than the window)
        self._decode_seconds: deque[float] = deque(maxlen=metrics_window)
        self._decode_tokens: deque[int] = deque(maxlen=metrics_window)
        # prefix-cache observability, same bounded-window discipline:
        # (cached, replay) per admission -> windowed hit rate; a per-step
        # sample of the pool's shared-block gauge -> windowed mean
        self._prefill_launches = 0
        self._prefix_admit: deque[tuple[int, int]] = deque(
            maxlen=metrics_window)
        self._shared_samples: deque[int] = deque(maxlen=metrics_window)
        self._spec_windows = 0
        self._spec_proposed = 0
        self._spec_accepted = 0
        self.requests: dict[int, Request] = {}

    @classmethod
    def from_params(cls, model, params, policy: QuantPolicy, **kw):
        """Quantize + bit-pack training params at ``policy`` and serve."""
        from repro.train.serve import quantize_for_serving

        return cls(model, quantize_for_serving(model, params, policy), **kw)

    def _resolve_draft(self, spec):
        """SpecConfig -> draft serving params (most-specific source wins:
        pre-packed sparams > per-group policy > uniform draft_bits)."""
        if spec.draft_sparams is not None:
            return spec.draft_sparams
        from repro.spec.draft import low_bit_view

        return low_bit_view(self.model, self.sparams,
                            bits=spec.draft_bits, policy=spec.draft_policy)

    # ------------------------------------------------------------- frontend
    def submit(self, prompt, max_new_tokens: int,
               sampling: SamplingParams | None = None,
               eos_id: int | None = None) -> int:
        req = Request(self._next_id, np.asarray(prompt), max_new_tokens,
                      sampling or SamplingParams(), eos_id)
        if self._length_bound is not None and req.total_len() > self._length_bound:
            raise ValueError(
                f"request needs {req.total_len()} cache tokens > pool "
                f"max_len {self._length_bound}")
        req.arrival_step = self._step_idx
        self.queue.push(req)  # may raise (backpressure): nothing registered
        self._next_id += 1
        self.requests[req.request_id] = req
        return req.request_id

    @property
    def steps(self) -> int:
        return self._step_idx

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    @property
    def num_running(self) -> int:
        return self.scheduler.num_running

    # ------------------------------------------------------------- prefill
    def _admit_slot(self, req: Request, slot: int):
        """Legacy path: full-prompt prefill at its exact length + splice."""
        logits, cache1 = self._prefill(
            self.sparams, jnp.asarray(req.prompt)[None, :], self.pool.max_len)
        self.pool.write(slot, cache1)
        self._prefill_launches += 1
        return req.select_token(np.asarray(logits)[0, -1]), len(req.prompt), True

    def _admit_paged(self, req: Request, seq: int, hit: int = 0):
        """Chunked prefill straight into the sequence's blocks.  Every
        chunk call has the same shapes — one executable total (``start``
        is data, so a prefix-cache tail starting mid-prompt reuses it
        too).  ``hit`` tokens were already mapped from the prefix trie by
        the scheduler (``pool.map_shared``): only the tail is prefilled,
        beginning at the shared boundary — block-aligned, or one token
        shy of it when the whole prompt hit and the last block was COW'd
        at admission (the tail token's logits seed sampling).  On resume
        after preemption the prompt + emitted tokens are replayed (exact
        recompute, minus whatever the trie still holds) and no new token
        is emitted."""
        replay = req.replay_tokens()
        C = self.prefill_chunk
        logits = None
        for lo in range(hit, len(replay), C):
            piece = replay[lo:lo + C]
            valid = len(piece)
            buf = np.zeros((1, C), np.int32)
            buf[0, :valid] = piece
            logits, cache = self._prefill(
                self.sparams, self.pool.step_cache(), jnp.asarray(buf),
                seq, lo, valid)
            self.pool.accept(cache)
            self._prefill_launches += 1
        # the whole replay is now fed: record it so completed blocks
        # publish into the trie for the next tenant
        self.pool.record_tokens(seq, replay)
        req.prefix_cached_tokens += hit
        self._prefix_admit.append((hit, len(replay)))
        if req.output_tokens:  # resume: last emitted token is the next feed
            return req.output_tokens[-1], len(replay), False
        return req.select_token(np.asarray(logits)[0, 0]), len(replay), True

    # ----------------------------------------------------------------- loop
    def step(self) -> dict:
        """One engine iteration.  Returns the step's events:
        ``{"admitted": [ids], "tokens": [(id, tok)], "finished": [ids],
        "preempted": [ids]}``.
        """
        t0 = time.perf_counter()
        events = {"admitted": [], "tokens": [], "finished": [],
                  "preempted": []}

        # 1) admit queued requests into free rows (mid-decode is fine:
        #    running sequences are untouched, their blocks never move)
        for req, slot, hit in self.scheduler.admissions():
            if self.cache_kind == "paged":
                tok, cached, emitted = self._admit_paged(req, slot, hit)
            else:
                tok, cached, emitted = self._admit_slot(req, slot)
            if emitted:
                self._emit(req, tok, events)
            events["admitted"].append(req.request_id)
            self.scheduler.start(req, slot, tok, cached_len=cached)
            if req.done:  # 1-token budget (or instant EOS): row back now
                self._finish(self.scheduler.finish(slot), events)

        # 2) reserve next-token blocks; exhaustion preempts youngest
        #    (spec mode reserves per-window inside _spec_step instead)
        if self.cache_kind == "paged" and self.spec is None:
            for req in self.scheduler.reserve_for_decode():
                events["preempted"].append(req.request_id)

        # 3) one packed decode step (or speculative window) over every
        #    running row
        if self.scheduler.running:
            self._occupancy_sum += self.pool.occupancy()
            if self.cache_kind == "paged":
                self._block_occupancy_sum += self.pool.block_occupancy()
                if self.pool.prefix_cache:
                    self._shared_samples.append(self.pool.blocks_shared)
            self._decode_steps += 1
            t_dec = time.perf_counter()
            n_tok = len(events["tokens"])
            if self.spec is not None:
                self._spec_step(events)
            else:
                self._decode_once(events)
            self._decode_seconds.append(time.perf_counter() - t_dec)
            self._decode_tokens.append(len(events["tokens"]) - n_tok)

        self._step_idx += 1
        self._run_seconds += time.perf_counter() - t0
        return events

    def _decode_once(self, events: dict) -> None:
        """One packed single-token decode over every running row."""
        toks = np.zeros((self.pool.num_slots, 1), np.int32)
        for slot, seq in self.scheduler.running.items():
            toks[slot, 0] = seq.last_token
        logits, cache = self._decode(
            self.sparams, self.pool.step_cache(), jnp.asarray(toks))
        self.pool.accept(cache)
        rows = np.asarray(logits[:, -1])  # (num_slots, V)
        for slot, seq in list(self.scheduler.running.items()):
            tok = seq.request.select_token(rows[slot])
            self._emit(seq.request, tok, events)
            if seq.request.done:
                self._finish(self.scheduler.finish(slot), events)
            else:
                self.scheduler.advance(slot, tok)

    # ------------------------------------------------------------ spec path
    def _spec_step(self, events: dict) -> None:
        """One speculative window: draft-roll k tokens per row with the
        low-bit self-draft, verify all k + 1 positions of every row in ONE
        batched chunk call, resolve by exact rejection sampling, emit.

        Cache discipline (the no-extra-KV contract): the draft reads and
        writes the SAME pool blocks through each row's block table; rows
        not drafting a given depth have their block-table row pointed at
        the garbage block for that call, so no live block is ever touched
        on their behalf.  Recurrent (non-paged) state is snapshotted
        before the draft roll and restored for the verifier, whose
        padding-masked chunk pass recomputes it exactly; a rejection
        triggers one fix-up verify at the accepted length (same shapes —
        same executable).  ``length`` is host-authoritative and rewritten
        after emission, so rejected positions' stale KV sits beyond every
        attention mask until genuinely overwritten.
        """
        from repro.spec.sampler import KIND_DRAFT, draft_token, spec_window

        pool, sched, spec = self.pool, self.scheduler, self.spec
        B = pool.num_slots
        ring_cap = None
        if pool.paged_keys and self.model.cfg.sliding_window is not None:
            # ring caches: a window must never wrap — a wrapped draft
            # write would clobber live in-window KV that a rejection
            # cannot restore.  Rows near the wrap point fall back to
            # k = 0 (still 1 token/step via the verifier).
            ring_cap = pool.blocks_per_seq * pool.block_size

        want: dict[int, int] = {}
        for slot, seq in sched.running.items():
            req = seq.request
            k = min(spec.k, req.max_new_tokens - len(req.output_tokens) - 1)
            if ring_cap is not None:
                k = min(k, ring_cap - 1 - seq.cached_len)
            want[slot] = max(k, 0)
        granted, preempted = sched.reserve_for_spec(want)
        for req in preempted:
            events["preempted"].append(req.request_id)
        if not sched.running:
            return
        max_k = max(granted.values())
        if max_k == 0:
            self._decode_once(events)  # nothing to speculate this step
            return

        lengths0 = {s: seq.cached_len for s, seq in sched.running.items()}
        # snapshot O(1) recurrent leaves (explicit copies: the decode and
        # verify calls donate the cache dict, invalidating originals)
        snap_keys = [key for key in pool.cache
                     if key not in pool.paged_keys and key != "length"]
        snap = {key: jnp.copy(pool.cache[key]) for key in snap_keys}

        # --- draft roll: k low-bit decode steps through the shared pool
        draft_toks: dict[int, list[int]] = {s: [] for s in granted}
        q_probs: dict[int, list] = {s: [] for s in granted}
        cur = np.zeros((B, 1), np.int32)
        for slot, seq in sched.running.items():
            cur[slot, 0] = seq.last_token
        # masked tables are nested (grants only expire as depth grows), so
        # upload one device array per DISTINCT mask, not one per depth —
        # in the common all-rows-full-window case that is a single upload
        bt_key, bt_dev = None, None
        for depth in range(1, max_k + 1):
            cache_d = dict(pool.cache)
            bt = pool.block_tables.copy()
            for slot in range(B):
                if granted.get(slot, 0) < depth:
                    bt[slot] = 0  # garbage sink: this row sits this one out
            key = bt.tobytes()
            # re-upload if the mask changed OR a donating backend consumed
            # the previous buffer (CPU ignores donation; accelerators don't)
            if key != bt_key or bt_dev.is_deleted():
                bt_key, bt_dev = key, jnp.asarray(bt)
            cache_d["block_tables"] = bt_dev
            logits, cache = self._decode(self._draft_sparams, cache_d,
                                         jnp.asarray(cur))
            pool.accept(cache)
            rows = np.asarray(logits[:, -1])
            for slot, seq in sched.running.items():
                if granted[slot] < depth:
                    continue
                req = seq.request
                pos = len(req.output_tokens) + depth - 1
                tok, q = draft_token(rows[slot], req.sampling,
                                     req.rng_for(pos, KIND_DRAFT))
                draft_toks[slot].append(tok)
                q_probs[slot].append(q)
                cur[slot, 0] = tok

        # --- verify: ONE batched fixed-shape chunk over every pool row.
        # Width is always spec.k + 1 (short windows pad with valid < C),
        # so every step reuses one executable.
        C = spec.k + 1
        ver_toks = np.zeros((B, C), np.int32)
        starts = np.zeros((B,), np.int32)
        valids = np.zeros((B,), np.int32)
        for slot, seq in sched.running.items():
            k = granted[slot]
            ver_toks[slot, 0] = seq.last_token
            ver_toks[slot, 1:1 + k] = draft_toks[slot]
            starts[slot] = lengths0[slot]
            valids[slot] = k + 1
        bt_full = jnp.asarray(pool.block_tables)  # shared with the fix-up
        cache_v = dict(pool.cache)
        for key in snap_keys:  # keep `snap` alive for a possible fix-up
            cache_v[key] = jnp.copy(snap[key])
        cache_v["block_tables"] = bt_full
        ver_toks_dev, starts_dev = jnp.asarray(ver_toks), jnp.asarray(starts)
        logits, cache = self._verify(
            self.sparams, cache_v, ver_toks_dev, starts_dev,
            jnp.asarray(valids))
        pool.accept(cache)
        target = np.asarray(logits)  # (B, C, V) float32

        # --- resolve each window on the host (exact rejection sampling)
        emitted_by_slot: dict[int, list[int]] = {}
        for slot, seq in sched.running.items():
            req = seq.request
            k = granted[slot]
            emitted, accepted = spec_window(
                draft_toks[slot], target[slot, :k + 1], req.sampling,
                req.rng_for, base_pos=len(req.output_tokens),
                q_probs=q_probs[slot])
            emitted_by_slot[slot] = emitted
            self._spec_windows += 1
            self._spec_proposed += k
            self._spec_accepted += accepted

        # --- recurrent fix-up: a rejection means the verifier advanced
        # wkv/SSM state through tokens that were never emitted; re-run the
        # same chunk at the accepted lengths (identical prefix => exact)
        if snap and any(len(emitted_by_slot[s]) < int(valids[s])
                        for s in emitted_by_slot):
            valids2 = np.zeros((B,), np.int32)
            for slot in emitted_by_slot:
                valids2[slot] = len(emitted_by_slot[slot])
            cache_f = dict(pool.cache)
            for key in snap_keys:
                cache_f[key] = snap[key]
            # a donating verify consumed the first call's inputs
            cache_f["block_tables"] = (jnp.asarray(pool.block_tables)
                                       if bt_full.is_deleted() else bt_full)
            if ver_toks_dev.is_deleted():
                ver_toks_dev, starts_dev = (jnp.asarray(ver_toks),
                                            jnp.asarray(starts))
            _, cache = self._verify(
                self.sparams, cache_f, ver_toks_dev, starts_dev,
                jnp.asarray(valids2))
            pool.accept(cache)

        # --- emit (EOS / budget can land mid-window), then restore the
        # host-authoritative lengths: the verifier wrote start + valid
        lengths1 = np.zeros((B,), np.int32)
        for slot, seq in list(sched.running.items()):
            req = seq.request
            finished = False
            for tok in emitted_by_slot[slot]:
                self._emit(req, tok, events)
                if req.done:
                    self._finish(sched.finish(slot), events)
                    finished = True
                    break
                sched.advance(slot, tok)
            if not finished:
                lengths1[slot] = seq.cached_len
        pool.cache["length"] = jnp.asarray(lengths1)

    def run_until_drained(self, max_steps: int = 100_000) -> dict:
        steps = 0
        while self.scheduler.has_work():
            if steps >= max_steps:
                raise RuntimeError(f"not drained after {max_steps} steps")
            self.step()
            steps += 1
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def _emit(self, req: Request, tok: int, events: dict) -> None:
        if not req.output_tokens:
            req.first_token_time = time.perf_counter()
            req.first_token_step = self._step_idx
        req.output_tokens.append(tok)
        self._tokens_total += 1
        events["tokens"].append((req.request_id, tok))

    def _finish(self, req: Request, events: dict) -> None:
        req.finish_time = time.perf_counter()
        events["finished"].append(req.request_id)

    def metrics(self) -> dict:
        per_request = []
        for req in self.requests.values():
            per_request.append({
                "id": req.request_id,
                "state": req.state.value,
                "prompt_len": int(req.prompt.size),
                "new_tokens": len(req.output_tokens),
                "preemptions": req.preemptions,
                "ttft_s": req.ttft(),
                "ttft_steps": (None if req.first_token_step is None
                               else req.first_token_step - req.arrival_step),
                "latency_s": (None if req.finish_time is None
                              else req.finish_time - req.arrival_time),
                "prefix_cached_tokens": req.prefix_cached_tokens,
            })
        occ = (self._occupancy_sum / self._decode_steps
               if self._decode_steps else 0.0)
        out = {
            "steps": self._step_idx,
            "decode_steps": self._decode_steps,
            "tokens_total": self._tokens_total,
            "tokens_per_s": (self._tokens_total / self._run_seconds
                             if self._run_seconds > 0 else 0.0),
            "mean_occupancy": occ,
            "num_slots": self.pool.num_slots,
            "cache": self.cache_kind,
            "preemptions": self.scheduler.preemptions,
            "requests": per_request,
        }
        if self._decode_seconds:
            ds = np.asarray(self._decode_seconds)
            out["decode_step_p50_ms"] = float(np.percentile(ds, 50) * 1e3)
            out["decode_step_p99_ms"] = float(np.percentile(ds, 99) * 1e3)
            per_tok = [s / t for s, t in zip(self._decode_seconds,
                                            self._decode_tokens) if t > 0]
            if per_tok:  # step cost normalized by what the step delivered
                out["decode_tok_p50_ms"] = float(
                    np.percentile(per_tok, 50) * 1e3)
        if self.cache_kind == "paged":
            out["mean_block_occupancy"] = (
                self._block_occupancy_sum / self._decode_steps
                if self._decode_steps else 0.0)
            out["block_size"] = self.pool.block_size
            out["num_blocks"] = self.pool.num_blocks
            out["prefill_launches"] = self._prefill_launches
            # windowed (metrics_window-bounded, like the latency deques):
            # hit rate over the last admissions, shared-block gauge mean
            # over the last decode steps
            cached = sum(c for c, _ in self._prefix_admit)
            total = sum(t for _, t in self._prefix_admit)
            out["prefix_hit_rate"] = cached / total if total else 0.0
            out["blocks_shared"] = (
                float(np.mean(self._shared_samples))
                if self._shared_samples else 0.0)
            out["prefix_cache"] = {
                "enabled": self.pool.prefix_cache,
                "lookups": self.pool.prefix_lookups,
                "hits": self.pool.prefix_hits,
                "hit_tokens": self.pool.prefix_hit_tokens,
                "cow_copies": self.pool.cow_copies,
                "evictions": self.pool.prefix_evictions,
                "cached_blocks": self.pool.prefix_cached_blocks,
            }
            if self.pool.kv_bits is not None:
                out["kv_bits"] = list(self.pool.kv_bits)
                out["kv_oracle"] = self.pool.kv_oracle
        if self.spec is not None:
            out["spec"] = {
                "k": self.spec.k,
                "windows": self._spec_windows,
                "proposed": self._spec_proposed,
                "accepted": self._spec_accepted,
                "acceptance_rate": (
                    self._spec_accepted / self._spec_proposed
                    if self._spec_proposed else 0.0),
            }
        return out

    def output(self, request_id: int) -> list[int]:
        return list(self.requests[request_id].output_tokens)
