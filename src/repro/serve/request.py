"""Request objects for the continuous-batching engine.

A ``Request`` is one generation job: a prompt, a token budget, and
sampling parameters.  ``max_new_tokens`` counts every emitted token
*including* the one produced from the prefill logits — so a request with
``max_new_tokens = G + 1`` reproduces the legacy static loop's
``--gen G`` output exactly (prefill argmax + G decode steps).

Token selection lives here too (``select_token``): greedy when
``temperature == 0`` (the parity-critical default), otherwise
temperature/top-k sampling from a per-request, per-POSITION deterministic
stream: the generator key folds in (seed, request_id, position, kind), so
the token drawn at output position ``i`` does not depend on batch
composition, scheduling order, or — crucially for the speculative parity
gate — on how many positions a spec window emitted at once.  ``kind``
separates the independent draws speculative decoding makes at one
position (draft proposal, accept/reject uniform, residual draw) from the
baseline token draw.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"        # submitted, waiting for a free slot
    RUNNING = "running"      # prefilled into a slot, decoding
    FINISHED = "finished"    # budget exhausted or EOS emitted


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 -> greedy argmax
    top_k: int = 0             # 0 -> full distribution
    top_p: float = 1.0         # nucleus: smallest prefix with mass >= top_p
    seed: int = 0              # per-request sampling stream


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # (S,) int32 token ids
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    eos_id: int | None = None
    state: RequestState = RequestState.QUEUED
    output_tokens: list[int] = field(default_factory=list)
    # wall-clock metrics (perf_counter seconds)
    arrival_time: float = field(default_factory=time.perf_counter)
    # when the request last entered the queue: arrival, or the most
    # recent preempt-requeue — queue-wait observability measures from
    # here, so a preempted request's second wait is its own sample
    queued_time: float = field(default_factory=time.perf_counter)
    first_token_time: float | None = None
    finish_time: float | None = None
    # engine-step metrics (deterministic; tests key on these)
    arrival_step: int | None = None
    first_token_step: int | None = None
    preemptions: int = 0       # times evicted-and-requeued (paged engine)
    # replay tokens served from the prefix trie instead of prefill,
    # summed over (re-)admissions (paged engine, prefix_cache=True)
    prefix_cached_tokens: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        if len(self.output_tokens) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.output_tokens
                and self.output_tokens[-1] == self.eos_id)

    def total_len(self) -> int:
        """Tokens the slot must hold: prompt + full decode budget."""
        return int(self.prompt.size) + self.max_new_tokens

    def cache_tokens_needed(self) -> int:
        """Cache tokens admission must cover now: the (replayed) prefix
        plus the first decode write.  Grows with emitted tokens so a
        preempted request re-admits with room for its whole replay."""
        return int(self.prompt.size) + max(len(self.output_tokens), 1)

    def replay_tokens(self) -> np.ndarray:
        """Tokens to prefill on (re-)admission: the prompt, plus — after a
        preemption — every emitted token except the last, which becomes
        the next decode input (exactly the pre-preemption state)."""
        if not self.output_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.output_tokens[:-1], np.int32)])

    def ttft(self) -> float | None:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def rng_for(self, position: int, kind: int = 0) -> np.random.Generator:
        """Deterministic stream for one (output position, draw kind).

        Seeded from ``SeedSequence((seed, request_id, position, kind))`` —
        a fresh generator per draw, so the value consumed at a position is
        a pure function of the request identity, independent of batch
        composition or whether the position was reached by plain decode or
        inside a speculative window."""
        return np.random.default_rng(np.random.SeedSequence(
            (self.sampling.seed, self.request_id, position, kind)))

    def select_token(self, logits: np.ndarray) -> int:
        """Pick the next token from a (V,) float32 logits row."""
        return select_token(logits, self.sampling,
                            self.rng_for(len(self.output_tokens)))


def _nucleus_mask(p: np.ndarray, top_p: float) -> np.ndarray:
    """Boolean keep-mask for the smallest stable-sorted prefix of ``p``
    whose mass reaches ``top_p`` — WITHOUT sorting the whole vocab.

    ``np.argpartition`` pulls the top-``m`` candidates in O(V); every
    element >= the m-th value joins the candidate set (ties included, so
    the set is closed under the stable order), and a stable sort of just
    the candidates reproduces the global stable prefix exactly — same
    comparison keys, same original-index tie-breaking, same sequential
    ``cumsum`` partial sums, hence a bitwise-identical mask (regression-
    gated against the full-sort reference in tests/test_sampler_device).
    ``m`` doubles until the candidate mass covers ``top_p``; flat
    distributions degrade to one full sort, peaked ones (the serving
    common case) stop at m = 64."""
    v = p.size
    m = 64
    while m < v:
        top_idx = np.argpartition(-p, m - 1)[:m]
        thresh = p[top_idx].min()
        cand = np.nonzero(p >= thresh)[0]  # tie-complete candidate set
        cand = cand[np.argsort(-p[cand], kind="stable")]
        csum = np.cumsum(p[cand])
        if csum[-1] >= top_p:
            cut = int(np.searchsorted(csum, top_p) + 1)
            mask = np.zeros(v, bool)
            mask[cand[:cut]] = True
            return mask
        m *= 2
    order = np.argsort(-p, kind="stable")
    csum = np.cumsum(p[order])
    cut = int(np.searchsorted(csum, top_p) + 1)
    mask = np.zeros(v, bool)
    mask[order[:cut]] = True
    return mask


def warp_probs(logits: np.ndarray,
               sampling: SamplingParams) -> np.ndarray | None:
    """Logits -> the warped sampling distribution (V,) float64, or ``None``
    for greedy (temperature 0).  Temperature, then top-k, then nucleus —
    the single definition shared by baseline decode and the speculative
    rejection sampler (which must warp draft and target *identically* for
    the accept ratio p/q to be meaningful).  Both truncations use partial
    selection (``np.partition`` / ``np.argpartition``), not a full vocab
    sort — this runs per row per step on the host oracle path."""
    logits = np.asarray(logits, np.float64).reshape(-1)
    if sampling.temperature <= 0.0:
        return None
    z = logits / sampling.temperature
    if sampling.top_k:
        kth = np.partition(z, -sampling.top_k)[-sampling.top_k]
        z = np.where(z < kth, -np.inf, z)
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    if sampling.top_p < 1.0:
        # nucleus: keep the smallest probability-sorted prefix whose mass
        # reaches top_p (the top token always survives), renormalize
        p = np.where(_nucleus_mask(p, sampling.top_p), p, 0.0)
        p /= p.sum()
    return p


def select_token(logits: np.ndarray, sampling: SamplingParams,
                 rng: np.random.Generator) -> int:
    p = warp_probs(logits, sampling)
    if p is None:
        return int(np.argmax(np.asarray(logits, np.float64).reshape(-1)))
    return int(rng.choice(p.size, p=p))
