"""KV-cache pools for continuous batching: paged (block-granular) + slot.

``PagedCachePool`` is the production pool: transformer K/V lives as
fixed-size *blocks* in one ``(L, num_blocks, block_size, KV, hd)`` pool and
each sequence owns an ordered block table into it, so a 6-token sequence
holds one block while its neighbor holds thirty — instead of every
sequence owning a ``max_len``-sized slot.  Admission is gated on *free
blocks*, capacity grows block-by-block as a sequence decodes, and block
exhaustion is an allocation failure the scheduler turns into
preempt-and-requeue (never a crash).  State that is O(1) per sequence
(Mamba ``ssm_*``, RWKV ``wkv``/token-shift, ``length``) keeps slot
semantics behind the same interface — ``models.model.cache_batch_axis``
names the per-sequence axis of each leaf, exactly as for the slot pool.

Physical block 0 is a reserved garbage sink: empty batch rows point their
block tables at it, so the fixed-shape decode step can scatter "writes"
for inactive rows without touching any live sequence's blocks.

Quantized-KV block layout (``kv_bits=...``, paged pool only):

- code leaves ``"k"``/``"v"``: ``(L, num_blocks, block_size, KV, hd)``
  int8 symmetric codes in ``[-qmax, qmax]`` — or, when every layer is
  4-bit, ``(L, num_blocks, block_size, KV, hd//2)`` uint8 with two
  codes nibble-packed per byte (``quant.pack.kv_pack_int4``),
- scale leaves ``"k_scale"``/``"v_scale"``: ``(L, num_blocks,
  block_size, KV)`` float32, one absmax scale per (token, KV-head) —
  written by the same scatter that writes the codes, so a block is
  always internally consistent,
- ``"kv_qmax"``: ``(L,)`` float32 per-layer code ceiling
  ``2^(bits-1) - 1``.  Per-layer bitwidths are DATA, not shape — a
  mixed {8,6,3}-bit grid runs the same decode executable as uniform 8.

``kv_oracle=True`` (requires ``kv_bits``) keeps ``"k"``/``"v"`` as
float32 leaves holding the exact quantize-dequantize values
(``quant.pack.kv_qdq``) with no scale leaves: the dequantized product
``codes · scale`` the quantized path computes is bitwise these stored
floats, so engine token parity against the oracle is an exact-match
gate, not an allclose.  The scale leaves ride in ``paged_keys`` so
speculative decoding's recurrent-state snapshot skips them (they move
with the blocks, not with the O(1) state).

Prefix caching (``prefix_cache=True``, the default where it is sound):
full blocks are deduplicated across sequences.  Every block is
refcounted; a radix/trie index maps *full-block token content* to the
physical block holding its KV, so admission can splice a shared system
prompt into a new sequence's block table with an incref instead of
re-prefilling it.  The trie is per-pool, so (weight-policy, kv-bits) are
implicit key dimensions — one pool serves one packed policy at one KV
layout, and ``flush_prefix_cache()`` (called by ``autotune.deploy.
hot_swap``) drops the index when the weights change.  Block lifecycle::

      alloc_seq/ensure                    record_tokens/record_token
    free ──────────────▶ owned (rc=1) ─────────────▶ owned+published
      ▲                     │                            │   ▲
      │ not published       │ free_seq                   │   │ map_shared
      │                     ▼              free_seq      ▼   │ (incref)
      └───────────────── (returned)       ┌──────▶ shared (rc>1)
      ▲                                   │              │
      │      evict (LRU leaf, rc==0)      │              │ divergent write
    cached (rc=0, in trie) ◀──────────────┘              ▼
      ▲      ▲                                  COW: copy codes+scales
      │      └── free_seq of last owner              to a fresh block,
      └───────── map_shared revives (incref)         decref the shared one

Only refcount-0 blocks are evictable — eviction order is (refcount,
recency): shared/owned blocks (rc ≥ 1) never leave, and among cached
blocks the least-recently-used one with no cached children goes first
(the deepest cached node of any chain qualifies, so eviction never
starves; a victim's still-owned children are orphaned from the root —
lookups then match a shorter prefix, never stale content).  Writes into a
shared block (decode at the block boundary, spec drafts, the one-token
tail of a block-aligned full hit) copy-on-write first: a fresh block is
allocated, code *and* scale leaves are copied bitwise on device, and the
shared block is decref'd — concurrent readers never observe the write.
Recurrent families (Mamba/RWKV state, ring windows) auto-disable the
prefix cache: their per-token state depends on the full history, so
skipping prefill would be wrong, not just stale.

``SlotCachePool`` is the legacy slot-granular pool (one ``max_len`` row
per sequence, admission splices a batch-1 prefill cache in).  Kept for one
release behind ``--cache slot`` as the parity baseline; the paged engine
is pinned token-for-token against it in ``tests/test_serve_paged.py``.

Allocator invariants (both pools, hypothesis-tested):
- an id is returned at most once until freed; double-free raises,
- ``ensure`` never over-allocates and reports exhaustion as ``False``,
- freeing returns every block; pools drain back to their initial state,
- with sharing: one refcount per owning sequence, never negative;
  freeing a shared block decrefs and never touches the free heap; COW
  preserves block contents bitwise (including ``k_scale``/``v_scale``).
"""
from __future__ import annotations

import heapq
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import cache_batch_axis
from repro.obs.trace import NULL_TRACER

PAGED_KEYS = ("k", "v")  # transformer KV pages; everything else is O(1)/seq


def _splice(pool_cache: dict, single_cache: dict, slot) -> dict:
    return {
        key: jax.lax.dynamic_update_slice_in_dim(
            leaf, single_cache[key].astype(leaf.dtype), slot,
            axis=cache_batch_axis(key))
        for key, leaf in pool_cache.items()
    }


# module-level jit: the donated pool cache updates in place, `slot` enters
# as data, and the executable cache is shared across every pool instance
# (a per-instance jit would recompile on each fresh engine)
_splice_jit = jax.jit(_splice, donate_argnums=(0,))


class SlotCachePool:
    """Legacy slot-granular pool: one max_len-sized cache row per sequence."""

    tracer = NULL_TRACER  # engine-assigned trace sink (no slot instants yet)

    def __init__(self, model, num_slots: int, max_len: int, dtype=None,
                 mesh=None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.max_len = max_len
        self.mesh = mesh
        self.cache = model.init_cache(num_slots, max_len, dtype)
        # hard per-sequence token bound (None = unbounded: recurrent or
        # ring state fits any length); admission and engine.submit gate on it
        self.length_bound = (
            max_len if "k" in self.cache
            and getattr(model.cfg, "sliding_window", None) is None else None)
        if mesh is not None:
            # data-axis sharding hook: slots live distributed over the
            # mesh's data axes (dist/sharding.cache_specs gives the slot
            # axis per leaf); splice/decode updates then stay in place on
            # the owning shard.  Decode is row-independent, so a slot's
            # tokens are identical wherever its rows are placed.
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))
        # min-heap: heappop -> lowest id (a sorted range is already a heap)
        self._free = list(range(num_slots))
        self._active: set[int] = set()

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_slots

    def can_admit(self, n_tokens: int, reserve_blocks: int = 0,
                  tokens=None) -> bool:
        """A free slot AND the sequence fitting its max_len-sized row.
        Admitting an over-length sequence would silently wrap/clobber the
        row — length is part of the admission decision, not just slots.
        ``tokens`` (the prefix-cache hint) is accepted for interface
        parity with the paged pool and ignored: slots don't share."""
        if self.length_bound is not None and n_tokens > self.length_bound:
            return False
        return bool(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(f"all {self.num_slots} slots in use")
        slot = heapq.heappop(self._free)
        self._active.add(slot)
        return slot

    alloc_seq = alloc

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Slots are pre-sized to max_len: capacity is always there."""
        return True

    def free(self, slot: int) -> None:
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        self._active.remove(slot)
        heapq.heappush(self._free, slot)  # O(log n); pop stays lowest-id

    free_seq = free

    # ------------------------------------------------------------- cache ops
    def write(self, slot: int, single_cache: dict) -> None:
        """Splice a batch-1 cache (one prefilled sequence) into ``slot``."""
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        if set(single_cache) != set(self.cache):
            raise ValueError(
                f"cache keys {sorted(single_cache)} != pool {sorted(self.cache)}")
        for key, pool_leaf in self.cache.items():
            ax = cache_batch_axis(key)
            want = pool_leaf.shape[:ax] + (1,) + pool_leaf.shape[ax + 1:]
            if tuple(single_cache[key].shape) != want:
                raise ValueError(
                    f"cache[{key!r}] shape {tuple(single_cache[key].shape)} "
                    f"!= {want}")
        self.cache = _splice_jit(self.cache, single_cache, slot)

    def step_cache(self) -> dict:
        return dict(self.cache)

    def accept(self, cache: dict) -> None:
        self.cache = cache

    def cache_bytes(self) -> int:
        """KV-leaf bytes (what the paged pool's equal-bytes claim compares)."""
        return sum(self.cache[k].size * self.cache[k].dtype.itemsize
                   for k in PAGED_KEYS if k in self.cache)


class _PrefixNode:
    """One full block's worth of token content in the prefix trie.

    ``key`` is the tuple of ``block_size`` token ids this block holds,
    ``block`` the physical block storing their KV, ``parent``/``children``
    the radix chain (child key = the *next* full block of tokens), and
    ``last_use`` a monotone tick for LRU eviction among refcount-0 nodes.
    """

    __slots__ = ("key", "parent", "children", "block", "depth", "last_use")

    def __init__(self, key, parent, block, depth):
        self.key = key
        self.parent = parent
        self.children: dict[tuple, _PrefixNode] = {}
        self.block = block
        self.depth = depth
        self.last_use = 0


def _cow_copy(cache, src, dst, keys):
    """Copy one physical block (codes AND scale leaves) src -> dst."""
    out = dict(cache)
    for key in keys:
        leaf = out[key]
        out[key] = leaf.at[:, dst].set(leaf[:, src])
    return out


# module-level jit, same reasoning as _splice_jit: src/dst are data, the
# key tuple is static, and the executable is shared across pool instances.
# Kept separate from the prefill/decode executables so the ONE-prefill +
# ONE-decode pins are untouched by sharing.
_cow_jit = jax.jit(_cow_copy, static_argnames=("keys",), donate_argnums=(0,))


class PagedCachePool:
    """Block-granular KV pool + per-sequence block tables.

    ``num_seqs``  max concurrently-running sequences (decode batch rows).
    ``max_len``   per-sequence token capacity bound (same meaning as the
                  slot pool's); sliding-window archs cap it at the window.
    ``block_size`` tokens per KV block.  For ring (windowed) caches the
                  block size is shrunk to the largest divisor of the ring
                  length so ring arithmetic stays exact.
    ``num_blocks`` physical blocks *including* the reserved garbage block
                  0.  Default allocates full slot-pool capacity
                  (num_seqs × blocks_per_seq + 1); pass less to
                  oversubscribe — that is the point of paging.
    ``kv_bits``   quantize the KV blocks: an int (uniform) or a
                  per-layer sequence of ints in 2..8.  See the module
                  docstring for the block layout.  Uniform 4 selects the
                  nibble-packed uint8 container (half the code bytes).
    ``kv_oracle`` with ``kv_bits``: store the exact QDQ *values* in
                  float32 instead of codes — the token-parity oracle the
                  quantized engine is gated against.
    ``prefix_cache`` share full KV blocks across sequences via the
                  refcounted trie (module docstring).  Auto-disabled for
                  ring windows and recurrent families, where paged KV is
                  not the whole per-token state and skipping prefill
                  would change tokens, not just waste work.
    """

    # trace sink for COW / eviction / flush instants; the engine points
    # this at its tracer (class default stays a shared disabled tracer)
    tracer = NULL_TRACER

    def __init__(self, model, num_seqs: int, max_len: int, *,
                 block_size: int = 16, num_blocks: int | None = None,
                 dtype=None, mesh=None, kv_bits=None, kv_oracle: bool = False,
                 prefix_cache: bool = True):
        if num_seqs < 1:
            raise ValueError("num_seqs must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if kv_oracle and kv_bits is None:
            raise ValueError("kv_oracle requires kv_bits")
        self.num_seqs = self.num_slots = num_seqs  # num_slots: engine compat
        self.max_len = max_len
        self.mesh = mesh
        template = model.init_cache(num_seqs, max_len, dtype)
        self.paged_keys = tuple(k for k in PAGED_KEYS if k in template)
        if kv_bits is not None and not self.paged_keys:
            raise ValueError(
                "kv_bits quantizes paged attention KV blocks; this model "
                "family keeps O(1) recurrent state (nothing paged to "
                "quantize)")
        if kv_bits is not None:
            L = template[self.paged_keys[0]].shape[0]
            bits = ([int(kv_bits)] * L if np.isscalar(kv_bits)
                    else [int(b) for b in kv_bits])
            if len(bits) != L:
                raise ValueError(
                    f"kv_bits has {len(bits)} entries for {L} layers")
            if any(not 2 <= b <= 8 for b in bits):
                raise ValueError(f"kv_bits entries must be in 2..8: {bits}")
            self.kv_bits = bits
        else:
            self.kv_bits = None
        self.kv_oracle = bool(kv_oracle)
        self._ring = (getattr(model.cfg, "sliding_window", None) is not None
                      and bool(self.paged_keys))
        if self.paged_keys:
            T = template[self.paged_keys[0]].shape[2]  # (L, B, T, KV, hd)
            if self._ring:
                # ring arithmetic needs blocks_per_seq · bs == ring length
                bs = min(block_size, T)
                while T % bs:
                    bs -= 1
                self.block_size = bs
            else:
                self.block_size = min(block_size, T)
            self.blocks_per_seq = -(-T // self.block_size)
        else:  # O(1)-state family: pure slot semantics, no blocks at all
            self.block_size = block_size
            self.blocks_per_seq = 0
        usable = (num_blocks - 1 if num_blocks is not None
                  else num_seqs * self.blocks_per_seq)
        if self.blocks_per_seq and usable < self.blocks_per_seq:
            raise ValueError(
                f"num_blocks={num_blocks} leaves {usable} usable blocks < "
                f"{self.blocks_per_seq} needed for a single full sequence")
        self.num_blocks = usable + 1  # + reserved garbage block 0
        if mesh is not None and self.paged_keys:
            # pad the pool to a multiple of the data-axis device count so
            # cache_specs' divisibility guard shards the block axis instead
            # of silently replicating (extra blocks just grow the free list)
            d = math.prod(s for n, s in zip(mesh.axis_names, mesh.axis_sizes)
                          if n in ("pod", "data"))
            self.num_blocks = -(-self.num_blocks // d) * d

        pack4 = (self.kv_bits is not None and not self.kv_oracle
                 and all(b == 4 for b in self.kv_bits))
        self.cache = {}
        for key, leaf in template.items():
            if key in self.paged_keys:
                L, _, _, KV, hd = leaf.shape
                if self.kv_bits is None:
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd), leaf.dtype
                elif self.kv_oracle:
                    # oracle: fp32 leaves that will hold exact QDQ values
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd), jnp.float32
                elif pack4:
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd // 2), jnp.uint8
                else:
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd), jnp.int8
                self.cache[key] = jnp.zeros(shape, dt)
            else:
                self.cache[key] = leaf
        if self.kv_bits is not None:
            L = template[self.paged_keys[0]].shape[0]
            KV = template[self.paged_keys[0]].shape[3]
            self.cache["kv_qmax"] = jnp.asarray(
                [float(2 ** (b - 1) - 1) for b in self.kv_bits], jnp.float32)
            if not self.kv_oracle:
                for key in ("k_scale", "v_scale"):
                    self.cache[key] = jnp.zeros(
                        (L, self.num_blocks, self.block_size, KV), jnp.float32)
                # scale leaves are block state: ride in paged_keys so the
                # spec path's recurrent snapshot/restore never touches them
                # and cache_bytes() counts them toward the KV budget
                self.paged_keys = self.paged_keys + ("k_scale", "v_scale")
        if mesh is not None:
            # same dist hook as the slot pool: the *block* axis (axis 1 of
            # every paged leaf — cache_batch_axis's slot position) shards
            # over the mesh's data axes; block tables stay replicated
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))

        self.block_tables = np.zeros((num_seqs, max(self.blocks_per_seq, 1)),
                                     np.int32)
        # min-heaps: heappop -> lowest id (sorted ranges are valid heaps)
        self._free_seqs = list(range(num_seqs))
        self._active: set[int] = set()
        self._free_blocks = list(range(1, self.num_blocks))
        self._seq_blocks: dict[int, list[int]] = {}
        # ---- prefix cache: refcounts + trie index over full-block content.
        # Sound only when the paged KV blocks ARE the whole per-token state:
        # ring windows rewrite blocks in place and recurrent leaves (Mamba
        # ssm_*, RWKV wkv, token-shift) fold the full history into O(1)
        # state, so a mapped prefix would not reproduce the cold tokens.
        recurrent = set(template) - set(self.paged_keys) - {"length"}
        self.prefix_cache = bool(prefix_cache and self.blocks_per_seq
                                 and not self._ring and not recurrent)
        self._refcount: dict[int, int] = {}       # block -> #owning seqs
        self._root = _PrefixNode(None, None, 0, 0)
        self._node_of: dict[int, _PrefixNode] = {}  # any published block
        self._cached: dict[int, _PrefixNode] = {}   # refcount-0, evictable
        self._seq_tokens: dict[int, list[int]] = {}  # fed tokens per seq
        self._seq_node: dict[int, _PrefixNode] = {}  # deepest published node
        self._seq_pub: dict[int, int] = {}           # #published full blocks
        self._tick = 0
        self.prefix_lookups = 0       # admissions that consulted the trie
        self.prefix_hits = 0          # ... that mapped >= 1 shared block
        self.prefix_hit_tokens = 0    # prompt tokens served from the trie
        self.cow_copies = 0
        self.prefix_evictions = 0
        # device mirror of block_tables, re-uploaded only when the host
        # copy changed (or a donating backend consumed the old buffer)
        self._bt_dev = None
        self._bt_dirty = True
        # per-sequence token bound: a non-ring attention cache caps every
        # sequence at blocks_per_seq · block_size tokens
        self.length_bound = (self.blocks_per_seq * self.block_size
                             if self.blocks_per_seq and not self._ring
                             else None)

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free_seqs)

    @property
    def num_free_blocks(self) -> int:
        """Blocks an allocation can claim: the free heap PLUS cached
        (refcount-0, trie-indexed) blocks, which evict on demand."""
        return len(self._free_blocks) + len(self._cached)

    @property
    def blocks_shared(self) -> int:
        """Physical blocks currently mapped by more than one sequence."""
        return sum(1 for c in self._refcount.values() if c > 1)

    @property
    def prefix_cached_blocks(self) -> int:
        """Refcount-0 blocks held in the trie awaiting reuse/eviction."""
        return len(self._cached)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_seqs

    def block_occupancy(self) -> float:
        usable = self.num_blocks - 1
        return 1.0 - self.num_free_blocks / usable if usable else 0.0

    def blocks_needed(self, n_tokens: int) -> int:
        if not self.blocks_per_seq:
            return 0
        n = min(n_tokens, self.blocks_per_seq * self.block_size)
        return -(-n // self.block_size)

    def can_admit(self, n_tokens: int, reserve_blocks: int = 0,
                  tokens=None) -> bool:
        """Admissible iff a row is free and the reclaimable blocks cover
        the whole prompt PLUS ``reserve_blocks`` of headroom (the
        scheduler passes one block per running sequence — a vLLM-style
        watermark so a fresh admission isn't immediately preempted by its
        neighbors' growth and its chunked prefill burned).  Sequences
        longer than the per-row capacity are refused outright —
        ``blocks_needed`` clamps to capacity, so without this gate an
        over-length prompt would be admitted and silently truncated.

        ``tokens`` (the replay token ids) lets the gate count only *new*
        blocks: trie-matched prefix blocks arrive by incref, not
        allocation.  A block-aligned full-prompt hit costs one extra block
        — the COW copy of the last shared block that the one-token tail
        prefill (we always re-prefill >= 1 token for its logits) writes
        into."""
        if not self._free_seqs:
            return False
        if self.length_bound is not None and n_tokens > self.length_bound:
            return False
        if not self.blocks_per_seq:
            # O(1)-state family: no blocks exist, nothing to reserve — a
            # free row is the whole admission decision
            return True
        need = self.blocks_needed(n_tokens)
        free = self.num_free_blocks
        if tokens is not None and self.prefix_cache:
            hits = self._match_nodes(tokens)
            if hits:
                need -= len(hits)
                if len(hits) * self.block_size >= len(tokens):
                    need += 1  # admission COW of the last shared block
                # mapped cached blocks leave the reclaimable set
                free -= sum(1 for n in hits if n.block in self._cached)
        return free >= need + reserve_blocks

    def alloc_seq(self) -> int:
        if not self._free_seqs:
            raise RuntimeError(f"all {self.num_seqs} sequences in use")
        seq = heapq.heappop(self._free_seqs)
        self._active.add(seq)
        self._seq_blocks[seq] = []
        return seq

    def _alloc_block(self) -> int:
        """Claim one block at refcount 1: free heap first, then evict the
        least-recently-used refcount-0 trie leaf.  Caller must have
        checked ``num_free_blocks`` — exhaustion here is a bug."""
        if self._free_blocks:
            blk = heapq.heappop(self._free_blocks)
        else:
            blk = self._evict_lru()
        self._refcount[blk] = 1
        return blk

    def _evict_lru(self) -> int:
        """Evict the LRU cached node with no CACHED children — the
        deepest cached node of any chain qualifies, so a candidate always
        exists while ``_cached`` is non-empty.  A candidate may still
        have *owned* children (an admission COW decrefs the last shared
        block back to the trie while its mapper goes on publishing
        children under it); evicting it orphans those from the root —
        future lookups just match a shorter prefix, never stale
        content."""
        best = None
        for blk, node in self._cached.items():
            if any(c.block in self._cached for c in node.children.values()):
                continue
            key = (node.last_use, -node.depth, blk)
            if best is None or key < best[0]:
                best = (key, blk, node)
        assert best is not None, "cached blocks exist but none evictable"
        _, blk, node = best
        del self._cached[blk]
        self._detach(node)
        self.prefix_evictions += 1
        self.tracer.instant("prefix.evict", block=blk, depth=node.depth)
        return blk

    def _detach(self, node: _PrefixNode) -> None:
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        node.parent = None
        self._node_of.pop(node.block, None)

    def ensure(self, seq: int, n_tokens: int) -> bool:
        """Grow ``seq`` to cover ``n_tokens`` (clamped to its capacity).

        Returns False — allocating *nothing* — when free + evictable
        blocks cannot cover the growth; the scheduler answers with
        preemption.
        """
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        have = self._seq_blocks[seq]
        need = self.blocks_needed(n_tokens) - len(have)
        if need <= 0:
            return True
        if need > self.num_free_blocks:
            return False
        for _ in range(need):
            blk = self._alloc_block()
            self.block_tables[seq, len(have)] = blk
            have.append(blk)
        self._bt_dirty = True
        return True

    def free_seq(self, seq: int) -> None:
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        self._active.remove(seq)
        for blk in self._seq_blocks.pop(seq):
            self._decref(blk)
        self.block_tables[seq] = 0            # back to the garbage sink
        self._bt_dirty = True
        heapq.heappush(self._free_seqs, seq)
        self._seq_tokens.pop(seq, None)
        self._seq_node.pop(seq, None)
        self._seq_pub.pop(seq, None)

    def _decref(self, blk: int) -> None:
        """Drop one ownership reference.  A still-shared block (refcount
        > 1) only decrements — it must NEVER reach the free heap while
        another sequence reads it.  At refcount 0 a published block parks
        in the trie as evictable; an unpublished one returns to the heap."""
        count = self._refcount.get(blk, 0)
        if count <= 0:
            raise ValueError(f"block {blk} is not allocated")
        if count > 1:
            self._refcount[blk] = count - 1
            return
        del self._refcount[blk]
        node = self._node_of.get(blk)
        if node is not None:
            self._tick += 1
            node.last_use = self._tick
            self._cached[blk] = node
        else:
            heapq.heappush(self._free_blocks, blk)

    # ---------------------------------------------------------- prefix cache
    def _block_chunks(self, tokens):
        bs = self.block_size
        for i in range(len(tokens) // bs):
            yield tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])

    def _match_nodes(self, tokens) -> list[_PrefixNode]:
        """Longest chain of trie nodes matching ``tokens`` full blocks."""
        node, out = self._root, []
        for key in self._block_chunks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            out.append(child)
            node = child
        return out

    def map_shared(self, seq: int, tokens) -> int:
        """Map the longest trie-matched prefix of ``tokens`` into a fresh
        sequence's block table with an incref per block; returns how many
        prompt tokens are thereby already cached (0 = no hit).

        The count is capped at ``len(tokens) - 1``: at least one tail
        token is always prefilled, because admission needs the last
        prompt token's logits to sample from.  When the whole prompt is
        block-aligned in the trie that tail re-enters the last shared
        block, so it is COW'd here — at admission time, while the gate's
        block accounting (``can_admit``) still holds.
        """
        if not self.prefix_cache or not len(tokens):
            return 0
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        if self._seq_blocks[seq]:
            raise ValueError("map_shared requires a fresh (empty) sequence")
        self.prefix_lookups += 1
        nodes = self._match_nodes(tokens)
        if not nodes:
            return 0
        have = self._seq_blocks[seq]
        self._tick += 1
        for i, node in enumerate(nodes):
            blk = node.block
            self._refcount[blk] = self._refcount.get(blk, 0) + 1
            self._cached.pop(blk, None)  # reserved again: not evictable
            node.last_use = self._tick
            self.block_tables[seq, i] = blk
            have.append(blk)
        self._seq_node[seq] = nodes[-1]
        self._seq_pub[seq] = len(nodes)
        self._bt_dirty = True
        cached = min(len(nodes) * self.block_size, len(tokens) - 1)
        self.prefix_hits += 1
        self.prefix_hit_tokens += cached
        if cached < len(nodes) * self.block_size:
            ok = self.cow_for_write(seq, cached)
            assert ok, "can_admit reserved the admission-COW block"
        return cached

    def record_tokens(self, seq: int, tokens) -> None:
        """Record ``seq``'s fed-token history (prompt replay) and publish
        every newly completed full block into the trie.  Idempotent for
        prefixes already recorded."""
        if not self.prefix_cache or seq not in self._active:
            return
        toks = self._seq_tokens.setdefault(seq, [])
        if len(tokens) > len(toks):
            toks[:] = [int(t) for t in tokens]
        self._publish(seq)

    def record_token(self, seq: int, token) -> None:
        """Append one fed token (decode/spec advance) and publish if it
        completed a block.  Callers only record *accepted* tokens whose
        KV writes have landed — rejected spec drafts never publish."""
        if not self.prefix_cache or seq not in self._active:
            return
        self._seq_tokens.setdefault(seq, []).append(int(token))
        self._publish(seq)

    def _publish(self, seq: int) -> None:
        toks = self._seq_tokens.get(seq, [])
        have = self._seq_blocks[seq]
        bs = self.block_size
        done = self._seq_pub.get(seq, 0)
        if done < 0:  # poisoned by flush_prefix_cache mid-flight
            return
        node = self._seq_node.get(seq) or self._root
        while (done + 1) * bs <= len(toks) and done < len(have):
            key = tuple(toks[done * bs:(done + 1) * bs])
            child = node.children.get(key)
            if child is None and have[done] not in self._node_of:
                child = _PrefixNode(key, node, have[done], node.depth + 1)
                node.children[key] = child
                self._node_of[have[done]] = child
            if child is None:
                # this physical block already indexes other content (it
                # was COW'd from a published block): leave the trie as-is
                break
            self._tick += 1
            child.last_use = self._tick
            node = child
            done += 1
        self._seq_node[seq] = node
        self._seq_pub[seq] = done

    def cow_for_write(self, seq: int, start: int,
                      end: int | None = None) -> bool:
        """Make every block covering write positions ``[start, end)``
        privately owned before a KV write lands there: a shared block
        (refcount > 1) is replaced by a fresh block holding a bitwise
        device copy of its codes AND scale leaves, and decref'd.  Returns
        False — changing nothing further — if allocation is exhausted;
        the scheduler answers with preemption, exactly like ``ensure``.

        Sole-owner published blocks are NOT copied: the only writes the
        engine issues into them re-store identical values (the
        deterministic recompute of the same fed tokens), so readers
        mapping the block later still see exactly its published content.
        """
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        have = self._seq_blocks[seq]
        if not self.prefix_cache or not have:
            return True
        end = start + 1 if end is None else max(end, start + 1)
        first = start // self.block_size
        last = min((end - 1) // self.block_size, len(have) - 1)
        for i in range(first, last + 1):
            blk = have[i]
            if self._refcount.get(blk, 0) <= 1:
                continue
            if not self.num_free_blocks:
                return False
            new = self._alloc_block()
            self.cache = _cow_jit(self.cache, np.int32(blk), np.int32(new),
                                  self.paged_keys)
            self._refcount[blk] -= 1
            have[i] = new
            self.block_tables[seq, i] = new
            self._bt_dirty = True
            self.cow_copies += 1
            self.tracer.instant("cow", seq=seq, src=blk, dst=new)
        return True

    def flush_prefix_cache(self) -> None:
        """Drop the prefix index: cached (refcount-0) blocks return to the
        free heap, the trie empties, and in-flight sequences stop
        publishing (their KV predates whatever invalidated the cache —
        weight hot-swap being the canonical caller via
        ``autotune.deploy.hot_swap``).  Shared mappings stay valid: live
        sequences keep their refcounts and block tables."""
        self.tracer.instant("prefix.flush", cached_blocks=len(self._cached))
        for blk in self._cached:
            heapq.heappush(self._free_blocks, blk)
        self._cached.clear()
        self._node_of.clear()
        self._root = _PrefixNode(None, None, 0, 0)
        for seq in self._seq_node:
            self._seq_node[seq] = self._root
        for seq in self._seq_pub:
            self._seq_pub[seq] = -1  # poison: no re-publish of stale KV

    # ------------------------------------------------------------- cache ops
    def step_cache(self) -> dict:
        """Device view for one prefill-chunk/decode call: pool leaves plus
        the current block tables (data — shape never changes).  The table
        upload is cached across steps: steady-state decode (no growth, no
        frees) reuses one device buffer instead of re-uploading B × nb
        int32s per layer step.  A donating backend may consume the cached
        buffer — ``is_deleted`` forces a re-upload then."""
        d = dict(self.cache)
        d["block_tables"] = self.block_tables_dev()
        return d

    def block_tables_dev(self):
        """The dirty-flagged device mirror of ``block_tables``, shared by
        the decode, verify and fix-up call sites: one upload per table
        mutation (alloc/free/share/COW set ``_bt_dirty``), not one per
        step, with an ``is_deleted`` re-upload guard for donating
        backends that consumed the buffer."""
        if (self._bt_dirty or self._bt_dev is None
                or self._bt_dev.is_deleted()):
            self._bt_dev = jnp.asarray(self.block_tables)
            self._bt_dirty = False
        return self._bt_dev

    def accept(self, cache: dict) -> None:
        """Take back the (donated-and-returned) cache from a jit call."""
        cache = dict(cache)
        cache.pop("block_tables", None)  # host copy is authoritative
        self.cache = cache

    def cache_bytes(self) -> int:
        """Paged-leaf bytes (the number "equal cache bytes" compares)."""
        return sum(self.cache[k].size * self.cache[k].dtype.itemsize
                   for k in self.paged_keys)
