"""KV-cache pools for continuous batching: paged (block-granular) + slot.

``PagedCachePool`` is the production pool: transformer K/V lives as
fixed-size *blocks* in one ``(L, num_blocks, block_size, KV, hd)`` pool and
each sequence owns an ordered block table into it, so a 6-token sequence
holds one block while its neighbor holds thirty — instead of every
sequence owning a ``max_len``-sized slot.  Admission is gated on *free
blocks*, capacity grows block-by-block as a sequence decodes, and block
exhaustion is an allocation failure the scheduler turns into
preempt-and-requeue (never a crash).  State that is O(1) per sequence
(Mamba ``ssm_*``, RWKV ``wkv``/token-shift, ``length``) keeps slot
semantics behind the same interface — ``models.model.cache_batch_axis``
names the per-sequence axis of each leaf, exactly as for the slot pool.

Physical block 0 is a reserved garbage sink: empty batch rows point their
block tables at it, so the fixed-shape decode step can scatter "writes"
for inactive rows without touching any live sequence's blocks.

Quantized-KV block layout (``kv_bits=...``, paged pool only):

- code leaves ``"k"``/``"v"``: ``(L, num_blocks, block_size, KV, hd)``
  int8 symmetric codes in ``[-qmax, qmax]`` — or, when every layer is
  4-bit, ``(L, num_blocks, block_size, KV, hd//2)`` uint8 with two
  codes nibble-packed per byte (``quant.pack.kv_pack_int4``),
- scale leaves ``"k_scale"``/``"v_scale"``: ``(L, num_blocks,
  block_size, KV)`` float32, one absmax scale per (token, KV-head) —
  written by the same scatter that writes the codes, so a block is
  always internally consistent,
- ``"kv_qmax"``: ``(L,)`` float32 per-layer code ceiling
  ``2^(bits-1) - 1``.  Per-layer bitwidths are DATA, not shape — a
  mixed {8,6,3}-bit grid runs the same decode executable as uniform 8.

``kv_oracle=True`` (requires ``kv_bits``) keeps ``"k"``/``"v"`` as
float32 leaves holding the exact quantize-dequantize values
(``quant.pack.kv_qdq``) with no scale leaves: the dequantized product
``codes · scale`` the quantized path computes is bitwise these stored
floats, so engine token parity against the oracle is an exact-match
gate, not an allclose.  The scale leaves ride in ``paged_keys`` so
speculative decoding's recurrent-state snapshot skips them (they move
with the blocks, not with the O(1) state).

``SlotCachePool`` is the legacy slot-granular pool (one ``max_len`` row
per sequence, admission splices a batch-1 prefill cache in).  Kept for one
release behind ``--cache slot`` as the parity baseline; the paged engine
is pinned token-for-token against it in ``tests/test_serve_paged.py``.

Allocator invariants (both pools, hypothesis-tested):
- an id is returned at most once until freed; double-free raises,
- ``ensure`` never over-allocates and reports exhaustion as ``False``,
- freeing returns every block; pools drain back to their initial state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import cache_batch_axis

PAGED_KEYS = ("k", "v")  # transformer KV pages; everything else is O(1)/seq


def _splice(pool_cache: dict, single_cache: dict, slot) -> dict:
    return {
        key: jax.lax.dynamic_update_slice_in_dim(
            leaf, single_cache[key].astype(leaf.dtype), slot,
            axis=cache_batch_axis(key))
        for key, leaf in pool_cache.items()
    }


# module-level jit: the donated pool cache updates in place, `slot` enters
# as data, and the executable cache is shared across every pool instance
# (a per-instance jit would recompile on each fresh engine)
_splice_jit = jax.jit(_splice, donate_argnums=(0,))


class SlotCachePool:
    """Legacy slot-granular pool: one max_len-sized cache row per sequence."""

    def __init__(self, model, num_slots: int, max_len: int, dtype=None,
                 mesh=None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.max_len = max_len
        self.mesh = mesh
        self.cache = model.init_cache(num_slots, max_len, dtype)
        # hard per-sequence token bound (None = unbounded: recurrent or
        # ring state fits any length); admission and engine.submit gate on it
        self.length_bound = (
            max_len if "k" in self.cache
            and getattr(model.cfg, "sliding_window", None) is None else None)
        if mesh is not None:
            # data-axis sharding hook: slots live distributed over the
            # mesh's data axes (dist/sharding.cache_specs gives the slot
            # axis per leaf); splice/decode updates then stay in place on
            # the owning shard.  Decode is row-independent, so a slot's
            # tokens are identical wherever its rows are placed.
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))
        self._free = list(range(num_slots - 1, -1, -1))  # pop() -> lowest id
        self._active: set[int] = set()

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_slots

    def can_admit(self, n_tokens: int, reserve_blocks: int = 0) -> bool:
        """A free slot AND the sequence fitting its max_len-sized row.
        Admitting an over-length sequence would silently wrap/clobber the
        row — length is part of the admission decision, not just slots."""
        if self.length_bound is not None and n_tokens > self.length_bound:
            return False
        return bool(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(f"all {self.num_slots} slots in use")
        slot = self._free.pop()
        self._active.add(slot)
        return slot

    alloc_seq = alloc

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Slots are pre-sized to max_len: capacity is always there."""
        return True

    def free(self, slot: int) -> None:
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        self._active.remove(slot)
        self._free.append(slot)
        self._free.sort(reverse=True)  # keep pop() -> lowest id deterministic

    free_seq = free

    # ------------------------------------------------------------- cache ops
    def write(self, slot: int, single_cache: dict) -> None:
        """Splice a batch-1 cache (one prefilled sequence) into ``slot``."""
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        if set(single_cache) != set(self.cache):
            raise ValueError(
                f"cache keys {sorted(single_cache)} != pool {sorted(self.cache)}")
        for key, pool_leaf in self.cache.items():
            ax = cache_batch_axis(key)
            want = pool_leaf.shape[:ax] + (1,) + pool_leaf.shape[ax + 1:]
            if tuple(single_cache[key].shape) != want:
                raise ValueError(
                    f"cache[{key!r}] shape {tuple(single_cache[key].shape)} "
                    f"!= {want}")
        self.cache = _splice_jit(self.cache, single_cache, slot)

    def step_cache(self) -> dict:
        return dict(self.cache)

    def accept(self, cache: dict) -> None:
        self.cache = cache

    def cache_bytes(self) -> int:
        """KV-leaf bytes (what the paged pool's equal-bytes claim compares)."""
        return sum(self.cache[k].size * self.cache[k].dtype.itemsize
                   for k in PAGED_KEYS if k in self.cache)


class PagedCachePool:
    """Block-granular KV pool + per-sequence block tables.

    ``num_seqs``  max concurrently-running sequences (decode batch rows).
    ``max_len``   per-sequence token capacity bound (same meaning as the
                  slot pool's); sliding-window archs cap it at the window.
    ``block_size`` tokens per KV block.  For ring (windowed) caches the
                  block size is shrunk to the largest divisor of the ring
                  length so ring arithmetic stays exact.
    ``num_blocks`` physical blocks *including* the reserved garbage block
                  0.  Default allocates full slot-pool capacity
                  (num_seqs × blocks_per_seq + 1); pass less to
                  oversubscribe — that is the point of paging.
    ``kv_bits``   quantize the KV blocks: an int (uniform) or a
                  per-layer sequence of ints in 2..8.  See the module
                  docstring for the block layout.  Uniform 4 selects the
                  nibble-packed uint8 container (half the code bytes).
    ``kv_oracle`` with ``kv_bits``: store the exact QDQ *values* in
                  float32 instead of codes — the token-parity oracle the
                  quantized engine is gated against.
    """

    def __init__(self, model, num_seqs: int, max_len: int, *,
                 block_size: int = 16, num_blocks: int | None = None,
                 dtype=None, mesh=None, kv_bits=None, kv_oracle: bool = False):
        if num_seqs < 1:
            raise ValueError("num_seqs must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if kv_oracle and kv_bits is None:
            raise ValueError("kv_oracle requires kv_bits")
        self.num_seqs = self.num_slots = num_seqs  # num_slots: engine compat
        self.max_len = max_len
        self.mesh = mesh
        template = model.init_cache(num_seqs, max_len, dtype)
        self.paged_keys = tuple(k for k in PAGED_KEYS if k in template)
        if kv_bits is not None and not self.paged_keys:
            raise ValueError(
                "kv_bits quantizes paged attention KV blocks; this model "
                "family keeps O(1) recurrent state (nothing paged to "
                "quantize)")
        if kv_bits is not None:
            L = template[self.paged_keys[0]].shape[0]
            bits = ([int(kv_bits)] * L if np.isscalar(kv_bits)
                    else [int(b) for b in kv_bits])
            if len(bits) != L:
                raise ValueError(
                    f"kv_bits has {len(bits)} entries for {L} layers")
            if any(not 2 <= b <= 8 for b in bits):
                raise ValueError(f"kv_bits entries must be in 2..8: {bits}")
            self.kv_bits = bits
        else:
            self.kv_bits = None
        self.kv_oracle = bool(kv_oracle)
        self._ring = (getattr(model.cfg, "sliding_window", None) is not None
                      and bool(self.paged_keys))
        if self.paged_keys:
            T = template[self.paged_keys[0]].shape[2]  # (L, B, T, KV, hd)
            if self._ring:
                # ring arithmetic needs blocks_per_seq · bs == ring length
                bs = min(block_size, T)
                while T % bs:
                    bs -= 1
                self.block_size = bs
            else:
                self.block_size = min(block_size, T)
            self.blocks_per_seq = -(-T // self.block_size)
        else:  # O(1)-state family: pure slot semantics, no blocks at all
            self.block_size = block_size
            self.blocks_per_seq = 0
        usable = (num_blocks - 1 if num_blocks is not None
                  else num_seqs * self.blocks_per_seq)
        if self.blocks_per_seq and usable < self.blocks_per_seq:
            raise ValueError(
                f"num_blocks={num_blocks} leaves {usable} usable blocks < "
                f"{self.blocks_per_seq} needed for a single full sequence")
        self.num_blocks = usable + 1  # + reserved garbage block 0
        if mesh is not None and self.paged_keys:
            # pad the pool to a multiple of the data-axis device count so
            # cache_specs' divisibility guard shards the block axis instead
            # of silently replicating (extra blocks just grow the free list)
            d = math.prod(s for n, s in zip(mesh.axis_names, mesh.axis_sizes)
                          if n in ("pod", "data"))
            self.num_blocks = -(-self.num_blocks // d) * d

        pack4 = (self.kv_bits is not None and not self.kv_oracle
                 and all(b == 4 for b in self.kv_bits))
        self.cache = {}
        for key, leaf in template.items():
            if key in self.paged_keys:
                L, _, _, KV, hd = leaf.shape
                if self.kv_bits is None:
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd), leaf.dtype
                elif self.kv_oracle:
                    # oracle: fp32 leaves that will hold exact QDQ values
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd), jnp.float32
                elif pack4:
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd // 2), jnp.uint8
                else:
                    shape, dt = (L, self.num_blocks, self.block_size, KV, hd), jnp.int8
                self.cache[key] = jnp.zeros(shape, dt)
            else:
                self.cache[key] = leaf
        if self.kv_bits is not None:
            L = template[self.paged_keys[0]].shape[0]
            KV = template[self.paged_keys[0]].shape[3]
            self.cache["kv_qmax"] = jnp.asarray(
                [float(2 ** (b - 1) - 1) for b in self.kv_bits], jnp.float32)
            if not self.kv_oracle:
                for key in ("k_scale", "v_scale"):
                    self.cache[key] = jnp.zeros(
                        (L, self.num_blocks, self.block_size, KV), jnp.float32)
                # scale leaves are block state: ride in paged_keys so the
                # spec path's recurrent snapshot/restore never touches them
                # and cache_bytes() counts them toward the KV budget
                self.paged_keys = self.paged_keys + ("k_scale", "v_scale")
        if mesh is not None:
            # same dist hook as the slot pool: the *block* axis (axis 1 of
            # every paged leaf — cache_batch_axis's slot position) shards
            # over the mesh's data axes; block tables stay replicated
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))

        self.block_tables = np.zeros((num_seqs, max(self.blocks_per_seq, 1)),
                                     np.int32)
        self._free_seqs = list(range(num_seqs - 1, -1, -1))  # pop -> lowest
        self._active: set[int] = set()
        self._free_blocks = list(range(self.num_blocks - 1, 0, -1))
        self._seq_blocks: dict[int, list[int]] = {}
        # device mirror of block_tables, re-uploaded only when the host
        # copy changed (or a donating backend consumed the old buffer)
        self._bt_dev = None
        self._bt_dirty = True
        # per-sequence token bound: a non-ring attention cache caps every
        # sequence at blocks_per_seq · block_size tokens
        self.length_bound = (self.blocks_per_seq * self.block_size
                             if self.blocks_per_seq and not self._ring
                             else None)

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free_seqs)

    @property
    def num_free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_seqs

    def block_occupancy(self) -> float:
        usable = self.num_blocks - 1
        return 1.0 - len(self._free_blocks) / usable if usable else 0.0

    def blocks_needed(self, n_tokens: int) -> int:
        if not self.blocks_per_seq:
            return 0
        n = min(n_tokens, self.blocks_per_seq * self.block_size)
        return -(-n // self.block_size)

    def can_admit(self, n_tokens: int, reserve_blocks: int = 0) -> bool:
        """Admissible iff a row is free and the free list covers the whole
        prompt PLUS ``reserve_blocks`` of headroom (the scheduler passes
        one block per running sequence — a vLLM-style watermark so a fresh
        admission isn't immediately preempted by its neighbors' growth and
        its chunked prefill burned).  Sequences longer than the per-row
        capacity are refused outright — ``blocks_needed`` clamps to
        capacity, so without this gate an over-length prompt would be
        admitted and silently truncated."""
        if not self._free_seqs:
            return False
        if self.length_bound is not None and n_tokens > self.length_bound:
            return False
        if not self.blocks_per_seq:
            # O(1)-state family: no blocks exist, nothing to reserve — a
            # free row is the whole admission decision
            return True
        return (len(self._free_blocks)
                >= self.blocks_needed(n_tokens) + reserve_blocks)

    def alloc_seq(self) -> int:
        if not self._free_seqs:
            raise RuntimeError(f"all {self.num_seqs} sequences in use")
        seq = self._free_seqs.pop()
        self._active.add(seq)
        self._seq_blocks[seq] = []
        return seq

    def ensure(self, seq: int, n_tokens: int) -> bool:
        """Grow ``seq`` to cover ``n_tokens`` (clamped to its capacity).

        Returns False — allocating *nothing* — when the free list cannot
        cover the growth; the scheduler answers with preemption.
        """
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        have = self._seq_blocks[seq]
        need = self.blocks_needed(n_tokens) - len(have)
        if need <= 0:
            return True
        if need > len(self._free_blocks):
            return False
        for _ in range(need):
            blk = self._free_blocks.pop()
            self.block_tables[seq, len(have)] = blk
            have.append(blk)
        self._bt_dirty = True
        return True

    def free_seq(self, seq: int) -> None:
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        self._active.remove(seq)
        self._free_blocks.extend(self._seq_blocks.pop(seq))
        self._free_blocks.sort(reverse=True)  # pop() -> lowest id
        self.block_tables[seq] = 0            # back to the garbage sink
        self._bt_dirty = True
        self._free_seqs.append(seq)
        self._free_seqs.sort(reverse=True)

    # ------------------------------------------------------------- cache ops
    def step_cache(self) -> dict:
        """Device view for one prefill-chunk/decode call: pool leaves plus
        the current block tables (data — shape never changes).  The table
        upload is cached across steps: steady-state decode (no growth, no
        frees) reuses one device buffer instead of re-uploading B × nb
        int32s per layer step.  A donating backend may consume the cached
        buffer — ``is_deleted`` forces a re-upload then."""
        d = dict(self.cache)
        if (self._bt_dirty or self._bt_dev is None
                or self._bt_dev.is_deleted()):
            self._bt_dev = jnp.asarray(self.block_tables)
            self._bt_dirty = False
        d["block_tables"] = self._bt_dev
        return d

    def accept(self, cache: dict) -> None:
        """Take back the (donated-and-returned) cache from a jit call."""
        cache = dict(cache)
        cache.pop("block_tables", None)  # host copy is authoritative
        self.cache = cache

    def cache_bytes(self) -> int:
        """Paged-leaf bytes (the number "equal cache bytes" compares)."""
        return sum(self.cache[k].size * self.cache[k].dtype.itemsize
                   for k in self.paged_keys)
