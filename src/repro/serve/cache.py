"""KV-cache pools for continuous batching: paged (block-granular) + slot.

``PagedCachePool`` is the production pool: transformer K/V lives as
fixed-size *blocks* in one ``(L, num_blocks, block_size, KV, hd)`` pool and
each sequence owns an ordered block table into it, so a 6-token sequence
holds one block while its neighbor holds thirty — instead of every
sequence owning a ``max_len``-sized slot.  Admission is gated on *free
blocks*, capacity grows block-by-block as a sequence decodes, and block
exhaustion is an allocation failure the scheduler turns into
preempt-and-requeue (never a crash).  State that is O(1) per sequence
(Mamba ``ssm_*``, RWKV ``wkv``/token-shift, ``length``) keeps slot
semantics behind the same interface — ``models.model.cache_batch_axis``
names the per-sequence axis of each leaf, exactly as for the slot pool.

Physical block 0 is a reserved garbage sink: empty batch rows point their
block tables at it, so the fixed-shape decode step can scatter "writes"
for inactive rows without touching any live sequence's blocks.

``SlotCachePool`` is the legacy slot-granular pool (one ``max_len`` row
per sequence, admission splices a batch-1 prefill cache in).  Kept for one
release behind ``--cache slot`` as the parity baseline; the paged engine
is pinned token-for-token against it in ``tests/test_serve_paged.py``.

Allocator invariants (both pools, hypothesis-tested):
- an id is returned at most once until freed; double-free raises,
- ``ensure`` never over-allocates and reports exhaustion as ``False``,
- freeing returns every block; pools drain back to their initial state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import cache_batch_axis

PAGED_KEYS = ("k", "v")  # transformer KV pages; everything else is O(1)/seq


def _splice(pool_cache: dict, single_cache: dict, slot) -> dict:
    return {
        key: jax.lax.dynamic_update_slice_in_dim(
            leaf, single_cache[key].astype(leaf.dtype), slot,
            axis=cache_batch_axis(key))
        for key, leaf in pool_cache.items()
    }


# module-level jit: the donated pool cache updates in place, `slot` enters
# as data, and the executable cache is shared across every pool instance
# (a per-instance jit would recompile on each fresh engine)
_splice_jit = jax.jit(_splice, donate_argnums=(0,))


class SlotCachePool:
    """Legacy slot-granular pool: one max_len-sized cache row per sequence."""

    def __init__(self, model, num_slots: int, max_len: int, dtype=None,
                 mesh=None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.max_len = max_len
        self.mesh = mesh
        self.cache = model.init_cache(num_slots, max_len, dtype)
        if mesh is not None:
            # data-axis sharding hook: slots live distributed over the
            # mesh's data axes (dist/sharding.cache_specs gives the slot
            # axis per leaf); splice/decode updates then stay in place on
            # the owning shard.  Decode is row-independent, so a slot's
            # tokens are identical wherever its rows are placed.
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))
        self._free = list(range(num_slots - 1, -1, -1))  # pop() -> lowest id
        self._active: set[int] = set()

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_slots

    def can_admit(self, n_tokens: int, reserve_blocks: int = 0) -> bool:
        """Slot granularity: any free slot fits any (length-bounded) seq."""
        return bool(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(f"all {self.num_slots} slots in use")
        slot = self._free.pop()
        self._active.add(slot)
        return slot

    alloc_seq = alloc

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Slots are pre-sized to max_len: capacity is always there."""
        return True

    def free(self, slot: int) -> None:
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        self._active.remove(slot)
        self._free.append(slot)
        self._free.sort(reverse=True)  # keep pop() -> lowest id deterministic

    free_seq = free

    # ------------------------------------------------------------- cache ops
    def write(self, slot: int, single_cache: dict) -> None:
        """Splice a batch-1 cache (one prefilled sequence) into ``slot``."""
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        if set(single_cache) != set(self.cache):
            raise ValueError(
                f"cache keys {sorted(single_cache)} != pool {sorted(self.cache)}")
        for key, pool_leaf in self.cache.items():
            ax = cache_batch_axis(key)
            want = pool_leaf.shape[:ax] + (1,) + pool_leaf.shape[ax + 1:]
            if tuple(single_cache[key].shape) != want:
                raise ValueError(
                    f"cache[{key!r}] shape {tuple(single_cache[key].shape)} "
                    f"!= {want}")
        self.cache = _splice_jit(self.cache, single_cache, slot)

    def step_cache(self) -> dict:
        return dict(self.cache)

    def accept(self, cache: dict) -> None:
        self.cache = cache

    def cache_bytes(self) -> int:
        """KV-leaf bytes (what the paged pool's equal-bytes claim compares)."""
        return sum(self.cache[k].size * self.cache[k].dtype.itemsize
                   for k in PAGED_KEYS if k in self.cache)


class PagedCachePool:
    """Block-granular KV pool + per-sequence block tables.

    ``num_seqs``  max concurrently-running sequences (decode batch rows).
    ``max_len``   per-sequence token capacity bound (same meaning as the
                  slot pool's); sliding-window archs cap it at the window.
    ``block_size`` tokens per KV block.  For ring (windowed) caches the
                  block size is shrunk to the largest divisor of the ring
                  length so ring arithmetic stays exact.
    ``num_blocks`` physical blocks *including* the reserved garbage block
                  0.  Default allocates full slot-pool capacity
                  (num_seqs × blocks_per_seq + 1); pass less to
                  oversubscribe — that is the point of paging.
    """

    def __init__(self, model, num_seqs: int, max_len: int, *,
                 block_size: int = 16, num_blocks: int | None = None,
                 dtype=None, mesh=None):
        if num_seqs < 1:
            raise ValueError("num_seqs must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_seqs = self.num_slots = num_seqs  # num_slots: engine compat
        self.max_len = max_len
        self.mesh = mesh
        template = model.init_cache(num_seqs, max_len, dtype)
        self.paged_keys = tuple(k for k in PAGED_KEYS if k in template)
        self._ring = (getattr(model.cfg, "sliding_window", None) is not None
                      and bool(self.paged_keys))
        if self.paged_keys:
            T = template[self.paged_keys[0]].shape[2]  # (L, B, T, KV, hd)
            if self._ring:
                # ring arithmetic needs blocks_per_seq · bs == ring length
                bs = min(block_size, T)
                while T % bs:
                    bs -= 1
                self.block_size = bs
            else:
                self.block_size = min(block_size, T)
            self.blocks_per_seq = -(-T // self.block_size)
        else:  # O(1)-state family: pure slot semantics, no blocks at all
            self.block_size = block_size
            self.blocks_per_seq = 0
        usable = (num_blocks - 1 if num_blocks is not None
                  else num_seqs * self.blocks_per_seq)
        if self.blocks_per_seq and usable < self.blocks_per_seq:
            raise ValueError(
                f"num_blocks={num_blocks} leaves {usable} usable blocks < "
                f"{self.blocks_per_seq} needed for a single full sequence")
        self.num_blocks = usable + 1  # + reserved garbage block 0
        if mesh is not None and self.paged_keys:
            # pad the pool to a multiple of the data-axis device count so
            # cache_specs' divisibility guard shards the block axis instead
            # of silently replicating (extra blocks just grow the free list)
            d = math.prod(s for n, s in zip(mesh.axis_names, mesh.axis_sizes)
                          if n in ("pod", "data"))
            self.num_blocks = -(-self.num_blocks // d) * d

        self.cache = {}
        for key, leaf in template.items():
            if key in self.paged_keys:
                L, _, _, KV, hd = leaf.shape
                self.cache[key] = jnp.zeros(
                    (L, self.num_blocks, self.block_size, KV, hd), leaf.dtype)
            else:
                self.cache[key] = leaf
        if mesh is not None:
            # same dist hook as the slot pool: the *block* axis (axis 1 of
            # every paged leaf — cache_batch_axis's slot position) shards
            # over the mesh's data axes; block tables stay replicated
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))

        self.block_tables = np.zeros((num_seqs, max(self.blocks_per_seq, 1)),
                                     np.int32)
        self._free_seqs = list(range(num_seqs - 1, -1, -1))  # pop -> lowest
        self._active: set[int] = set()
        self._free_blocks = list(range(self.num_blocks - 1, 0, -1))
        self._seq_blocks: dict[int, list[int]] = {}

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free_seqs)

    @property
    def num_free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_seqs

    def block_occupancy(self) -> float:
        usable = self.num_blocks - 1
        return 1.0 - len(self._free_blocks) / usable if usable else 0.0

    def blocks_needed(self, n_tokens: int) -> int:
        if not self.blocks_per_seq:
            return 0
        n = min(n_tokens, self.blocks_per_seq * self.block_size)
        return -(-n // self.block_size)

    def can_admit(self, n_tokens: int, reserve_blocks: int = 0) -> bool:
        """Admissible iff a row is free and the free list covers the whole
        prompt PLUS ``reserve_blocks`` of headroom (the scheduler passes
        one block per running sequence — a vLLM-style watermark so a fresh
        admission isn't immediately preempted by its neighbors' growth and
        its chunked prefill burned)."""
        if not self._free_seqs:
            return False
        if not self.blocks_per_seq:
            # O(1)-state family: no blocks exist, nothing to reserve — a
            # free row is the whole admission decision
            return True
        return (len(self._free_blocks)
                >= self.blocks_needed(n_tokens) + reserve_blocks)

    def alloc_seq(self) -> int:
        if not self._free_seqs:
            raise RuntimeError(f"all {self.num_seqs} sequences in use")
        seq = self._free_seqs.pop()
        self._active.add(seq)
        self._seq_blocks[seq] = []
        return seq

    def ensure(self, seq: int, n_tokens: int) -> bool:
        """Grow ``seq`` to cover ``n_tokens`` (clamped to its capacity).

        Returns False — allocating *nothing* — when the free list cannot
        cover the growth; the scheduler answers with preemption.
        """
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        have = self._seq_blocks[seq]
        need = self.blocks_needed(n_tokens) - len(have)
        if need <= 0:
            return True
        if need > len(self._free_blocks):
            return False
        for _ in range(need):
            blk = self._free_blocks.pop()
            self.block_tables[seq, len(have)] = blk
            have.append(blk)
        return True

    def free_seq(self, seq: int) -> None:
        if seq not in self._active:
            raise ValueError(f"seq {seq} is not allocated")
        self._active.remove(seq)
        self._free_blocks.extend(self._seq_blocks.pop(seq))
        self._free_blocks.sort(reverse=True)  # pop() -> lowest id
        self.block_tables[seq] = 0            # back to the garbage sink
        self._free_seqs.append(seq)
        self._free_seqs.sort(reverse=True)

    # ------------------------------------------------------------- cache ops
    def step_cache(self) -> dict:
        """Device view for one prefill-chunk/decode call: pool leaves plus
        the current block tables (data — shape never changes)."""
        d = dict(self.cache)
        d["block_tables"] = jnp.asarray(self.block_tables)
        return d

    def accept(self, cache: dict) -> None:
        """Take back the (donated-and-returned) cache from a jit call."""
        cache = dict(cache)
        cache.pop("block_tables", None)  # host copy is authoritative
        self.cache = cache

    def cache_bytes(self) -> int:
        """Paged-leaf bytes (the number "equal cache bytes" compares)."""
        return sum(self.cache[k].size * self.cache[k].dtype.itemsize
                   for k in self.paged_keys)
