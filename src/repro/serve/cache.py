"""Slot-based KV-cache pool for continuous batching.

One preallocated decode cache of ``num_slots`` sequences (the model's own
``init_cache`` layout: per-layer state ``(L, B, ...)``, bookkeeping
``(B,)`` — see ``models.model.cache_batch_axis``).  Sequences of different
lengths share it: admission *splices* a batch-1 prefill cache into a free
slot, and a finished sequence frees its slot immediately so the next
queued request can take it on the very next engine step.

The pool is the alloc/free bookkeeping plus the cache pytree; it never
calls the model.  Invariants (enforced, tested in test_serve_engine.py):

- ``alloc`` returns each slot at most once until it is freed; raises
  ``RuntimeError`` when the pool is exhausted,
- ``free`` of a non-allocated slot raises ``ValueError``,
- ``write`` only accepts a cache whose non-batch dims match the pool's
  (same layers / cache length / head layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import cache_batch_axis


def _splice(pool_cache: dict, single_cache: dict, slot) -> dict:
    return {
        key: jax.lax.dynamic_update_slice_in_dim(
            leaf, single_cache[key].astype(leaf.dtype), slot,
            axis=cache_batch_axis(key))
        for key, leaf in pool_cache.items()
    }


# module-level jit: the donated pool cache updates in place, `slot` enters
# as data, and the executable cache is shared across every pool instance
# (a per-instance jit would recompile on each fresh engine)
_splice_jit = jax.jit(_splice, donate_argnums=(0,))


class SlotCachePool:
    def __init__(self, model, num_slots: int, max_len: int, dtype=None,
                 mesh=None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.max_len = max_len
        self.mesh = mesh
        self.cache = model.init_cache(num_slots, max_len, dtype)
        if mesh is not None:
            # data-axis sharding hook: slots live distributed over the
            # mesh's data axes (dist/sharding.cache_specs gives the slot
            # axis per leaf); splice/decode updates then stay in place on
            # the owning shard.  Decode is row-independent, so a slot's
            # tokens are identical wherever its rows are placed.
            from repro.dist import sharding as shd

            self.cache = jax.device_put(
                self.cache, shd.to_named(shd.cache_specs(self.cache, mesh),
                                         mesh))
        self._free = list(range(num_slots - 1, -1, -1))  # pop() -> lowest id
        self._active: set[int] = set()

    # ----------------------------------------------------------- bookkeeping
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> frozenset:
        return frozenset(self._active)

    def occupancy(self) -> float:
        return len(self._active) / self.num_slots

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(f"all {self.num_slots} slots in use")
        slot = self._free.pop()
        self._active.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        self._active.remove(slot)
        self._free.append(slot)
        self._free.sort(reverse=True)  # keep pop() -> lowest id deterministic

    # ------------------------------------------------------------- cache ops
    def write(self, slot: int, single_cache: dict) -> None:
        """Splice a batch-1 cache (one prefilled sequence) into ``slot``."""
        if slot not in self._active:
            raise ValueError(f"slot {slot} is not allocated")
        if set(single_cache) != set(self.cache):
            raise ValueError(
                f"cache keys {sorted(single_cache)} != pool {sorted(self.cache)}")
        for key, pool_leaf in self.cache.items():
            ax = cache_batch_axis(key)
            want = pool_leaf.shape[:ax] + (1,) + pool_leaf.shape[ax + 1:]
            if tuple(single_cache[key].shape) != want:
                raise ValueError(
                    f"cache[{key!r}] shape {tuple(single_cache[key].shape)} "
                    f"!= {want}")
        self.cache = _splice_jit(self.cache, single_cache, slot)
