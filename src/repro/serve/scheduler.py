"""Iteration-level scheduler: which sequence runs in which row, when.

Continuous batching à la Orca/vLLM, specialized to ReLeQ serving: every
engine step the scheduler (1) admits queued requests — *mid-decode*, the
running sequences never stop — gated on both a free sequence row AND
enough free KV blocks for the whole prompt (paged pool; the slot pool
degenerates to "any free slot"), and (2) reserves one token of cache
growth per running sequence before the packed decode step.  When the
block pool is exhausted, the reservation pass *preempts the youngest
running sequence*: its blocks return to the pool, the request goes back
to the FRONT of the admission queue, and re-admission recomputes its
cache from prompt + already-emitted tokens (recompute-style preemption —
greedy decode is deterministic, so the replayed state is exact and the
client-visible token stream is unaffected).  Oldest-first reservation
plus a pool sized for ≥ 1 full sequence guarantees progress: the oldest
sequence can always grow.

The scheduler owns the bookkeeping (queue, pool, running table) and makes
no model calls — the engine turns its decisions into prefill/decode
launches.  Keeping the policy host-side means the device-side decode step
stays a single fixed-shape executable regardless of traffic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, RequestState


@dataclass
class RunningSeq:
    """One admitted sequence: its request, next token to feed, and how
    many tokens its cache currently holds (drives block reservation)."""

    request: Request
    slot: int
    last_token: int
    cached_len: int = 0
    order: int = 0        # admission counter — youngest = max(order)


class ContinuousScheduler:
    def __init__(self, pool, queue: AdmissionQueue, registry=None):
        self.pool = pool
        self.queue = queue
        self.running: dict[int, RunningSeq] = {}  # row -> sequence
        self.preemptions = 0
        self._order = 0
        # prefix-cache hooks; identity no-ops for pools without sharing
        self._cow = getattr(pool, "cow_for_write", lambda *a: True)
        self._record = getattr(pool, "record_token", lambda *a: None)
        # scheduling-decision counters (repro.obs); a private registry
        # keeps the instrument calls unconditional
        if registry is None:
            from repro.obs import Registry
            registry = Registry()
        self._c_admitted = registry.counter("sched.admitted",
                                            unit="requests")
        self._c_blocked = registry.counter(
            "sched.admit_blocked", desc="head-of-line admission stalls")
        self._c_preempt = registry.counter("sched.preemptions")

    # ------------------------------------------------------------------
    @property
    def num_running(self) -> int:
        return len(self.running)

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    def admissions(self) -> list[tuple[Request, int, int]]:
        """Pop queued requests into free rows (FIFO, head-of-line blocking:
        a big request never gets overtaken by a small one).  Returns
        ``(request, row, cached)`` triples — ``cached`` is how many replay
        tokens the prefix trie already holds, mapped into the fresh block
        table by incref (``pool.map_shared``): the engine prefills only
        the tail.  The admission gate counts *new* blocks only, so a
        request whose prompt is mostly shared admits into a pool that
        could not hold it cold.  The trie is consulted at pop time:
        requests admitted in the SAME step don't see each other's blocks
        (they publish after their prefill lands), which staggered
        arrivals make irrelevant in steady state."""
        admitted = []
        map_shared = getattr(self.pool, "map_shared", None)
        while self.queue:
            req = self.queue.peek()
            tokens = req.replay_tokens()
            # headroom watermark: one growth block per running (or just-
            # admitted) sequence, so admitting never sets up an immediate
            # preempt-replay cycle
            if not self.pool.can_admit(
                    req.cache_tokens_needed(),
                    reserve_blocks=len(self.running) + len(admitted),
                    tokens=tokens):
                self._c_blocked.inc()
                break
            self.queue.pop()
            seq = self.pool.alloc_seq()
            cached = map_shared(seq, tokens) if map_shared else 0
            ok = self.pool.ensure(seq, req.cache_tokens_needed())
            assert ok, "can_admit promised the blocks"
            self._c_admitted.inc()
            admitted.append((req, seq, cached))
        return admitted

    def start(self, request: Request, slot: int, first_token: int,
              cached_len: int = 0) -> None:
        """Register a prefilled sequence as running."""
        request.state = RequestState.RUNNING
        self.running[slot] = RunningSeq(request, slot, first_token,
                                        cached_len, self._order)
        self._order += 1

    def advance(self, slot: int, token: int) -> None:
        seq = self.running[slot]
        # the PREVIOUS token is now fed (its KV write landed this step):
        # record it so the pool publishes completed blocks into the trie
        self._record(slot, seq.last_token)
        seq.last_token = token
        seq.cached_len += 1

    def reserve_for_decode(self) -> list[Request]:
        """Grow every running sequence by one token's worth of blocks,
        oldest first; preempt-and-requeue the youngest on exhaustion.
        The write position must also be privately owned — a decode into a
        still-shared block (a preempted sibling's prefix outliving it)
        copies-on-write first, and a failed copy is handled exactly like
        block exhaustion.  Returns the preempted requests (already
        requeued)."""
        preempted: list[Request] = []
        for slot in sorted(self.running, key=lambda s: self.running[s].order):
            if slot not in self.running:  # already preempted this pass
                continue
            seq = self.running[slot]
            while not (self.pool.ensure(slot, seq.cached_len + 1)
                       and self._cow(slot, seq.cached_len)):
                victim = max(self.running,
                             key=lambda s: self.running[s].order)
                preempted.append(self.preempt(victim))
                if victim == slot:
                    break
        return preempted

    def reserve_lookahead(self) -> bool:
        """Non-preempting reservation ONE decode step beyond the last
        reserved write: blocks for ``cached_len + 2`` tokens and private
        ownership of position ``cached_len + 1`` for every running
        sequence.  Used by the engine's one-step-lookahead pipeline,
        which falls back to the synchronous path (a ``pipeline.bubbles``
        count) whenever the extra step cannot be covered without
        preempting.  Partial grants are kept: the blocks are needed
        within two steps anyway and are freed by preempt/finish like any
        others, so the progress guarantee is unchanged."""
        for slot in sorted(self.running,
                           key=lambda s: self.running[s].order):
            seq = self.running[slot]
            if not (self.pool.ensure(slot, seq.cached_len + 2)
                    and self._cow(slot, seq.cached_len + 1)):
                return False
        return True

    def reserve_for_spec(self, want: dict[int, int]
                         ) -> tuple[dict[int, int], list[Request]]:
        """Reserve ``cached_len + k + 1`` tokens of cache per running row
        for a speculative window of ``want[slot] = k`` draft tokens,
        oldest first.  Under block pressure a row's window SHRINKS toward
        zero before anyone is preempted — losing speculation for a step
        is strictly cheaper than a preempt-replay cycle — and only when
        even plain decode growth (k = 0) cannot be covered does the
        youngest sequence get preempted, exactly like
        :meth:`reserve_for_decode`.  Returns (granted window per surviving
        slot, preempted requests).  Speculation never reserves beyond what
        the target itself will need (callers cap k by the remaining token
        budget), so the no-extra-blocks invariant holds by construction.
        """
        granted: dict[int, int] = {}
        preempted: list[Request] = []
        for slot in sorted(self.running, key=lambda s: self.running[s].order):
            if slot not in self.running:  # already preempted this pass
                continue
            seq = self.running[slot]
            want_k = max(int(want.get(slot, 0)), 0)
            while slot in self.running:
                # retry the FULL wanted window each pass: a preemption on
                # the previous pass freed blocks, so a window that had
                # shrunk toward zero may now be grantable again
                k = want_k
                while k > 0 and not self.pool.ensure(slot,
                                                     seq.cached_len + k + 1):
                    k -= 1  # shrink the window before taking blocks
                if k > 0 or self.pool.ensure(slot, seq.cached_len + 1):
                    # drafts + verify write [cached_len, cached_len+k+1):
                    # COW any still-shared block under the window before
                    # the spec step scatters into it
                    if not self._cow(slot, seq.cached_len,
                                     seq.cached_len + k + 1):
                        k = 0  # treat like exhaustion: shrink, then preempt
                        if self._cow(slot, seq.cached_len):
                            granted[slot] = 0
                            break
                    else:
                        granted[slot] = k
                        break
                victim = max(self.running,
                             key=lambda s: self.running[s].order)
                preempted.append(self.preempt(victim))
        return granted, preempted

    def preempt(self, slot: int) -> Request:
        """Evict a running sequence: blocks back to the pool, request back
        to the queue head (it keeps its emitted tokens; re-admission
        replays prompt + outputs to rebuild the cache)."""
        seq = self.running.pop(slot)
        self.pool.free_seq(slot)
        req = seq.request
        req.state = RequestState.QUEUED
        req.preemptions += 1
        req.queued_time = time.perf_counter()  # its next wait starts now
        self.preemptions += 1
        self._c_preempt.inc()
        self.queue.push_front(req)
        return req

    def finish(self, slot: int) -> Request:
        """Retire a sequence and free its row + blocks for the next one."""
        seq = self.running.pop(slot)
        seq.request.state = RequestState.FINISHED
        self.pool.free_seq(slot)
        return seq.request
