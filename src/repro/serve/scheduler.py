"""Iteration-level scheduler: which sequence runs in which slot, when.

Continuous batching à la Orca/vLLM, specialized to ReLeQ serving: every
engine step the scheduler (1) admits queued requests into free slots —
*admissions happen mid-decode*, the running sequences never stop — and
(2) reports the set of running sequences to pack into the next jit'd
decode step.  Finished sequences release their slot in the same step, so
a drained slot is refillable on the next iteration.

The scheduler owns the bookkeeping (queue, slot pool, running table) and
makes no model calls — the engine turns its decisions into prefill/decode
launches.  Keeping the policy host-side means the device-side decode step
stays a single fixed-shape executable regardless of traffic.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.serve.cache import SlotCachePool
from repro.serve.queue import AdmissionQueue
from repro.serve.request import Request, RequestState


@dataclass
class RunningSeq:
    """One admitted sequence: its request and the token to feed next."""

    request: Request
    slot: int
    last_token: int


class ContinuousScheduler:
    def __init__(self, pool: SlotCachePool, queue: AdmissionQueue):
        self.pool = pool
        self.queue = queue
        self.running: dict[int, RunningSeq] = {}  # slot -> sequence

    # ------------------------------------------------------------------
    @property
    def num_running(self) -> int:
        return len(self.running)

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    def admissions(self) -> list[tuple[Request, int]]:
        """Pop queued requests into free slots (FIFO, one slot each)."""
        admitted = []
        while self.queue and self.pool.num_free:
            req = self.queue.pop()
            admitted.append((req, self.pool.alloc()))
        return admitted

    def start(self, request: Request, slot: int, first_token: int) -> None:
        """Register a prefilled sequence as running."""
        request.state = RequestState.RUNNING
        self.running[slot] = RunningSeq(request, slot, first_token)

    def advance(self, slot: int, token: int) -> None:
        self.running[slot].last_token = token

    def finish(self, slot: int) -> Request:
        """Retire a sequence and free its slot for the next admission."""
        seq = self.running.pop(slot)
        seq.request.state = RequestState.FINISHED
        self.pool.free(slot)
        return seq.request
