"""Admission queue: FIFO of submitted-but-not-yet-scheduled requests.

Deliberately minimal — ordering policy (FIFO) is the only decision made
here; *when* to pop is the scheduler's call.  ``max_pending`` gives the
engine backpressure: ``submit`` on a full queue raises instead of letting
an open-ended producer grow host memory without bound.
"""
from __future__ import annotations

from collections import deque

from repro.serve.request import Request


class AdmissionQueue:
    def __init__(self, max_pending: int = 0):
        """``max_pending = 0`` means unbounded."""
        self.max_pending = max_pending
        self._q: deque[Request] = deque()

    def push(self, request: Request) -> None:
        if self.max_pending and len(self._q) >= self.max_pending:
            raise RuntimeError(
                f"admission queue full ({self.max_pending} pending)")
        self._q.append(request)

    def push_front(self, request: Request) -> None:
        """Requeue at the head (preempted sequences re-admit first; no
        backpressure check — the request was already admitted once)."""
        self._q.appendleft(request)

    def pop(self) -> Request:
        return self._q.popleft()

    def peek(self) -> Request | None:
        return self._q[0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
