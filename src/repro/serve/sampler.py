"""On-device fused sampling for the serve hotpath.

The host sampling oracle (``request.select_token``) forces every decode
step to fetch a full ``(num_slots, V)`` logits matrix, cast it to
float64, and loop over rows in Python — on the measured PR 8 traces that
host tail is the largest single cost in the decode step.  This module
moves the whole selection onto the device as ONE jitted call so the
engine fetches a ``(num_slots,) int32`` token vector instead:

- **greedy** (``temperature <= 0``, the parity-critical default) is
  ``jnp.argmax`` over the logits row.  The host oracle argmaxes the same
  row after an ``np.float64`` cast; the cast is monotone and injective,
  and both argmaxes break ties toward the first index, so the device
  token is *bitwise identical* to ``Request.select_token`` (gated in
  tests/test_sampler_device.py across all model families).
- **temperature / top-k / top-p** mirror ``request.warp_probs``: divide
  by temperature, mask below the k-th largest logit, softmax, keep the
  nucleus whose mass reaches ``top_p``, then draw by inverse CDF.  Both
  truncations are SORT-FREE: XLA's CPU sort costs milliseconds per
  ``(rows, V)`` batch — an order of magnitude more than the entire rest
  of the step — so the k-th order statistic and the nucleus probability
  cut are found by 32-step bisection over the *uint32 sortable key*
  space (IEEE floats bitcast to integers compare consistently), which is
  exact, O(V) per step, and branch-free.  Tie semantics at the cut are
  *tie-complete*: every token equal to the threshold survives — same as
  the host's top-k rule; the host nucleus cuts mid-tie in stable order
  instead, a measure-zero difference that only shows on exactly-tied
  probabilities.  The draw consumes one uniform from a *threefry* stream
  keyed by folding (seed, request_id, position, kind) into a
  ``jax.random`` key — the device-side analogue of the host path's
  ``SeedSequence((seed, request_id, position, kind))`` Philox stream.
  The value drawn at a position is a pure function of the request
  identity, so device sampling is batch-composition- and
  pipeline-invariant exactly like the host path (hypothesis-gated).
  The two streams are *different* PRNGs, so sampled (not greedy) tokens
  differ draw-for-draw from host sampling while remaining exactly
  distributed per the warped probabilities (chi-square gated).

Inactive rows (slot not running) carry ``temperature = 0`` in the packed
parameter arrays and reduce to a cheap argmax — no NaNs, no branches.

``sample_rows`` is a module-level jit shared by every engine in the
process (like the pool's splice/COW helpers), so a warmed executable
serves all engines and per-engine recompile detection sees zero growth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# draw-kind namespace shared with repro.spec.sampler: the baseline token
# draw is kind 0 there too, so one (request, position) never reuses a
# stream across the plain and speculative paths
KIND_TOKEN = 0


def _stream_key(seed, rid, position):
    """Per-(request, position, kind) threefry key: fold the identity into
    the seed one field at a time (order matters and is part of the stream
    schema — documented in docs/metrics.md)."""
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, rid)
    key = jax.random.fold_in(key, position)
    return jax.random.fold_in(key, KIND_TOKEN)


def _sort_key(x):
    """float32 -> uint32 key with the float's ordering (IEEE totally
    ordered under the sign-flip bitcast trick; -inf lowest)."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jnp.where(b >> 31 == 1, ~b, b | jnp.uint32(0x80000000))


def _bisect_threshold(keys, good):
    """Largest uint32 ``t`` with ``good(count-or-mass of keys >= t)``
    still true, by 32-step integer bisection — ``good`` must be monotone
    non-increasing in ``t`` and true at ``t = 0``.  Exact: the key space
    is integral, so 32 halvings pin the threshold bit-for-bit."""
    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2 + (hi - lo) % 2  # upper mid, no overflow
        ok = good(keys >= mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)
    lo, _ = jax.lax.fori_loop(
        0, 32, body, (jnp.uint32(0), jnp.uint32(0xFFFFFFFF)))
    return lo


def _sample_row(logits, temp, top_k, top_p, seed, rid, position):
    """One row: (V,) logits -> int32 token."""
    v = logits.shape[-1]
    f = logits.astype(jnp.float32)
    # greedy: argmax with first-index tie-breaking == host oracle
    greedy = jnp.argmax(f).astype(jnp.int32)

    # warped distribution (f32 mirror of request.warp_probs; temp <= 0
    # rows compute it with t = 1 purely to stay finite — the final
    # select ignores the result)
    t = jnp.where(temp > 0.0, temp, jnp.float32(1.0))
    z = f / t
    # top-k: keep everything >= the k-th largest (tie-complete, the host
    # rule); the order statistic comes from key bisection, not a sort.
    # top_k == 0 disables by degenerating to k = V (threshold = min)
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    zkeys = _sort_key(z)
    kth = _bisect_threshold(zkeys, lambda m: m.sum() >= k)
    z = jnp.where(zkeys < kth, -jnp.inf, z)
    z = z - z.max()
    p = jnp.exp(z)
    p = p / p.sum()
    # top-p nucleus: the highest probability cut whose tail mass still
    # reaches top_p (tie-complete at the cut; ties aside this keeps the
    # same set as the host's stable-sorted prefix).  Bisection again —
    # the target is relative to the realized f32 total, so top_p = 1.0
    # keeps everything even when the float sum lands just under 1
    pkeys = _sort_key(p)
    target = top_p * p.sum()
    pcut = _bisect_threshold(
        pkeys, lambda m: jnp.where(m, p, 0.0).sum() >= target)
    p = jnp.where((top_p < 1.0) & (pkeys < pcut), 0.0, p)
    # inverse-CDF draw from the per-(request, position, kind) stream;
    # scaling u by the total mass keeps the draw in range under f32
    # cumsum error, and side="right" skips zero-probability tokens
    u = jax.random.uniform(_stream_key(seed, rid, position),
                           dtype=jnp.float32)
    cdf = jnp.cumsum(p)
    drawn = jnp.searchsorted(cdf, u * cdf[-1], side="right")
    drawn = jnp.clip(drawn, 0, v - 1).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, drawn)


_sample_rows_impl = jax.vmap(_sample_row, in_axes=(0, 0, 0, 0, 0, 0, 0))

# ONE executable for any traffic mix: every argument is data, the only
# shape is (num_slots, V) / (num_slots,) — engines share this jit like
# they share decode_fn, so the executable-count pins stay 1 prefill +
# 1 decode (+ this sampler, tracked separately by _note_exec)
sample_rows = jax.jit(_sample_rows_impl)


def row_arrays(num_slots: int, rows) -> tuple[np.ndarray, ...]:
    """Pack per-row sampling parameters for ``sample_rows``.

    ``rows`` yields ``(slot, request)`` pairs for the running sequences;
    idle slots default to greedy (temperature 0) so their lanes stay
    NaN-free and cheap.  The engine uploads the result once per batch
    composition, not per step."""
    temps = np.zeros((num_slots,), np.float32)
    top_ks = np.zeros((num_slots,), np.int32)
    top_ps = np.ones((num_slots,), np.float32)
    seeds = np.zeros((num_slots,), np.uint32)
    rids = np.zeros((num_slots,), np.int32)
    for slot, req in rows:
        s = req.sampling
        temps[slot] = s.temperature
        top_ks[slot] = s.top_k
        top_ps[slot] = s.top_p
        seeds[slot] = np.uint32(s.seed & 0xFFFFFFFF)
        rids[slot] = req.request_id
    return temps, top_ks, top_ps, seeds, rids
