from repro.optim.adamw import AdamW, cosine_schedule, linear_schedule  # noqa: F401
