"""AdamW from scratch (no optax offline), with optional int8 moments.

The int8 variant stores both Adam moments as block-wise int8 ``QTensor``s
(quant/int8_opt.py) — 4× less state memory, which is what lets the
llama4-maverick-400b optimizer state fit a 256-chip v5e pod (DESIGN.md §4).
Moments are dequantized, updated, and requantized inside the jit'd step;
the requantization error acts like tiny gradient noise (8-bit Adam).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.quant.int8_opt import (
    QTensor,
    dequantize_state,
    dequantize_state_sq,
    quantize_state,
    quantize_state_sq,
)


def cosine_schedule(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, base_lr * (1 - t))
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


@dataclass(frozen=True)
class AdamW:
    lr: object = 1e-3                 # float or schedule fn(step)->lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0
    moments: str = "fp32"             # fp32 | int8
    sequential: bool | None = None    # barrier-chain per-leaf updates
    # (default: True for int8 moments — otherwise the scheduler may hold
    # every leaf's dequantized f32 moment live at once: ~25 GB of transient
    # at llama4-400B scale; EXPERIMENTS.md §Perf)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def init(self, params):
        def zeros(q):
            z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            return jax.tree.map(q, z) if self.moments == "int8" else z
        return {"m": zeros(quantize_state), "v": zeros(quantize_state_sq),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, opt):
        step = opt["step"] + 1
        lr = self._lr(step)
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        int8 = self.moments == "int8"
        if int8:
            deq_m, deq_v = dequantize_state, dequantize_state_sq
            req_m, req_v = quantize_state, quantize_state_sq
        else:
            deq_m = deq_v = req_m = req_v = lambda t: t
        c1 = 1 - self.b1 ** step.astype(jnp.float32)
        c2 = 1 - self.b2 ** step.astype(jnp.float32)

        def leaf_update(p, g, mm_q, vv_q):
            gf = g.astype(jnp.float32)
            mm = self.b1 * deq_m(mm_q) + (1 - self.b1) * gf
            vv = self.b2 * deq_v(vv_q) + (1 - self.b2) * jnp.square(gf)
            u = (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, req_m(mm), req_v(vv)

        sequential = self.sequential if self.sequential is not None else int8
        is_q = lambda x: isinstance(x, QTensor)
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        m_leaves = jax.tree.leaves(opt["m"], is_leaf=is_q)
        v_leaves = jax.tree.leaves(opt["v"], is_leaf=is_q)
        new_p, new_m, new_v = [], [], []
        gate = None
        for p, g, mm, vv in zip(p_leaves, g_leaves, m_leaves, v_leaves):
            if sequential and gate is not None:
                # barrier: leaf i+1's update may not start before leaf i's
                # f32 transients die — bounds peak at ~one leaf, not the tree
                p, gate = jax.lax.optimization_barrier((p, gate))
            np_, nm_, nv_ = leaf_update(p, g, mm, vv)
            gate = np_
            new_p.append(np_)
            new_m.append(nm_)
            new_v.append(nv_)
        m_def = jax.tree_util.tree_structure(opt["m"], is_leaf=is_q)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"m": jax.tree_util.tree_unflatten(m_def, new_m),
                 "v": jax.tree_util.tree_unflatten(m_def, new_v),
                 "step": step})
