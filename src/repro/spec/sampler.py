"""Distribution-exact rejection sampling for speculative windows.

Pure host-side math (numpy, no jax): given the draft's k proposed tokens
and the target's logits at all k + 1 positions (one batched
``verify_chunk`` call), decide how many proposals survive and what to
emit.  The classic speculative-sampling argument applies per position:

    accept d ~ q with probability min(1, p(d) / q(d));
    on rejection emit a draw from the residual norm(max(p - q, 0)).

The emitted token is then *exactly* distributed as p — for ANY proposal
q — so speculation changes throughput, never the served distribution
(chi-square-pinned in tests/test_spec.py).  Greedy (temperature 0) is
the degenerate case: accept while the draft token equals the target
argmax, emit the target argmax at the first disagreement — which makes
greedy spec output token-identical to non-spec decode, the engine parity
gate.

Randomness is drawn through the request's per-position streams
(``Request.rng_for``): each (output position, draw kind) pair is an
independent deterministic stream, so results are invariant to batch
composition and to how positions are grouped into windows.
"""
from __future__ import annotations

import numpy as np

from repro.serve.request import SamplingParams, warp_probs

# Draw kinds for Request.rng_for — one independent stream per decision a
# speculative step can make at a given output position.
KIND_TOKEN = 0      # baseline token draw (also the bonus token)
KIND_DRAFT = 1      # draft proposal draw
KIND_ACCEPT = 2     # accept/reject uniform
KIND_RESIDUAL = 3   # residual draw after a rejection


def draft_token(logits: np.ndarray, sampling: SamplingParams,
                rng: np.random.Generator) -> tuple[int, np.ndarray | None]:
    """Draw one draft proposal; -> (token, warped q or None for greedy).

    The draft warps with the SAME sampling params as the target — the
    accept ratio p(d)/q(d) is only meaningful when both sides went
    through identical temperature/top-k/top-p shaping.
    """
    q = warp_probs(logits, sampling)
    if q is None:
        return int(np.argmax(np.asarray(logits, np.float64).reshape(-1))), None
    return int(rng.choice(q.size, p=q)), q


def greedy_window(draft_tokens, target_tops) -> tuple[list[int], int]:
    """Resolve one all-greedy window from PRE-COMPUTED target argmaxes;
    -> (emitted tokens, num accepted).

    Equivalent to :func:`spec_window` when every request in the batch is
    greedy (pinned in tests/test_sampler_device.py) — but it only needs the
    verifier's ``(k + 1,)`` int32 argmax row, not the ``(k + 1, V)``
    logits, which is what lets the engine's device-sampling fast path
    fetch accepted-token vectors instead of the full logits tensor.
    ``target_tops[j]`` must be the argmax of the target's position-``j``
    logits row (computed on device with the same first-index
    tie-breaking as the host oracle)."""
    emitted: list[int] = []
    accepted = 0
    for j, d in enumerate(draft_tokens):
        top = int(target_tops[j])
        if int(d) == top:
            emitted.append(top)
            accepted += 1
            continue
        emitted.append(top)
        return emitted, accepted
    emitted.append(int(target_tops[len(draft_tokens)]))
    return emitted, accepted


def spec_window(draft_tokens, target_logits, sampling: SamplingParams,
                rng_for, *, base_pos: int,
                q_probs=None) -> tuple[list[int], int]:
    """Resolve one speculative window; -> (emitted tokens, num accepted).

    - ``draft_tokens``: the k proposals, in order.
    - ``target_logits``: (k + 1, V) — row j is the target's distribution
      for output position ``base_pos + j`` (the verifier's all-position
      logits; row k is the "bonus" position past the last proposal).
    - ``rng_for(position, kind)``: per-position stream factory
      (:meth:`repro.serve.request.Request.rng_for`).
    - ``base_pos``: output index of the first token this window emits.
    - ``q_probs``: the draft's warped distributions, one per proposal
      (None entries / None list => greedy draft).

    Always emits at least one token (k = 0 degenerates to plain decode
    from row 0).  On full acceptance the bonus token is drawn from row k
    with the SAME stream plain decode would use at that position.
    """
    k = len(draft_tokens)
    emitted: list[int] = []
    accepted = 0
    for j in range(k):
        p = warp_probs(target_logits[j], sampling)
        d = int(draft_tokens[j])
        pos = base_pos + j
        if p is None:  # greedy: accept iff the draft matches the argmax
            top = int(np.argmax(
                np.asarray(target_logits[j], np.float64).reshape(-1)))
            if d == top:
                emitted.append(d)
                accepted += 1
                continue
            emitted.append(top)
            return emitted, accepted
        q = None if q_probs is None else q_probs[j]
        if q is None:
            # greedy draft under a sampled target: a point mass at d
            ratio = p[d]
        else:
            ratio = 1.0 if q[d] <= 0.0 else p[d] / q[d]
        if rng_for(pos, KIND_ACCEPT).random() < ratio:
            emitted.append(d)
            accepted += 1
            continue
        if q is None:  # point-mass proposal: residual is p with d removed
            resid = p.copy()
            resid[d] = 0.0
        else:
            resid = np.maximum(p - q, 0.0)
        s = resid.sum()
        if s <= 0.0:  # q covers p exactly at this position: any p-draw
            emitted.append(int(rng_for(pos, KIND_RESIDUAL)
                               .choice(p.size, p=p)))
        else:
            emitted.append(int(rng_for(pos, KIND_RESIDUAL)
                               .choice(p.size, p=resid / s)))
        return emitted, accepted
    # every proposal survived: bonus token from the k-th target row
    p = warp_probs(target_logits[k], sampling)
    if p is None:
        emitted.append(int(np.argmax(
            np.asarray(target_logits[k], np.float64).reshape(-1))))
    else:
        emitted.append(int(rng_for(base_pos + k, KIND_TOKEN)
                           .choice(p.size, p=p)))
    return emitted, accepted
