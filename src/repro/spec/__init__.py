"""repro.spec — speculative decoding with a quantized self-draft.

ReLeQ's Pareto archive already holds cheap, accurate *drafts of the same
weights* for free: a low-bit policy is the target model with fewer
bitplanes streamed per matmul.  This subsystem turns that frontier into
a speculative decoder over the existing paged serving stack:

- :mod:`repro.spec.config` — :class:`SpecConfig`, the
  ``ServeEngine(spec=...)`` knob (window k + how to derive the draft).
- :mod:`repro.spec.draft` — :func:`low_bit_view` (re-pack the target's
  packed weights at fewer planes; everything else shared by reference),
  :class:`DraftSelector` (pick a draft policy off a ``ParetoArchive``
  frontier), :func:`snap_params_to_grid` (controlled-acceptance
  experiments).
- :mod:`repro.spec.sampler` — the distribution-exact rejection sampler
  resolving each window on the host (greedy degenerates to token-exact
  parity with plain decode).

The drafter and verifier live in the engine/models: the draft rolls k
tokens through the same jit'd ``decode_step`` (its ``Packed`` leaves
carry static bits, so draft and target are two executables under one
wrapper) writing into the SAME ``PagedCachePool`` blocks the target
owns — speculation allocates zero extra KV — and the target then scores
all k + 1 positions of every row in ONE batched ``verify_chunk`` call
through the fixed-shape chunked-prefill path.
"""
from repro.spec.config import SpecConfig
from repro.spec.draft import DraftSelector, low_bit_view, snap_params_to_grid
from repro.spec.sampler import (
    KIND_ACCEPT,
    KIND_DRAFT,
    KIND_RESIDUAL,
    KIND_TOKEN,
    draft_token,
    spec_window,
)

__all__ = [
    "SpecConfig",
    "DraftSelector",
    "low_bit_view",
    "snap_params_to_grid",
    "KIND_ACCEPT",
    "KIND_DRAFT",
    "KIND_RESIDUAL",
    "KIND_TOKEN",
    "draft_token",
    "spec_window",
]
