"""Configuration for quantized self-draft speculative decoding."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.quant.policy import QuantPolicy


@dataclass(frozen=True)
class SpecConfig:
    """Knobs for :class:`~repro.serve.engine.ServeEngine` speculation.

    Exactly the paper-native configuration surface: the draft is the SAME
    weights at a lower-bit policy (ReLeQ's frontier supplies it), so a
    draft is specified by *bitwidths*, not by a second model.

    - ``k``: speculative window — tokens the draft rolls per engine step;
      the verifier scores all ``k + 1`` positions in one batched call.
    - ``draft_bits``: uniform draft bitwidth; the engine derives the draft
      via :func:`repro.spec.draft.low_bit_view` (frozen-at-8 groups such
      as ``lm_head`` stay at 8, exactly like a searched policy would).
    - ``draft_policy``: full per-group :class:`QuantPolicy` for the draft
      (e.g. a :class:`~repro.spec.draft.DraftSelector` pick off the
      Pareto archive).  Overrides ``draft_bits``.
    - ``draft_sparams``: pre-packed serving params for the draft.  Skips
      derivation entirely; caller owns layout compatibility.  Overrides
      both of the above.
    """

    k: int = 4
    draft_bits: int | None = None
    draft_policy: QuantPolicy | None = None
    draft_sparams: Any = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec window k must be >= 1, got {self.k}")
        if (self.draft_bits is None and self.draft_policy is None
                and self.draft_sparams is None):
            raise ValueError(
                "SpecConfig needs a draft: draft_bits, draft_policy, or "
                "draft_sparams")
        if self.draft_bits is not None and not 2 <= self.draft_bits <= 8:
            raise ValueError(
                f"draft_bits must be in 2..8, got {self.draft_bits}")
