"""Draft construction: low-bit views of packed weights + archive picks.

The draft model of ``repro.spec`` is never a second network — it is the
target's own bitplane-packed weights re-packed at fewer planes
(:func:`repro.quant.pack.repack_weight`), so decode HBM traffic drops
with the plane count while every non-weight tensor (norms, routers,
decay LoRA, caches) is *shared by construction*.  Two entry points:

- :func:`low_bit_view` — serving params -> draft serving params under a
  uniform ``bits`` or a full per-group policy.
- :class:`DraftSelector` — pick a draft policy off a
  :class:`~repro.autotune.archive.ParetoArchive` frontier: among entries
  whose relative accuracy clears ``acc_floor`` (a proxy for acceptance
  rate — the draft only pays off when it usually agrees with the
  target), take the cheapest by average bits.

:func:`snap_params_to_grid` supports controlled experiments: projecting
training weights onto the ``bits`` uniform grid makes the low-bit
re-pack lossless (grid levels are exactly representable at 8 bits too),
so draft/target agreement — and hence acceptance — approaches 1 while
the draft still streams ``bits``-plane traffic.  The spec benchmark uses
it to isolate the *mechanical* speedup ceiling from draft quality.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.autotune.archive import ArchiveEntry, ParetoArchive
from repro.quant.pack import Packed, dequant_packed, pack_weight, repack_weight
from repro.quant.policy import QuantPolicy
from repro.quant.qat import get_by_path, policy_for, set_by_path


def low_bit_view(model, sparams, bits: int | None = None,
                 policy: QuantPolicy | None = None):
    """Serving params -> draft serving params at a lower-bit policy.

    Walks the model's quant groups through the *serving* layout (per-layer
    lists) and re-packs each :class:`Packed` leaf at the policy's
    bitwidth; dense/QDQ leaves (norms, embeddings — a gather, no matmul
    traffic to save) pass through by reference.  With ``bits`` given, the
    policy is ``policy_for(model, bits)`` — frozen-at-8 groups keep their
    8 planes, mirroring what any searched policy would serve.  Re-packing
    to >= the current plane count is a no-op (never "up-quantize"), so the
    view is monotone: the draft is at most as wide as the target.
    """
    if policy is None:
        if bits is None:
            raise ValueError("low_bit_view needs bits or a policy")
        policy = policy_for(model, bits)

    blocks = sparams["blocks"]
    nested = bool(blocks) and isinstance(blocks[0], list)
    nb = [list(sub) for sub in blocks] if nested else list(blocks)
    out = dict(sparams)
    for g in model.quant_groups():
        want = policy.get(g.name)
        if g.path[0] == "blocks":
            if nested:
                sub, rest = g.path[1], g.path[2:]
                tree = nb[sub][g.layer]
            else:
                rest, tree = g.path[1:], nb[g.layer]
            leaf = get_by_path(tree, rest)
            if isinstance(leaf, Packed) and want < leaf.bits:
                tree = set_by_path(tree, rest, repack_weight(leaf, want))
                if nested:
                    nb[sub][g.layer] = tree
                else:
                    nb[g.layer] = tree
        elif g.path == ("lm_head",):
            head = out["lm_head"]
            if isinstance(head, Packed) and want < head.bits:
                out["lm_head"] = repack_weight(head, want)
    out["blocks"] = nb
    return out


@dataclass(frozen=True)
class DraftSelector:
    """Pick a quantized self-draft policy off the Pareto frontier.

    ``acc_floor`` gates on relative accuracy (entries that disagree with
    the fp model rarely agree with the 8-bit target either);
    ``max_avg_bits`` optionally caps draft width (a 7-bit "draft" saves
    almost no traffic).  Among survivors the *cheapest* entry wins
    (lowest average bits, ties to higher accuracy): draft cost scales
    with plane count, and acceptance differences above the floor are
    second-order next to a 4x traffic cut.
    """

    acc_floor: float = 0.95
    max_avg_bits: float | None = None

    def candidates(self, archive: ParetoArchive) -> list[ArchiveEntry]:
        out = []
        for e in archive.entries():
            if e.acc < self.acc_floor:
                continue
            avg = _avg_bits(e)
            if self.max_avg_bits is not None and avg > self.max_avg_bits:
                continue
            out.append(e)
        return out

    def select(self, archive: ParetoArchive) -> ArchiveEntry | None:
        """Cheapest sufficiently-accurate entry, or None (empty/too
        strict — caller falls back to a uniform ``draft_bits``)."""
        cands = self.candidates(archive)
        if not cands:
            return None
        return min(cands, key=lambda e: (_avg_bits(e), -e.acc, e.bits))

    def policy(self, model, archive: ParetoArchive) -> QuantPolicy | None:
        """Archive -> draft QuantPolicy aligned with ``model``'s groups."""
        from repro.autotune.deploy import policy_from_entry

        entry = self.select(archive)
        if entry is None:
            return None
        return policy_from_entry(model, entry)


def _avg_bits(entry: ArchiveEntry) -> float:
    bits = [b for _, b in entry.bits]
    return sum(bits) / max(len(bits), 1)


def _roundtrip(w, bits: int):
    if w.ndim > 2:  # stacked layers / expert banks: recurse per slice
        return jax.vmap(lambda m: _roundtrip(m, bits))(w)
    planes, scale = pack_weight(w.astype(jnp.float32), bits)
    return dequant_packed(planes, scale, bits).astype(w.dtype)


def snap_params_to_grid(model, params, bits: int):
    """Project training params onto the ``bits`` quantization grid.

    Every *searchable* quant group is round-tripped through
    pack -> dequant at ``bits``, so subsequent packing at ``bits`` or
    wider reconstructs the weights near-exactly — the
    controlled-acceptance regime the spec benchmark measures its speedup
    ceiling in.  Frozen groups are skipped: the draft's low-bit view
    never re-packs them (re-pack to >= current bits is a no-op), so they
    are bit-identical between draft and target already.  Non-group
    leaves are untouched.
    """
    frozen = model.frozen_bits()
    out = params
    seen: set[tuple] = set()
    for g in model.quant_groups():
        # stacked training layouts share one leaf across layers (the path
        # has no layer index) — round-trip each leaf exactly once
        if g.path in seen or g.name in frozen:
            continue
        seen.add(g.path)
        leaf = get_by_path(out, g.path)
        out = set_by_path(out, g.path, _roundtrip(leaf, bits))
    return out
