"""Structured, rate-limited logging for long-running services.

Replaces the ad-hoc ``print`` progress lines in the autotune service and
the lockstep search with loggers that

- emit one *event* with typed fields (``log.event("episode", reward=r,
  acc=a)``) instead of a pre-formatted string,
- render either human text (default) or one JSON object per line
  (``configure(json_mode=True)`` — the launchers' ``--log-json`` flag),
- rate-limit per event name (``min_interval_s``): a tight serve loop can
  call ``event()`` every step and the sink sees at most one line per
  interval, with a ``suppressed`` count carried on the next emitted line
  so nothing disappears silently.

Zero-dependency by design: the sink is a writable stream (stdout), not a
logging framework — services stay importable anywhere the repo runs.
"""
from __future__ import annotations

import json
import sys
import threading
import time

_config_lock = threading.Lock()
_json_mode = False
_loggers: dict[str, "StructuredLogger"] = {}


def configure(json_mode: bool = False) -> None:
    """Process-wide output format: human text or JSON lines."""
    global _json_mode
    with _config_lock:
        _json_mode = bool(json_mode)


def json_mode() -> bool:
    with _config_lock:
        return _json_mode


def get_logger(name: str, *, min_interval_s: float = 0.0,
               stream=None) -> "StructuredLogger":
    """Process-shared logger per name (same-name call sites interleave
    into one rate-limit budget)."""
    with _config_lock:
        lg = _loggers.get(name)
        if lg is None:
            lg = _loggers[name] = StructuredLogger(
                name, min_interval_s=min_interval_s, stream=stream)
        return lg


class StructuredLogger:
    def __init__(self, name: str, *, min_interval_s: float = 0.0,
                 stream=None):
        self.name = name
        self.min_interval_s = float(min_interval_s)
        self.stream = stream
        self._lock = threading.Lock()
        self._last_emit: dict[str, float] = {}
        self._suppressed: dict[str, int] = {}
        self.emitted = 0

    def _out(self):
        return self.stream if self.stream is not None else sys.stdout

    def event(self, event: str, *, force: bool = False, **fields) -> bool:
        """Log one event.  Returns True iff a line was written (False =
        rate-limited; the drop is counted and reported on the next
        emitted line of the same event as ``suppressed=N``)."""
        now = time.monotonic()
        with self._lock:
            last = self._last_emit.get(event)
            if (not force and self.min_interval_s > 0 and last is not None
                    and now - last < self.min_interval_s):
                self._suppressed[event] = self._suppressed.get(event, 0) + 1
                return False
            self._last_emit[event] = now
            suppressed = self._suppressed.pop(event, 0)
            self.emitted += 1
        if suppressed:
            fields = {**fields, "suppressed": suppressed}
        if json_mode():
            rec = {"ts": round(time.time(), 3), "logger": self.name,
                   "event": event, **fields}
            line = json.dumps(rec, default=str)
        else:
            body = " ".join(f"{k}={_fmt(v)}" for k, v in fields.items())
            line = f"[{self.name}] {event} {body}".rstrip()
        print(line, file=self._out(), flush=True)
        return True


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
