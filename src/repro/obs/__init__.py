"""repro.obs: zero-dependency observability for the search->serve pipeline.

Three small, composable layers (stdlib + numpy only):

- :mod:`repro.obs.core` — a thread-safe :class:`Registry` of typed
  instruments (:class:`Counter`, :class:`Gauge`, :class:`Histogram` with
  fixed buckets + optional exact sliding window for p50/p99) whose
  ``snapshot()`` is one JSON-safe dict, plus :func:`run_provenance`
  (git sha / timestamp / jax version / device count) for benchmark
  records;
- :mod:`repro.obs.trace` — a bounded ring-buffer span :class:`Tracer`
  (``span()`` context manager, ``instant()`` events, ``complete()`` for
  retro-dated durations) that is near-zero cost when disabled and
  exports Chrome-trace / Perfetto JSON;
- :mod:`repro.obs.log` — rate-limited structured logging
  (:func:`get_logger`, ``--log-json`` on the launchers switches every
  logger to one-JSON-object-per-line via :func:`configure`).

The serving engine (``serve/engine.py``), scheduler, paged pool, and the
autotune service all take ``registry=`` / ``tracer=`` and default to
private, disabled instances — instrumentation costs nothing unless a
caller opts in (gated at <= 3% tokens/s in ``benchmarks/serve_bench.py``).
See ``docs/metrics.md`` for the full metric / trace-event reference.
"""
from repro.obs.core import (
    Counter,
    Gauge,
    Histogram,
    Registry,
    run_provenance,
)
from repro.obs.log import StructuredLogger, configure, get_logger
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "run_provenance",
    "Tracer",
    "NULL_TRACER",
    "StructuredLogger",
    "configure",
    "get_logger",
]
