"""Metrics registry: typed, thread-safe instruments + JSON snapshots.

Three instrument kinds cover everything the pipeline reports:

- :class:`Counter` — monotone float/int accumulator (``inc``).  Tokens
  emitted, prefill launches, COW copies, recompiles, preemptions.
- :class:`Gauge` — last-write-wins level (``set``).  Queue depth,
  running rows, free blocks, archive size.
- :class:`Histogram` — fixed-boundary bucket counts plus, when
  ``window=N`` is given, an exact bounded sample window whose
  ``percentile()`` reproduces ``np.percentile`` over the last ``N``
  observations — the same ``metrics_window`` semantics the serve
  engine's latency deques always had, so rebuilding
  ``ServeEngine.metrics()`` on the registry is value-identical, not
  just key-compatible.

Every instrument carries its own lock (observations are a few
nanoseconds of lock + float add, far below the 3% tracing-overhead gate
in ``benchmarks/serve_bench.py``), and :meth:`Registry.snapshot` walks a
consistent copy of the instrument table so concurrent evaluator threads
never tear a read (property-tested in ``tests/test_obs.py``).

:func:`run_provenance` is the benchmark-record stamp: git sha,
UTC timestamp, jax version, device count/platform, optional mesh shape —
what makes a ``BENCH_*.json`` perf number interpretable across PRs.
"""
from __future__ import annotations

import threading
from collections import deque

import numpy as np

# log-spaced latency boundaries (seconds): 10us .. 10s covers a chunked
# prefill on a smoke model through a cold multi-second drive
DEFAULT_TIME_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


class _Instrument:
    """Shared name/unit/desc plumbing; one lock per instrument."""

    kind = "instrument"

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        self.name = name
        self.unit = unit
        self.desc = desc
        self._lock = threading.Lock()

    def _meta(self) -> dict:
        out: dict = {"type": self.kind}
        if self.unit:
            out["unit"] = self.unit
        if self.desc:
            out["desc"] = self.desc
        return out


class Counter(_Instrument):
    """Monotone accumulator.  ``inc`` rejects negative deltas — a counter
    that can go down is a :class:`Gauge` wearing the wrong type."""

    kind = "counter"

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        super().__init__(name, unit, desc)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {**self._meta(), "value": self.value}


class Gauge(_Instrument):
    """Last-write-wins level; ``add`` for +/- deltas on shared levels."""

    kind = "gauge"

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        super().__init__(name, unit, desc)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {**self._meta(), "value": self.value}


class Histogram(_Instrument):
    """Fixed-boundary bucket counts + count/sum/min/max, and optionally
    an exact sample window.

    ``buckets`` are upper boundaries (``le``); an implicit +inf bucket
    catches the tail.  With ``window=N`` the last ``N`` raw samples are
    kept in a ring and :meth:`percentile` is exact over them
    (``np.percentile``); without a window, percentiles interpolate
    linearly inside the matching bucket — cheap and bounded-memory for
    unbounded streams.
    """

    kind = "histogram"

    def __init__(self, name: str, unit: str = "", desc: str = "",
                 buckets=DEFAULT_TIME_BUCKETS, window: int | None = None):
        super().__init__(name, unit, desc)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError(f"histogram {self.name}: empty buckets")
        if window is not None and window < 1:
            raise ValueError(f"histogram {self.name}: window must be >= 1")
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # + the +inf tail bucket
        self._count = 0
        self._sum = 0.0
        self._min = np.inf
        self._max = -np.inf
        self.window = window
        self._samples: deque | None = (deque(maxlen=window)
                                       if window is not None else None)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            self._counts[np.searchsorted(self.bounds, v, side="left")] += 1
            if self._samples is not None:
                self._samples.append(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def samples(self) -> list[float]:
        """The current window (empty list when windowless)."""
        with self._lock:
            return list(self._samples) if self._samples is not None else []

    def window_sum(self) -> float:
        with self._lock:
            return float(sum(self._samples)) if self._samples else 0.0

    def window_mean(self) -> float:
        with self._lock:
            if not self._samples:
                return 0.0
            return float(sum(self._samples) / len(self._samples))

    def percentile(self, q: float) -> float:
        """Exact over the sample window; bucket-interpolated otherwise."""
        with self._lock:
            if self._samples:
                return float(np.percentile(np.asarray(self._samples), q))
            if not self._count:
                return 0.0
            # cumulative walk to the q-th observation, linear inside the
            # bucket; the open tail bucket reports the observed max
            target = self._count * q / 100.0
            cum = 0
            for i, n in enumerate(self._counts):
                if cum + n >= target and n:
                    if i == len(self.bounds):
                        return float(self._max)
                    lo = self.bounds[i - 1] if i else min(self._min, self.bounds[i])
                    hi = self.bounds[i]
                    frac = (target - cum) / n
                    return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))
                cum += n
            return float(self._max)

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                **self._meta(),
                "count": self._count,
                "sum": self._sum,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None,
                "buckets": {
                    **{str(b): c for b, c in zip(self.bounds, self._counts)},
                    "+inf": self._counts[-1],
                },
            }
            if self.window is not None:
                out["window"] = self.window
        if self._count:
            out["p50"] = self.percentile(50)
            out["p99"] = self.percentile(99)
        return out


class Registry:
    """Thread-safe name -> instrument table with get-or-create access.

    ``counter`` / ``gauge`` / ``histogram`` return the existing
    instrument when the name is taken (so independent call sites share
    one series) and raise on a *kind* collision — silently returning a
    Counter where a Histogram was requested would corrupt both series.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name: str, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, **kw)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"instrument {name!r} is a {inst.kind}, not a "
                    f"{cls.kind}")
            return inst

    def counter(self, name: str, unit: str = "", desc: str = "") -> Counter:
        return self._get_or_create(Counter, name, unit=unit, desc=desc)

    def gauge(self, name: str, unit: str = "", desc: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, unit=unit, desc=desc)

    def histogram(self, name: str, unit: str = "", desc: str = "",
                  buckets=DEFAULT_TIME_BUCKETS,
                  window: int | None = None) -> Histogram:
        return self._get_or_create(Histogram, name, unit=unit, desc=desc,
                                   buckets=buckets, window=window)

    def get(self, name: str) -> _Instrument | None:
        with self._lock:
            return self._instruments.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict:
        """One JSON-safe dict of every instrument.  The instrument table
        is copied under the registry lock, then each instrument
        snapshots under its own lock — concurrent observers can keep
        writing and every individual value read is consistent."""
        with self._lock:
            table = dict(self._instruments)
        return {name: inst.snapshot() for name, inst in sorted(table.items())}


def run_provenance(mesh=None) -> dict:
    """Provenance stamp for benchmark records: everything needed to
    interpret a perf number months later.  Never raises — a missing git
    binary or a detached workdir yields ``None`` fields, not a dead
    benchmark."""
    import datetime
    import platform
    import subprocess

    def _git(*args):
        try:
            out = subprocess.run(
                ("git",) + args, capture_output=True, text=True, timeout=5)
            return out.stdout.strip() or None if out.returncode == 0 else None
        except (OSError, subprocess.SubprocessError):
            return None

    prov: dict = {
        "git_sha": _git("rev-parse", "HEAD"),
        "git_dirty": bool(_git("status", "--porcelain")),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "python": platform.python_version(),
        "hostname": platform.node(),
    }
    try:
        import jax

        prov["jax"] = jax.__version__
        prov["device_count"] = jax.device_count()
        prov["device_platform"] = jax.devices()[0].platform
    except Exception:  # jax import/device init must never kill a record
        prov["jax"] = None
        prov["device_count"] = None
        prov["device_platform"] = None
    if mesh is not None:
        prov["mesh_shape"] = {str(n): int(s) for n, s in
                              zip(mesh.axis_names, mesh.axis_sizes)}
    return prov
