"""Span tracer: bounded ring buffer -> Chrome-trace / Perfetto JSON.

Usage::

    tr = Tracer(enabled=True)
    with tr.span("decode.step", step=i) as sp:
        ...
        sp.set(tokens=n)            # args may be added before close
    tr.instant("preempt", request=rid)
    tr.complete("queue.wait", start=req.arrival_time, dur=wait_s)
    tr.save("out.json")             # chrome://tracing / ui.perfetto.dev

Design constraints (the serve loop calls this per decode step):

- **near-zero cost when disabled**: ``span()`` returns one shared
  no-op context manager (no allocation), ``instant``/``complete``
  return immediately — the only per-call cost is an attribute check.
  The serve bench gates tracing-enabled throughput at <= 3% of
  disabled.
- **bounded**: events live in a ``deque(maxlen=capacity)`` — a
  long-lived engine can trace forever and keep the newest ``capacity``
  events; ``dropped`` counts what the ring discarded.
- **balanced by construction**: spans are recorded as Chrome *complete*
  events (``ph: "X"`` with ``ts`` + ``dur``) emitted at ``__exit__``,
  which runs on exceptions too — preemption, spec-window rejection, and
  admission failure can never leave a dangling open span (property the
  tests pin).  ``depth()`` exposes the live per-thread nesting for
  those tests.
- **thread-aware**: events carry the recording thread (evaluator-pool
  workers show up as their own Perfetto tracks); ``deque.append`` is
  atomic under the GIL, so recording never takes a lock.

Timestamps are ``time.perf_counter()`` microseconds relative to the
tracer's construction, matching the engine's latency clocks.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque


class _NullSpan:
    """Shared do-nothing span for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        pass


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0", "_tid")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        tr = self._tracer
        self._tid = threading.get_ident()
        tr._depth[self._tid] = tr._depth.get(self._tid, 0) + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        tr = self._tracer
        tr._depth[self._tid] -= 1
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        tr._push(self.name, "X", self._t0, t1 - self._t0, self._tid,
                 self.args)
        return False

    def set(self, **args) -> None:
        """Attach/overwrite args before the span closes."""
        self.args.update(args)


class Tracer:
    """Bounded ring-buffer tracer with Chrome-trace export."""

    def __init__(self, capacity: int = 1 << 16, enabled: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.enabled = bool(enabled)
        self._epoch = time.perf_counter()
        self._events: deque = deque(maxlen=capacity)
        self._pushed = 0
        self._depth: dict[int, int] = {}     # thread id -> open spans
        self._tid_names: dict[int, str] = {}

    # ------------------------------------------------------------- control
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def name_thread(self, name: str) -> None:
        """Label the calling thread's track in the exported trace."""
        self._tid_names[threading.get_ident()] = str(name)

    # ------------------------------------------------------------ recording
    def _push(self, name, ph, t0, dur, tid, args) -> None:
        # (name, ph, ts_s, dur_s, tid, args) — converted at export time
        self._events.append((name, ph, t0 - self._epoch, dur, tid, args))
        self._pushed += 1

    def span(self, name: str, **args):
        """Context manager timing one operation.  Nested spans render as
        Perfetto stack frames on the recording thread's track."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event."""
        if not self.enabled:
            return
        self._push(name, "i", time.perf_counter(), 0.0,
                   threading.get_ident(), args)

    def complete(self, name: str, start: float, dur: float, **args) -> None:
        """Retro-dated span from explicit ``perf_counter`` seconds — e.g.
        queue wait recorded at admission, dated back to arrival."""
        if not self.enabled:
            return
        self._push(name, "X", start, max(dur, 0.0),
                   threading.get_ident(), args)

    # ----------------------------------------------------------- inspection
    @property
    def num_events(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        """Events discarded by the ring (recorded - retained)."""
        return self._pushed - len(self._events)

    def depth(self, thread_id: int | None = None) -> int:
        """Open (entered, not yet exited) spans on one thread."""
        tid = thread_id if thread_id is not None else threading.get_ident()
        return self._depth.get(tid, 0)

    def events(self, name: str | None = None) -> list[dict]:
        """Raw events (newest-last), optionally filtered by name."""
        out = []
        for ev_name, ph, ts, dur, tid, args in list(self._events):
            if name is not None and ev_name != name:
                continue
            out.append({"name": ev_name, "ph": ph, "ts_s": ts,
                        "dur_s": dur, "tid": tid, "args": dict(args)})
        return out

    def clear(self) -> None:
        self._events.clear()
        self._pushed = 0

    # --------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Chrome-trace JSON object (the format Perfetto's UI ingests):
        ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with
        microsecond ``ts``/``dur``, ``ph: "X"`` complete spans and
        ``ph: "i"`` thread-scoped instants, plus thread-name metadata
        for every labeled track."""
        events = []
        tids = set()
        for name, ph, ts, dur, tid, args in list(self._events):
            tids.add(tid)
            ev = {
                "name": name,
                "ph": ph,
                "ts": round(ts * 1e6, 3),
                "pid": 1,
                "tid": tid,
                "args": {k: _jsonable(v) for k, v in args.items()},
            }
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            else:
                ev["s"] = "t"  # instant scope: thread
            events.append(ev)
        for tid in sorted(tids):
            label = self._tid_names.get(tid)
            if label:
                events.append({
                    "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                    "args": {"name": label},
                })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


def _jsonable(v):
    """Coerce numpy scalars etc. into JSON-safe values."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if hasattr(v, "item"):
        return v.item()
    return str(v)


# shared disabled tracer: the default for every instrumented component,
# so hot paths guard on one attribute instead of a None check
NULL_TRACER = Tracer(capacity=1, enabled=False)
