"""Model zoo: scan-based decoder LM family covering the 10 assigned archs.

All models share one protocol (see :mod:`repro.models.model`):

- ``init(rng) -> params``         pure (usable under ``jax.eval_shape``)
- ``forward(params, batch) -> logits``  teacher-forced training forward
- ``loss(params, batch) -> scalar``
- ``init_cache(batch) -> cache`` / ``decode_step(params, cache, tok) -> ...``
- ``quant_groups() -> [QuantGroup]``   what ReLeQ's episode walks

Training forward uses ``lax.scan`` over a stacked layer pytree so HLO size
is depth-independent; the decode path unrolls layers so each layer's packed
quantized weights specialize to their own bitwidth (DESIGN.md §3).
"""
from repro.models.model import build_model, QuantGroup  # noqa: F401
