"""TransformerLM: one scan-based decoder implementation covering the
dense / moe / vlm / audio / hybrid families (RWKV6 lives in rwkv.py).

Structure
---------
- ``init`` builds a params pytree whose repeated-block leaves are stacked
  along a leading axis of length ``L_super = num_layers // moe_interleave``;
  the training/prefill forward is one ``lax.scan`` over that stack (HLO size
  independent of depth — required to keep 80 dry-run compiles tractable on
  one CPU core).
- The decode path is *unrolled* per layer so each layer's packed quantized
  weights specialize to their own ReLeQ bitwidth (DESIGN.md §3): a scan
  cannot stack buffers whose plane count differs per layer.
- Every weight matmul goes through ``apply_linear`` which accepts either a
  raw array (training / fp serving) or a packed ``{planes, scale, bits}``
  dict (quantized serving via kernels.ops.qmm).

A "sub" is one attention+FFN residual block.  ``moe_interleave=2`` (llama4)
makes the scanned superblock = [dense sub, moe sub]; ``family="hybrid"``
(hymba) gives each sub parallel attention+SSM branches.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.quant.pack import (QDQ, Packed, kv_dequantize, kv_pack_int4,
                              kv_qdq, kv_quantize, kv_unpack_int4)
from repro.quant.wrpn import fake_quant as wrpn_fake_quant
from repro.models import mamba as mamba_mod
from repro.models.common import (
    apply_linear,
    apply_mrope,
    apply_rope,
    batch_axes,
    blocked_attention,
    chunk_attention,
    constrain,
    decode_attention,
    dense_init,
    embed_init,
    model_axis,
    readout_axes,
    rms_norm,
    seq_axis,
    swiglu,
)
from repro.models.model import QuantGroup
from repro.models.moe import init_moe, moe_ffn


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        if cfg.family == "ssm":
            raise ValueError("use RWKV6LM for family='ssm'")
        self.cfg = cfg
        self.n_sub = cfg.moe_interleave if cfg.num_experts else 1
        if cfg.num_layers % self.n_sub:
            raise ValueError("num_layers must divide moe_interleave")
        self.L_super = cfg.num_layers // self.n_sub

    # ------------------------------------------------------------------ init
    def _init_attn(self, key, dtype):
        cfg = self.cfg
        D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
        ks = jax.random.split(key, 4)
        return {
            "wq": dense_init(ks[0], D, H * hd, dtype),
            "wk": dense_init(ks[1], D, KV * hd, dtype),
            "wv": dense_init(ks[2], D, KV * hd, dtype),
            "wo": dense_init(ks[3], H * hd, D, dtype, scale=(H * hd) ** -0.5),
        }

    def _init_mlp(self, key, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "wg": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
            "wu": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "wd": dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype, scale=cfg.d_ff ** -0.5),
        }

    def _init_sub(self, key, sub: int, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        p = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": self._init_attn(ks[0], dtype),
        }
        is_moe_sub = cfg.num_experts and sub == self.n_sub - 1
        if is_moe_sub:
            p["moe"] = init_moe(ks[1], cfg.num_experts, cfg.d_model, cfg.d_ff, dtype)
            if cfg.shared_expert:
                p["shared"] = self._init_mlp(ks[2], dtype)
        else:
            p["mlp"] = self._init_mlp(ks[1], dtype)
        if cfg.family == "hybrid":
            p["ssm"] = mamba_mod.init_mamba(
                ks[3], cfg.d_model, cfg.ssm_expand * cfg.d_model, cfg.ssm_state,
                cfg.ssm_conv, dtype)
            p["mix"] = jnp.asarray(0.5, jnp.float32)
        return p

    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k_emb, k_head, k_blocks = jax.random.split(rng, 3)
        subs = []
        for s in range(self.n_sub):
            keys = jax.random.split(jax.random.fold_in(k_blocks, s), self.L_super)
            subs.append(jax.vmap(lambda k: self._init_sub(k, s, dtype))(keys))
        params = {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "blocks": subs,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
        return params

    # ------------------------------------------------------------- sublayers
    def _fused_decode_attn(self, h, p, cache, layer):
        """Fused quantized decode: bit-serial QKV + RoPE + KV-quantize +
        paged attention in one kernel (kernels.ops.fused_qkv_paged_decode),
        then the new token's codes/scales scattered into the pool.  The
        scatter-after-attend is numerically write-then-attend: the kernel
        folds the new token in from its own (quantized) computation."""
        cfg = self.cfg
        kc, vc, length = cache["k"][layer], cache["v"][layer], cache["length"]
        ksc, vsc = cache["k_scale"][layer], cache["v_scale"][layer]
        bt = cache["block_tables"]                      # (B, nb)
        bs = kc.shape[1]
        Tc = bt.shape[1] * bs
        out, k_codes, v_codes, k_sc, v_sc = kops.fused_qkv_paged_decode(
            h[:, 0], p["attn"]["wq"], p["attn"]["wk"], p["attn"]["wv"],
            kc, vc, ksc, vsc, bt, length, cache["kv_qmax"][layer],
            rope_theta=cfg.rope_theta, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads)
        slot = jnp.minimum(length, Tc - 1)
        phys = jnp.take_along_axis(bt, (slot // bs)[:, None], axis=1)[:, 0]
        sub = slot % bs
        cache["k"] = cache["k"].at[layer, phys, sub].set(k_codes)
        cache["v"] = cache["v"].at[layer, phys, sub].set(v_codes)
        cache["k_scale"] = cache["k_scale"].at[layer, phys, sub].set(k_sc)
        cache["v_scale"] = cache["v_scale"].at[layer, phys, sub].set(v_sc)
        return out

    def _attn(self, x, p, positions, *, window, cache=None, layer=None):
        """Residual attention sublayer; cache != None → single-token decode."""
        cfg = self.cfg
        B, S, D = x.shape
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if (cache is not None and S == 1 and "k_scale" in cache
                and window is None and cfg.rope == "rope"
                and all(isinstance(p["attn"][m], Packed)
                        for m in ("wq", "wk", "wv"))):
            out = self._fused_decode_attn(h, p, cache, layer)
            out = out.reshape(B, S, H * hd)
            out = apply_linear(out, p["attn"]["wo"])
            return x + constrain(out, batch_axes(), seq_axis(), None)
        q = apply_linear(h, p["attn"]["wq"]).reshape(B, S, H, hd)
        k = apply_linear(h, p["attn"]["wk"]).reshape(B, S, KV, hd)
        v = apply_linear(h, p["attn"]["wv"]).reshape(B, S, KV, hd)
        if cfg.rope == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        elif cfg.rope == "mrope":
            pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(positions, (3,) + positions.shape[-2:])
            q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        q = constrain(q, batch_axes(), None, model_axis(), None)
        k = constrain(k, batch_axes(), None, model_axis(), None)  # dropped if KV % axis
        v = constrain(v, batch_axes(), None, model_axis(), None)
        if cache is None:
            out = blocked_attention(
                q, k, v, causal=True, window=window,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        elif "block_tables" in cache:
            # paged pool: write the new kv into the owning block, attend by
            # block table (kernels.ops.paged_attention — Pallas on TPU,
            # gather + the identical decode_attention math on CPU)
            kc, vc, length = cache["k"][layer], cache["v"][layer], cache["length"]
            bt = cache["block_tables"]                     # (B, nb)
            bs = kc.shape[1]
            Tc = bt.shape[1] * bs                          # tokens per sequence
            slot = (length % Tc) if window is not None else jnp.minimum(length, Tc - 1)
            phys = jnp.take_along_axis(bt, (slot // bs)[:, None], axis=1)[:, 0]
            sub = slot % bs
            eff_len = jnp.minimum(length + 1, Tc)
            if "k_scale" in cache:
                # quantized blocks (unfused path: windowed attention or
                # unpacked weights): quantize the new token, scatter codes
                # + per-(token, head) scales, attend with in-place dequant
                qmax = cache["kv_qmax"][layer]
                k_codes, k_sc = kv_quantize(k[:, 0], qmax)
                v_codes, v_sc = kv_quantize(v[:, 0], qmax)
                if kc.dtype == jnp.uint8:  # nibble-packed uniform int4
                    k_codes, v_codes = kv_pack_int4(k_codes), kv_pack_int4(v_codes)
                kc = kc.at[phys, sub].set(k_codes)
                vc = vc.at[phys, sub].set(v_codes)
                ksc = cache["k_scale"][layer].at[phys, sub].set(k_sc)
                vsc = cache["v_scale"][layer].at[phys, sub].set(v_sc)
                out = kops.paged_attention(q, kc, vc, bt, eff_len, ksc, vsc)
                cache["k_scale"] = cache["k_scale"].at[layer].set(ksc)
                cache["v_scale"] = cache["v_scale"].at[layer].set(vsc)
            else:
                if "kv_qmax" in cache:
                    # fp-KV oracle: store the quantize-dequantize value —
                    # exactly what the quantized read path reconstructs —
                    # in fp32 blocks (the token-parity gate)
                    qmax = cache["kv_qmax"][layer]
                    k_w = kv_qdq(k[:, 0], qmax).astype(kc.dtype)
                    v_w = kv_qdq(v[:, 0], qmax).astype(vc.dtype)
                else:
                    k_w, v_w = k[:, 0], v[:, 0]
                kc = kc.at[phys, sub].set(k_w)
                vc = vc.at[phys, sub].set(v_w)
                out = kops.paged_attention(q, kc, vc, bt, eff_len)
            cache["k"] = cache["k"].at[layer].set(kc)
            cache["v"] = cache["v"].at[layer].set(vc)
        else:
            # write new kv into this layer's cache slot, attend over the cache
            kc, vc, length = cache["k"][layer], cache["v"][layer], cache["length"]
            Tc = kc.shape[1]
            slot = (length % Tc) if window is not None else jnp.minimum(length, Tc - 1)
            kc = kc.at[jnp.arange(B), slot].set(k[:, 0])
            vc = vc.at[jnp.arange(B), slot].set(v[:, 0])
            eff_len = jnp.minimum(length + 1, Tc)
            out = decode_attention(q, kc, vc, eff_len)
            cache["k"] = cache["k"].at[layer].set(kc)
            cache["v"] = cache["v"].at[layer].set(vc)
        out = out.reshape(B, S, H * hd)
        out = apply_linear(out, p["attn"]["wo"])
        # tp_sp: the residual returns to a sequence-sharded layout here
        return x + constrain(out, batch_axes(), seq_axis(), None)

    def _ffn(self, x, p, *, exact: bool = False):
        cfg = self.cfg
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        aux = jnp.asarray(0.0, jnp.float32)
        if "moe" in p:
            y, aux = moe_ffn(h, p["moe"], k=cfg.experts_per_token,
                             capacity_factor=cfg.capacity_factor, no_drop=exact)
            if "shared" in p:
                y = y + self._dense_mlp(h, p["shared"])
        else:
            y = self._dense_mlp(h, p["mlp"])
        return x + y, aux

    def _dense_mlp(self, h, p):
        cfg = self.cfg
        g = apply_linear(h, p["wg"])
        if cfg.act == "swiglu":
            u = apply_linear(h, p["wu"])
            z = swiglu(g, u)
        else:
            z = jax.nn.gelu(g.astype(jnp.float32)).astype(h.dtype)
        z = constrain(z, batch_axes(), None, model_axis())
        return apply_linear(z, p["wd"])

    def _ssm_branch(self, x, p, cache=None, layer=None):
        h = rms_norm(x, p["ln1"], self.cfg.norm_eps)
        if cache is None:
            y, _ = mamba_mod.mamba_forward(h, p["ssm"], chunk=self.cfg.chunk_size)
            return y
        state = {"h": cache["ssm_h"][layer], "conv": cache["ssm_conv"][layer]}
        y, state = mamba_mod.mamba_step(h, p["ssm"], state)
        cache["ssm_h"] = cache["ssm_h"].at[layer].set(state["h"])
        cache["ssm_conv"] = cache["ssm_conv"].at[layer].set(state["conv"])
        return y

    def _sub_forward(self, x, p, positions, sub: int, *, cache=None, layer=None):
        cfg = self.cfg
        window = cfg.sliding_window
        if cfg.family == "hybrid":
            # parallel attention + SSM heads (Hymba): shared ln1, mixed output
            a = self._attn(x, p, positions, window=window, cache=cache, layer=layer) - x
            s = self._ssm_branch(x, p, cache=cache, layer=layer)
            mix = jax.nn.sigmoid(p["mix"]).astype(x.dtype)
            x = x + mix * a + (1.0 - mix) * s
        else:
            x = self._attn(x, p, positions, window=window, cache=cache, layer=layer)
        x, aux = self._ffn(x, p, exact=cache is not None)
        return x, aux

    # ------------------------------------------------------------- forwards
    def _embed_in(self, params, tokens, embeds):
        if embeds is not None:
            h = embeds.astype(jnp.dtype(self.cfg.dtype))
        else:
            emb = params["embed"]
            if isinstance(emb, QDQ):  # serving embed: quantize at lookup
                emb = wrpn_fake_quant(emb.w, emb.bits, axis=0)
            h = jnp.take(emb, tokens, axis=0)
        return constrain(h, batch_axes(), None, None)

    def _positions_default(self, B, S, offset=0):
        pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
        return jnp.broadcast_to(pos, (B, S))

    def _abs_sin(self, positions, D):
        half = D // 2
        freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
        ang = positions[..., None].astype(jnp.float32) * freq
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

    def forward(self, params, tokens=None, embeds=None, positions=None,
                remat: str = "none", return_hidden: bool = False):
        """Teacher-forced forward -> (logits_f32 | final hidden, aux_loss)."""
        cfg = self.cfg
        h = self._embed_in(params, tokens, embeds)
        B, S, D = h.shape
        if positions is None:
            positions = self._positions_default(B, S)
            if cfg.rope == "mrope":
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        if cfg.rope == "abs_sin":
            p2 = positions if positions.ndim == 2 else positions[0]
            h = h + self._abs_sin(p2, D).astype(h.dtype)

        def superblock(h, stacked):
            aux = jnp.asarray(0.0, jnp.float32)
            for s in range(self.n_sub):
                h, a = self._sub_forward(h, stacked[s], positions, s)
                aux = aux + a
            return h, aux

        if remat == "full":
            superblock = jax.checkpoint(superblock)
        elif remat == "dots":
            superblock = jax.checkpoint(
                superblock,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        def body(carry, stacked):
            h = carry
            h, aux = superblock(h, stacked)
            h = constrain(h, batch_axes(), seq_axis(), None)  # SP carry layout
            return h, aux

        h, auxs = jax.lax.scan(body, h, params["blocks"])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if return_hidden:
            return h, jnp.sum(auxs)
        logits = self._readout(params, h)
        return logits, jnp.sum(auxs)

    def _readout(self, params, h):
        w = params.get("lm_head")
        if w is None:
            emb = params["embed"]
            if isinstance(emb, QDQ):
                emb = wrpn_fake_quant(emb.w, emb.bits, axis=0)
            w = emb.T
        h = constrain(h, readout_axes(), None, None)  # tokens off the model axis
        logits = apply_linear(h, w).astype(jnp.float32)
        return constrain(logits, readout_axes(), None, "model")

    def loss(self, params, batch, remat: str = "none"):
        """Mean next-token CE (+ MoE aux), sequence-chunked readout.

        The f32 (tokens × vocab) logits never materialize whole — computed
        in rematerialized sequence chunks (3.3 GB/chip at the llama4 train
        shape otherwise; EXPERIMENTS.md §Perf)."""
        from repro.models.common import chunked_ce

        h, aux = self.forward(
            params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), remat=remat, return_hidden=True)
        nll, z2 = chunked_ce(lambda hc: self._readout(params, hc),
                             h, batch["labels"])
        return nll + 1e-4 * z2 + 1e-2 * aux, {"nll": nll, "aux": aux}

    # --------------------------------------------------------------- decode
    def cache_len(self, max_len: int) -> int:
        w = self.cfg.sliding_window
        return min(max_len, w) if w else max_len

    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
        T = self.cache_len(max_len)
        cache = {
            "k": jnp.zeros((L, batch, T, KV, hd), dtype),
            "v": jnp.zeros((L, batch, T, KV, hd), dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.family == "hybrid":
            Di, N = cfg.ssm_expand * cfg.d_model, cfg.ssm_state
            cache["ssm_h"] = jnp.zeros((L, batch, Di, N), jnp.float32)
            cache["ssm_conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, Di), dtype)
        return cache

    def _layer_slice(self, params, l: int):
        """Per-layer param view: stacked pytree or pre-unrolled serving list."""
        sub = l % self.n_sub
        idx = l // self.n_sub
        stacked = params["blocks"][sub]
        if isinstance(stacked, list):  # serving layout: already per-layer list
            return stacked[idx]
        return jax.tree.map(lambda a: a[idx], stacked)

    def decode_step(self, params, cache, tokens, positions=None):
        """One token for every sequence.  tokens: (B, 1) int32.

        Unrolled over layers (each layer's quantized weights keep their own
        bitwidth).  Returns (logits (B,1,V) f32, new cache).
        """
        cfg = self.cfg
        cache = dict(cache)
        h = self._embed_in(params, tokens, None)
        B = h.shape[0]
        if positions is None:
            positions = cache["length"][:, None]
            if cfg.rope == "mrope":
                positions = jnp.broadcast_to(positions[None], (3, B, 1))
        if cfg.rope == "abs_sin":
            p2 = positions if positions.ndim == 2 else positions[0]
            h = h + self._abs_sin(p2, cfg.d_model).astype(h.dtype)
        for l in range(cfg.num_layers):
            p = self._layer_slice(params, l)
            h, _ = self._sub_forward(h, p, positions, l % self.n_sub,
                                     cache=cache, layer=l)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._readout(params, h)
        cache["length"] = cache["length"] + 1
        return logits, cache

    def prefill(self, params, tokens=None, embeds=None, max_len: int | None = None):
        """Forward over a prompt, building the KV cache sized for
        ``max_len`` total tokens (prompt + decode budget).  Returns
        (last-token logits, cache).  Unrolled per layer so it also accepts
        serving-layout (packed-quantized) params."""
        cfg = self.cfg
        h = self._embed_in(params, tokens, embeds)
        B, S, _ = h.shape
        positions = self._positions_default(B, S)
        pos_in = jnp.broadcast_to(positions[None], (3, B, S)) if cfg.rope == "mrope" else positions
        if cfg.rope == "abs_sin":
            h = h + self._abs_sin(positions, cfg.d_model).astype(h.dtype)
        cache = self.init_cache(B, max_len=max(S, max_len or 0, 1))
        Tc = cache["k"].shape[2]

        kv_list, ssm_list = [], []

        def run_sub(h, p, sub, layer):
            # capture this layer's K/V (and ssm state) for the cache
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            k = apply_linear(hn, p["attn"]["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
            v = apply_linear(hn, p["attn"]["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
            if cfg.rope == "rope":
                k = apply_rope(k, positions, cfg.rope_theta)
            elif cfg.rope == "mrope":
                k = apply_mrope(k, pos_in, cfg.rope_theta, cfg.mrope_sections)
            kv_list.append((k[:, -Tc:], v[:, -Tc:]))
            if cfg.family == "hybrid":
                _, st = mamba_mod.mamba_forward(hn, p["ssm"], chunk=cfg.chunk_size,
                                                return_state=True)
                ssm_list.append(st)
            hn2, _ = self._sub_forward(h, p, pos_in, sub)
            return hn2

        for l in range(cfg.num_layers):
            p = self._layer_slice(params, l)
            h = run_sub(h, p, l % self.n_sub, l)

        ks = jnp.stack([kv[0] for kv in kv_list]).astype(cache["k"].dtype)
        vs = jnp.stack([kv[1] for kv in kv_list]).astype(cache["v"].dtype)
        pad = Tc - ks.shape[2]
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        if cfg.sliding_window is not None:
            # ring layout: token position p lives at slot p % Tc.  The slice
            # holds the last Tmin=min(S,Tc) tokens, so element i is position
            # S-Tmin+i -> roll by (S-Tmin) % Tc.
            shift = (S - min(S, Tc)) % Tc
            ks = jnp.roll(ks, shift, axis=2)
            vs = jnp.roll(vs, shift, axis=2)
        cache["k"], cache["v"] = ks, vs
        cache["length"] = jnp.full((B,), S, jnp.int32)
        if cfg.family == "hybrid" and ssm_list:
            cache["ssm_h"] = jnp.stack([s["h"] for s in ssm_list])
            cache["ssm_conv"] = jnp.stack([s["conv"] for s in ssm_list])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self._readout(params, h[:, -1:])
        return logits, cache

    # ----------------------------------------------------- chunked prefill
    def _chunk_attn(self, x, p, positions, pos_in, cache, layer, rows,
                    starts, valids):
        """Chunk attention sublayer against the paged pool, batched over
        pool ``rows``: each lane's queries attend [that row's cached
        pages ; the chunk itself], then the chunk's kv is scattered into
        the owning blocks (padding lanes dropped)."""
        cfg = self.cfg
        window = cfg.sliding_window
        B, C, D = x.shape
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = apply_linear(h, p["attn"]["wq"]).reshape(B, C, H, hd)
        k = apply_linear(h, p["attn"]["wk"]).reshape(B, C, KV, hd)
        v = apply_linear(h, p["attn"]["wv"]).reshape(B, C, KV, hd)
        if cfg.rope == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        elif cfg.rope == "mrope":
            q = apply_mrope(q, pos_in, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, pos_in, cfg.rope_theta, cfg.mrope_sections)

        kc, vc = cache["k"][layer], cache["v"][layer]   # (NB, bs, KV, hd[/2])
        bt = cache["block_tables"][rows]                # (B, nb)
        bs = kc.shape[1]
        nb = bt.shape[1]
        Tc = nb * bs                                    # tokens per sequence
        quant = "k_scale" in cache
        oracle = not quant and "kv_qmax" in cache
        if quant:
            # dequantize the gathered context: codes · per-(token, head)
            # scale — identical f32 values to what the oracle pool stores
            ksc, vsc = cache["k_scale"][layer], cache["v_scale"][layer]
            kcg = kc[bt].reshape(B, Tc, KV, -1)
            vcg = vc[bt].reshape(B, Tc, KV, -1)
            if kc.dtype == jnp.uint8:
                kcg, vcg = kv_unpack_int4(kcg), kv_unpack_int4(vcg)
            k_ctx = kv_dequantize(kcg, ksc[bt].reshape(B, Tc, KV))
            v_ctx = kv_dequantize(vcg, vsc[bt].reshape(B, Tc, KV))
        else:
            k_ctx = kc[bt].reshape(B, Tc, KV, hd)
            v_ctx = vc[bt].reshape(B, Tc, KV, hd)
        # the cache stores QDQ values (codes·scale, or the oracle's fp copy
        # of the same product), so in-chunk keys must attend through the
        # SAME quantize-dequantize — otherwise a token scored inside a
        # chunk (prefill / spec verify) diverges from the identical token
        # scored one decode step later, breaking verify ≡ decode parity
        k_att, v_att = k, v
        if quant:
            qmax = cache["kv_qmax"][layer]
            k_codes, k_sc = kv_quantize(k, qmax)        # (B, C, KV, hd), (B, C, KV)
            v_codes, v_sc = kv_quantize(v, qmax)
            k_att = kv_dequantize(k_codes, k_sc)
            v_att = kv_dequantize(v_codes, v_sc)
        elif oracle:
            k_att = kv_qdq(k, cache["kv_qmax"][layer])
            v_att = kv_qdq(v, cache["kv_qmax"][layer])
        s_idx = jnp.arange(Tc, dtype=jnp.int32)[None, :]
        if window is None:
            ctx_pos = jnp.where(s_idx < starts[:, None], s_idx, -1)
        else:
            # ring: slot s holds the youngest token p ≡ s (mod Tc), p < start
            p_tok = starts[:, None] - 1 - ((starts[:, None] - 1 - s_idx) % Tc)
            ctx_pos = jnp.where(p_tok >= 0, p_tok, -1)
        out = chunk_attention(q, k_ctx, v_ctx, ctx_pos, k_att, v_att,
                              positions, window=window)

        i_idx = jnp.arange(C, dtype=jnp.int32)[None, :]
        logical = positions
        if window is not None:
            logical = logical % Tc
        blk = jnp.take_along_axis(bt, jnp.clip(logical // bs, 0, nb - 1),
                                  axis=1)
        phys = jnp.where(i_idx < valids[:, None], blk, kc.shape[0])  # OOB -> dropped
        if quant:
            # codes/scales computed above (the chunk attended their QDQ)
            if kc.dtype == jnp.uint8:
                k_codes, v_codes = kv_pack_int4(k_codes), kv_pack_int4(v_codes)
            kc = kc.at[phys, logical % bs].set(k_codes, mode="drop")
            vc = vc.at[phys, logical % bs].set(v_codes, mode="drop")
            ksc = ksc.at[phys, logical % bs].set(k_sc, mode="drop")
            vsc = vsc.at[phys, logical % bs].set(v_sc, mode="drop")
            cache["k_scale"] = cache["k_scale"].at[layer].set(ksc)
            cache["v_scale"] = cache["v_scale"].at[layer].set(vsc)
        else:
            # oracle writes the QDQ values it attended; fp writes raw k/v
            k_w, v_w = (k_att, v_att) if oracle else (k, v)
            kc = kc.at[phys, logical % bs].set(k_w.astype(kc.dtype), mode="drop")
            vc = vc.at[phys, logical % bs].set(v_w.astype(vc.dtype), mode="drop")
        cache["k"] = cache["k"].at[layer].set(kc)
        cache["v"] = cache["v"].at[layer].set(vc)

        out = out.reshape(B, C, H * hd)
        out = apply_linear(out, p["attn"]["wo"])
        return x + constrain(out, batch_axes(), seq_axis(), None)

    def _chunk_ssm(self, x, p, cache, layer, rows, starts, valids):
        """Hybrid SSM branch over a chunk, carrying each row's cached
        state; padding tokens are masked out of the state update.  A row's
        first chunk (start == 0) zeros the carried state — a freshly
        admitted sequence may be reusing a row whose previous occupant's
        final state is still in the cache."""
        h = rms_norm(x, p["ln1"], self.cfg.norm_eps)
        continuing = (starts > 0)[:, None, None]
        state = {"h": jnp.where(continuing, cache["ssm_h"][layer, rows], 0.0),
                 "conv": jnp.where(continuing, cache["ssm_conv"][layer, rows],
                                   0).astype(cache["ssm_conv"].dtype)}
        y, st = mamba_mod.mamba_forward(
            h, p["ssm"], chunk=self.cfg.chunk_size, return_state=True,
            init_state=state, valid=valids)
        cache["ssm_h"] = cache["ssm_h"].at[layer, rows].set(st["h"])
        cache["ssm_conv"] = cache["ssm_conv"].at[layer, rows].set(
            st["conv"].astype(cache["ssm_conv"].dtype))
        return y

    def _chunk_body(self, params, cache, tokens, rows, starts, valids):
        """Shared fixed-shape chunk forward over pooled-cache rows.

        ``tokens`` (B, C) int32, garbage past each lane's ``valid``;
        ``rows``/``starts``/``valids`` are (B,) int32 *data* mapping batch
        lane -> pool row / tokens already cached / live chunk length, so
        one executable serves every (prompt length × chunk index × batch
        composition).  Both the admission prefill (B = 1, one row) and the
        speculative verifier (B = every pool row) lower through this body.
        Returns (final-norm hidden (B, C, D), cache).
        """
        cfg = self.cfg
        cache = dict(cache)
        B, C = tokens.shape
        h = self._embed_in(params, tokens, None)
        positions = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        pos_in = (jnp.broadcast_to(positions[None], (3, B, C))
                  if cfg.rope == "mrope" else positions)
        if cfg.rope == "abs_sin":
            h = h + self._abs_sin(positions, cfg.d_model).astype(h.dtype)
        for l in range(cfg.num_layers):
            p = self._layer_slice(params, l)
            if cfg.family == "hybrid":
                a = self._chunk_attn(h, p, positions, pos_in, cache, l, rows,
                                     starts, valids) - h
                s = self._chunk_ssm(h, p, cache, l, rows, starts, valids)
                mix = jax.nn.sigmoid(p["mix"]).astype(h.dtype)
                h = h + mix * a + (1.0 - mix) * s
            else:
                h = self._chunk_attn(h, p, positions, pos_in, cache, l, rows,
                                     starts, valids)
            h, _ = self._ffn(h, p, exact=True)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache["length"] = cache["length"].at[rows].set(starts + valids)
        return h, cache

    def prefill_chunk(self, params, cache, tokens, seq, start, valid):
        """One fixed-shape prompt chunk into pooled-cache row ``seq``.

        ``tokens``: (1, C) int32, garbage past ``valid``; ``start`` tokens
        of this sequence are already cached.  ``seq``/``start``/``valid``
        enter as data, so ONE executable serves every (prompt length ×
        chunk index) combination — the compile-churn fix chunked prefill
        exists for.  Returns (logits (1, 1, V) f32 for the last *valid*
        token — the only row an admission ever reads — and the cache).
        """
        h, cache = self._chunk_body(
            params, cache, tokens,
            jnp.asarray(seq, jnp.int32).reshape(1),
            jnp.asarray(start, jnp.int32).reshape(1),
            jnp.asarray(valid, jnp.int32).reshape(1))
        # only the last valid token's logits are ever consumed: slice the
        # hidden state BEFORE the d_model x V readout (a C-wide vocab
        # matmul per chunk otherwise, discarded for all but the last chunk)
        last = jax.lax.dynamic_slice_in_dim(h, valid - 1, 1, axis=1)
        logits = self._readout(params, last)
        return logits, cache

    def verify_chunk(self, params, cache, tokens, starts, valids):
        """Score a speculative window for EVERY pool row in one batched
        fixed-shape call (the chunked verifier behind ``repro.spec``).

        ``tokens`` (B, C): lane r is pool row r — [last committed token,
        draft_1..draft_k, garbage pad]; ``starts``/``valids`` (B,) data
        (valid = 0 marks a dead lane: its reads are masked, its writes
        drop to the garbage block).  Returns (logits (B, C, V) f32 at
        *every* position — index j scores the continuation after
        tokens[:, :j+1] — and the cache, with target-model K/V now written
        for all valid positions of the window).
        """
        B = tokens.shape[0]
        h, cache = self._chunk_body(
            params, cache, tokens, jnp.arange(B, dtype=jnp.int32),
            starts, valids)
        return self._readout(params, h), cache

    # ------------------------------------------------------------ quant API
    def quant_groups(self, seq_len: int = 4096) -> list[QuantGroup]:
        """Ordered weight groups for the ReLeQ episode (embed first,
        lm_head last, matching the paper's layer walk)."""
        cfg = self.cfg
        D, H, KV, hd, F = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_ff
        groups: list[QuantGroup] = []

        def add(name, path, layer, shape, macs_per_token):
            nw = math.prod(shape)
            groups.append(QuantGroup(name, path, layer, tuple(shape), nw,
                                     int(macs_per_token * seq_len)))

        add("embed", ("embed",), None, (cfg.vocab_size, D), 0)
        for l in range(cfg.num_layers):
            s, pre = l % self.n_sub, f"L{l:02d}."
            base = ("blocks", s)
            add(pre + "attn.wq", base + ("attn", "wq"), l // self.n_sub, (D, H * hd), D * H * hd)
            add(pre + "attn.wk", base + ("attn", "wk"), l // self.n_sub, (D, KV * hd), D * KV * hd)
            add(pre + "attn.wv", base + ("attn", "wv"), l // self.n_sub, (D, KV * hd), D * KV * hd)
            add(pre + "attn.wo", base + ("attn", "wo"), l // self.n_sub, (H * hd, D), D * H * hd)
            is_moe = cfg.num_experts and s == self.n_sub - 1
            if is_moe:
                E, k = cfg.num_experts, cfg.experts_per_token
                active = D * F * k  # per token, per matrix
                add(pre + "moe.wg", base + ("moe", "wg"), l // self.n_sub, (E, D, F), active)
                add(pre + "moe.wu", base + ("moe", "wu"), l // self.n_sub, (E, D, F), active)
                add(pre + "moe.wd", base + ("moe", "wd"), l // self.n_sub, (E, F, D), active)
                if cfg.shared_expert:
                    for m, sh in (("wg", (D, F)), ("wu", (D, F)), ("wd", (F, D))):
                        add(pre + f"shared.{m}", base + ("shared", m), l // self.n_sub, sh, D * F)
            else:
                add(pre + "mlp.wg", base + ("mlp", "wg"), l // self.n_sub, (D, F), D * F)
                if cfg.act == "swiglu":
                    add(pre + "mlp.wu", base + ("mlp", "wu"), l // self.n_sub, (D, F), D * F)
                add(pre + "mlp.wd", base + ("mlp", "wd"), l // self.n_sub, (F, D), D * F)
            if cfg.family == "hybrid":
                Di = cfg.ssm_expand * D
                add(pre + "ssm.in_x", base + ("ssm", "in_x"), l // self.n_sub, (D, Di), D * Di)
                add(pre + "ssm.in_z", base + ("ssm", "in_z"), l // self.n_sub, (D, Di), D * Di)
                add(pre + "ssm.out", base + ("ssm", "out"), l // self.n_sub, (Di, D), D * Di)
        if not cfg.tie_embeddings:
            add("lm_head", ("lm_head",), None, (D, cfg.vocab_size), D * cfg.vocab_size)
        return groups

    def kv_quant_groups(self, seq_len: int = 4096) -> list[QuantGroup]:
        """Per-layer KV-cache bitwidth groups (HAQ-style): one pseudo-group
        per layer named ``kv.L{l:02d}`` whose "weights" are the K+V token
        activations a sequence of ``seq_len`` stores for that layer.
        ``n_macs=0`` — KV bits buy cache *bytes* (and decode bandwidth),
        not multiply precision, so the cost model sees them purely through
        the memory term.  ``path=("kv", l)`` is virtual: these groups are
        consumed by the serving engine's ``kv_bits`` knob, never by the
        params pytree."""
        cfg = self.cfg
        kv_hd = cfg.num_kv_heads * cfg.hd
        return [QuantGroup(f"kv.L{l:02d}", ("kv", l), l,
                           (seq_len, cfg.num_kv_heads, cfg.hd),
                           2 * seq_len * kv_hd, 0)
                for l in range(cfg.num_layers)]

    def frozen_bits(self) -> dict[str, int]:
        """Groups the agent may not touch (kept at 8 bits), per config."""
        out = {}
        for g in self.quant_groups():
            if any(g.name.startswith(p) or p in g.name for p in self.cfg.frozen_at_8):
                out[g.name] = 8
        return out
