"""Shared building blocks: norms, RoPE/M-RoPE, blocked attention, sharding.

Everything is a pure function over explicit param pytrees (no framework).
All attention paths are *blocked* (flash-style online softmax over KV
chunks) so the 32k/500k shapes never materialize an (S, S) score matrix —
a hard requirement for the dry-run memory analysis to prove fit.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import ambient_mesh

# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def _mesh_axes() -> tuple[str, ...]:
    m = ambient_mesh()
    return tuple(m.axis_names) if m is not None and not m.empty else ()


def shard_profile() -> str:
    """Activation-sharding profile (REPRO_SHARD_PROFILE):

    - ``tp``   : batch over (pod, data); TP over model; residual replicated
                 on model (Megatron-style, the baseline).
    - ``tp_sp``: tp + the residual stream sequence-sharded over model
                 between blocks (Megatron sequence parallelism).
    - ``fsdp`` : batch over (pod, data, model) — no activation TP; weights
                 fully sharded over all axes (ZeRO-3).
    - ``dp``   : pure data parallelism under an *outer* ``shard_map``
                 (train_step.make_dp_train_step): the model body runs on a
                 per-shard local batch, so every in-model constraint must
                 no-op — sharding constraints are illegal inside manual
                 collectives, and the shard is the whole world anyway.
    """
    return os.environ.get("REPRO_SHARD_PROFILE", "tp")


def batch_axes():
    """Axes the global batch shards over."""
    prof = shard_profile()
    if prof == "dp":
        return None
    axes = ("pod", "data", "model") if prof == "fsdp" else ("pod", "data")
    axes = tuple(a for a in axes if a in _mesh_axes())
    return axes if axes else None


def model_axis():
    if shard_profile() in ("fsdp", "dp"):
        return None
    return "model" if "model" in _mesh_axes() else None


def seq_axis():
    """Residual-stream sequence axis (tp_sp profile only)."""
    if shard_profile() == "tp_sp" and "model" in _mesh_axes():
        return "model"
    return None


def readout_axes():
    """Batch axes at the vocab-parallel readout: never includes "model"
    (the vocab dim owns it in every profile — a vocab matmul whose tokens
    are also model-sharded would otherwise compute full (D, V) f32 grad
    partials on every chip; EXPERIMENTS.md §Perf)."""
    if shard_profile() == "dp":
        return None
    axes = tuple(a for a in ("pod", "data") if a in _mesh_axes())
    return axes if axes else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that no-ops outside a mesh context.

    spec entries may be None, an axis name, or a tuple of axis names; any
    axis not present in the ambient mesh — or whose size does not divide the
    corresponding array dim — is dropped, so the same model code runs on the
    1-device smoke mesh, the single-pod and the multi-pod mesh, and on archs
    whose head counts don't divide the model axis (e.g. glm4 kv=2).
    """
    m = ambient_mesh()
    if m is None or m.empty:
        return x
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    clean = []
    for dim, s in zip(x.shape, spec):
        names = tuple(a for a in ((s,) if isinstance(s, str) else tuple(s or ()))
                      if a in sizes)
        # largest suffix whose product divides the dim (handles e.g. 1600-wide
        # dims on a 256-way combined axis by falling back to 16-way)
        pick = None
        for start in range(len(names)):
            sub = names[start:]
            prod = 1
            for a in sub:
                prod *= sizes[a]
            if dim % prod == 0:
                pick = sub[0] if len(sub) == 1 else tuple(sub)
                break
        clean.append(pick)
    if all(pick is None for pick in clean):  # fully replicated: no-op (and
        return x                             # legal inside shard_map bodies)
    return jax.lax.with_sharding_constraint(x, P(*clean))


# ---------------------------------------------------------------------------
# sequence-chunked cross-entropy (readout never materializes full logits)
# ---------------------------------------------------------------------------


def chunked_ce(readout_fn, h: jax.Array, labels: jax.Array,
               chunk: int = 512):
    """Mean next-token CE + mean logz² over (B, S) tokens.

    ``readout_fn(h_chunk) -> logits_f32``.  Scans rematerialized sequence
    chunks so only (B, chunk, V) logits are live at once; the backward
    recomputes each chunk's logits.
    """
    B, S, D = h.shape
    c = min(chunk, S)
    Sp = -(-S // c) * c
    hp = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, Sp - S)))
    valid = jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, Sp - S)))
    nch = Sp // c
    hb = jnp.moveaxis(hp.reshape(B, nch, c, D), 1, 0)
    lb = jnp.moveaxis(lp.reshape(B, nch, c), 1, 0)
    vb = jnp.moveaxis(valid.reshape(B, nch, c), 1, 0)

    @jax.checkpoint
    def chunk_fn(carry, inp):
        nll_sum, z2_sum = carry
        hc, lc, vc = inp
        logits = readout_fn(hc)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        # label pick as a masked reduce, NOT take_along_axis: a gather over
        # the vocab-sharded dim makes GSPMD all-gather the full (B, c, V)
        # f32 logits (a 5 GB/device temp at the glm4 fsdp train_4k cell);
        # the compare+sum keeps the vocab dim sharded end to end
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        tgt = jnp.sum(jnp.where(vid == lc[..., None], logits, 0.0), axis=-1)
        nll_sum = nll_sum + jnp.sum((logz - tgt) * vc)
        z2_sum = z2_sum + jnp.sum(jnp.square(logz) * vc)
        return (nll_sum, z2_sum), None

    (nll_sum, z2_sum), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hb, lb, vb))
    n = float(B * S)
    return nll_sum / n, z2_sum / n


# ---------------------------------------------------------------------------
# linear application (raw | Packed bitplane serving weight)
# ---------------------------------------------------------------------------


def apply_linear(x: jax.Array, w) -> jax.Array:
    """x @ w where w is a raw array or a Packed bitplane weight."""
    from repro.kernels import ops as kops
    from repro.quant.pack import Packed

    if isinstance(w, Packed):
        return kops.qmm(x, w.planes, w.scale, bits=w.bits).astype(x.dtype)
    return x @ w


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: float | None = None):
    s = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma).astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim//2,) float32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections: tuple[int, int, int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: 3 position streams (t, h, w) rotate
    disjoint sections of each head's dim.

    x: (B, S, H, hd); positions3: (3, B, S) int32; sections: half-dim split
    (sums to hd//2), e.g. hd=128 -> (16, 24, 24).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    # section id per frequency index
    sec_sizes = jnp.asarray(sections)
    bounds = jnp.cumsum(sec_sizes)
    idx = jnp.arange(hd // 2)
    sec_id = jnp.sum(idx[:, None] >= bounds[None, :], axis=1)  # 0/1/2
    # pick the position stream per frequency
    pos = positions3.astype(jnp.float32)  # (3, B, S)
    pos_sel = jnp.take(pos, sec_id, axis=0)  # (hd/2, B, S)
    ang = jnp.moveaxis(pos_sel, 0, -1) * inv  # (B, S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blocked (flash-style) attention — pure JAX, O(S·chunk) memory
# ---------------------------------------------------------------------------


_NEG = -1e30  # finite "-inf" so the online-softmax carries stay NaN-free


def _tile_mask(q_pos, k_pos, S: int, causal: bool, window):
    mask = (k_pos < S)[None, :]
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


def _flash_fwd_blocks(qb, kb, vb, S, causal, window, cq, ck):
    """qb: (B,nq,cq,KV,G,hd); kb/vb: (B,nk,ck,KV,hd).
    -> out (B,nq,cq,KV,G,hd) f32, lse (B,nq,cq,KV,G) f32."""
    B, nq, _, KV, G, hd = qb.shape
    nk = kb.shape[1]
    scale = hd ** -0.5

    def q_block(args):
        qi, q_tile = args
        q_pos = qi * cq + jnp.arange(cq)

        def kv_step(carry, inputs):
            acc, m, l = carry
            ki, k_tile, v_tile = inputs
            k_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgh,bckh->bqkgc", q_tile.astype(jnp.float32),
                           k_tile.astype(jnp.float32)) * scale
            mask5 = _tile_mask(q_pos, k_pos, S, causal, window)[None, :, None, None, :]
            s = jnp.where(mask5, s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(mask5, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgc,bckh->bqkgh", p, v_tile.astype(jnp.float32))
            return (acc * corr[..., None] + pv, m_new, l_new), None

        acc0 = jnp.zeros((B, cq, KV, G, hd), jnp.float32)
        m0 = jnp.full((B, cq, KV, G), _NEG, jnp.float32)
        l0 = jnp.zeros((B, cq, KV, G), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        lse = m + jnp.log(jnp.maximum(l, 1e-20))
        return out, lse

    outs, lses = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1), jnp.moveaxis(lses, 0, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(qb, kb, vb, S, causal, window, cq, ck):
    out, _ = _flash_fwd_blocks(qb, kb, vb, S, causal, window, cq, ck)
    return out


def _flash_vjp_fwd(qb, kb, vb, S, causal, window, cq, ck):
    out, lse = _flash_fwd_blocks(qb, kb, vb, S, causal, window, cq, ck)
    return out, (qb, kb, vb, out, lse)


def _flash_vjp_bwd(S, causal, window, cq, ck, res, g):
    """Manual flash backward: recompute p per (q-block × kv-block) tile from
    the saved logsumexp — score tiles never round-trip HBM as saved scan
    carries (the 6.8 TB/chip failure mode of autodiff through the fwd scan;
    EXPERIMENTS.md §Perf).  dq accumulates via scatter-add into its block
    index; dk/dv are per-kv-block scan outputs."""
    qb, kb, vb, out, lse = res
    B, nq, _, KV, G, hd = qb.shape
    nk = kb.shape[1]
    scale = hd ** -0.5
    g = g.astype(jnp.float32)
    # D_i = rowsum(dout ⊙ out): (B,nq,cq,KV,G)
    Drow = jnp.sum(g * out, axis=-1)
    lse_safe = jnp.where(lse <= _NEG / 2, 1e30, lse)  # padded rows -> p = 0

    def kv_step(dq_acc, inputs):
        ki, k_tile, v_tile = inputs        # (B,ck,KV,hd)
        k_pos = ki * ck + jnp.arange(ck)
        kf = k_tile.astype(jnp.float32)
        vf = v_tile.astype(jnp.float32)

        def q_step(carry, inputs_q):
            dk, dv, dq_acc = carry
            qi, q_tile, g_tile, lse_i, D_i = inputs_q
            q_pos = qi * cq + jnp.arange(cq)
            qf = q_tile.astype(jnp.float32)
            s = jnp.einsum("bqkgh,bckh->bqkgc", qf, kf) * scale
            mask5 = _tile_mask(q_pos, k_pos, S, causal, window)[None, :, None, None, :]
            p = jnp.where(mask5, jnp.exp(s - lse_i[..., None]), 0.0)
            dv = dv + jnp.einsum("bqkgc,bqkgh->bckh", p, g_tile)
            dp = jnp.einsum("bqkgh,bckh->bqkgc", g_tile, vf)
            ds = p * (dp - D_i[..., None]) * scale
            dk = dk + jnp.einsum("bqkgc,bqkgh->bckh", ds, qf)
            dq_i = jnp.einsum("bqkgc,bckh->bqkgh", ds, kf)
            dq_acc = dq_acc.at[:, qi].add(dq_i)
            return (dk, dv, dq_acc), None

        dk0 = jnp.zeros((B, ck, KV, hd), jnp.float32)
        dv0 = jnp.zeros((B, ck, KV, hd), jnp.float32)
        (dk, dv, dq_acc), _ = jax.lax.scan(
            q_step, (dk0, dv0, dq_acc),
            (jnp.arange(nq), jnp.moveaxis(qb, 1, 0), jnp.moveaxis(g, 1, 0),
             jnp.moveaxis(lse_safe, 1, 0), jnp.moveaxis(Drow, 1, 0)))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros(qb.shape, jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        kv_step, dq0,
        (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
    dk = jnp.moveaxis(dks, 0, 1).astype(kb.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).astype(vb.dtype)
    return dq.astype(qb.dtype), dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def blocked_attention(
    q: jax.Array,        # (B, S, H, hd)
    k: jax.Array,        # (B, S, KV, hd)
    v: jax.Array,        # (B, S, KV, hd)
    *,
    causal: bool = True,
    window: int | None = None,   # sliding-window size (None = full)
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Flash attention (fwd: online softmax over KV chunks; bwd: manual
    tile recompute via custom_vjp).  Supports GQA + SWA.  Never
    materializes (S, S); peak per-tile memory O(B·q_chunk·kv_chunk·H/KV).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    cq = min(q_chunk, S)
    ck = min(kv_chunk, S)
    Sq = -(-S // cq) * cq
    Sk = -(-S // ck) * ck
    qp = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    qb = qp.reshape(B, Sq // cq, cq, KV, G, hd)
    kb = kp.reshape(B, Sk // ck, ck, KV, hd)
    vb = vp.reshape(B, Sk // ck, ck, KV, hd)
    out = _flash(qb, kb, vb, S, causal, window, cq, ck)
    out = out.reshape(B, Sq, KV * G, hd)[:, :S]
    return out.astype(q.dtype)


def chunk_attention(
    q: jax.Array,        # (B, C, H, hd) — one fixed-shape prompt chunk
    k_ctx: jax.Array,    # (B, T, KV, hd) — already-cached context
    v_ctx: jax.Array,    # (B, T, KV, hd)
    ctx_pos: jax.Array,  # (B, T) absolute token index per context slot, -1 = empty
    k_new: jax.Array,    # (B, C, KV, hd) — this chunk's keys (pre-write)
    v_new: jax.Array,    # (B, C, KV, hd)
    q_pos: jax.Array,    # (B, C) absolute token index per query (garbage tail ok)
    *,
    window: int | None = None,
) -> jax.Array:
    """Chunked-prefill attention: queries attend [context cache ; own chunk].

    The chunk's keys are taken from ``k_new`` rather than the cache so a
    ring-layout (sliding-window) cache is never read at slots the chunk is
    about to overwrite.  Masking is purely in absolute token positions, so
    the same code covers linear caches (slot t holds token t), ring caches
    (slot s holds the youngest token ≡ s mod T), and paged gathers.  fp32
    masked softmax — same arithmetic as :func:`decode_attention`.
    """
    B, C, H, hd = q.shape
    KV = k_new.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qf = q.reshape(B, C, KV, G, hd).astype(jnp.float32)

    def scores(k):
        return jnp.einsum("bckgh,btkh->bkgct", qf,
                          k.astype(jnp.float32)) * scale

    def mask(key_pos):  # (B, Tk) -> (B, 1, 1, C, Tk)
        ok = (key_pos[:, None, :] >= 0) & (key_pos[:, None, :] <= q_pos[:, :, None])
        if window is not None:
            ok &= q_pos[:, :, None] - key_pos[:, None, :] < window
        return ok[:, None, None]

    s = jnp.concatenate(
        [jnp.where(mask(ctx_pos), scores(k_ctx), _NEG),
         jnp.where(mask(q_pos), scores(k_new), _NEG)], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    v = jnp.concatenate([v_ctx, v_new], axis=1).astype(jnp.float32)
    o = jnp.einsum("bkgct,btkh->bckgh", p, v)
    return o.reshape(B, C, H, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # (B, 1, H, hd) — single new token
    k_cache: jax.Array,  # (B, T, KV, hd)
    v_cache: jax.Array,  # (B, T, KV, hd)
    length: jax.Array,   # (B,) valid prefix lengths (int32)
    *,
    window: int | None = None,
) -> jax.Array:
    """One-step attention against a (possibly windowed) KV cache."""
    B, T, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qf, k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(T)[None, :]  # (1, T)
    valid = pos < length[:, None]
    if window is not None:
        valid &= pos >= (length[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
