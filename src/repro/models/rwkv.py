"""RWKV6 ("Finch"): attention-free LM with data-dependent diagonal decay.

Per head (dims K=V=head_dim) the wkv recurrence is

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)

with w_t = exp(-exp(w0 + tanh(x W_a) W_b)) ∈ (0,1) *input-dependent* (the
RWKV6 novelty).  We evaluate it chunk-parallel: within a chunk of length c
the pairwise decay products D[t,i,d] = exp(L_{t-1,d} − L_{i,d}) (L = cumsum
of log-decay) are ≤ 1 by construction — no overflow — and cost O(c²·K) per
head; across chunks a ``lax.scan`` carries the (K, V) state.  c defaults to
16 to bound the (B, c, c, H, K) pairwise tensor (DESIGN.md §5).

Quantizable groups per layer: the five time-mix projections + output, and
the three channel-mix matrices.  The decay LoRA (W_a, W_b), bonus u, and
token-shift mixes stay fp — tiny and sensitivity-critical, the analogue of
the paper keeping first/last CNN layers at 8 bits.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.quant.pack import QDQ
from repro.quant.wrpn import fake_quant as wrpn_fake_quant
from repro.models.common import (
    apply_linear,
    batch_axes,
    constrain,
    dense_init,
    embed_init,
    model_axis,
    readout_axes,
    rms_norm,
    seq_axis,
)
from repro.models.model import QuantGroup


def _embed_table(params):
    """Embedding matrix, dequantized at lookup when serving-tagged."""
    emb = params["embed"]
    if isinstance(emb, QDQ):
        emb = wrpn_fake_quant(emb.w, emb.bits, axis=0)
    return emb


def wkv6_chunked(r, k, v, logw, u, state0, chunk: int = 16):
    """r/k/v/logw: (B, S, H, K); u: (H, K); state0: (B, H, K, V).

    Returns (out (B,S,H,V), state (B,H,K,V)).  fp32 throughout.
    """
    B, S, H, K = r.shape
    c = min(chunk, S)
    Sp = -(-S // c) * c
    pad = Sp - S

    def pad_t(a, val=0.0):
        return jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=val)

    rp, kp, vp = pad_t(r), pad_t(k), pad_t(v)
    lwp = pad_t(logw)  # padded decay 0 (=no decay) is harmless: k,v padded 0
    nc = Sp // c

    def chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, c, H, K), 1, 0)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)  # i < t

    # recompute the pairwise-decay tile in the backward pass instead of
    # letting scan save (nc, c, c, H, K) stacked residuals — 6.6 TB/chip of
    # HBM traffic at train_4k otherwise (EXPERIMENTS.md §Perf)
    @jax.checkpoint
    def step(state, inp):
        rc, kc, vc, lwc = inp                     # (B,c,H,K)
        L = jnp.cumsum(lwc, axis=1)               # inclusive
        Lprev = L - lwc                           # exclusive (L_{t-1})
        q = rc * jnp.exp(Lprev)
        out_inter = jnp.einsum("bchk,bhkv->bchv", q, state)
        diff = Lprev[:, :, None] - L[:, None]     # (B,t,i,H,K)
        diff = jnp.where(tri[None, :, :, None, None], diff, -jnp.inf)
        Dm = jnp.exp(diff)
        A = jnp.einsum("bthk,bihk,btihk->btih", rc, kc, Dm)   # (B,c,c,H)
        Adiag = jnp.einsum("bthk,hk,bthk->bth", rc, u, kc)
        A = A + jnp.eye(c)[None, :, :, None] * Adiag[:, :, None, :]
        out_intra = jnp.einsum("btih,bihv->bthv", A, vc)
        L_last = L[:, -1]                         # (B,H,K)
        kmod = kc * jnp.exp(L_last[:, None] - L)
        state = state * jnp.exp(L_last)[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", kmod, vc)
        return state, out_inter + out_intra

    state, outs = jax.lax.scan(
        step, state0.astype(jnp.float32),
        (chunks(rp).astype(jnp.float32), chunks(kp).astype(jnp.float32),
         chunks(vp).astype(jnp.float32), chunks(lwp).astype(jnp.float32)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sp, H, K)[:, :S]
    return out, state


def wkv6_step(r, k, v, logw, u, state):
    """Single token: r/k/v/logw (B,H,K); state (B,H,K,V)."""
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    o = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    state = state * jnp.exp(logw)[..., None] + kv
    return o, state


class RWKV6LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.d_model % cfg.hd:
            raise ValueError("d_model must divide head_dim")
        self.H = cfg.d_model // cfg.hd

    # ------------------------------------------------------------------ init
    def _init_layer(self, key, dtype):
        cfg = self.cfg
        D, F, R = cfg.d_model, cfg.d_ff, cfg.wkv_lora_rank
        ks = jax.random.split(key, 10)
        mu = lambda k: jax.random.uniform(k, (D,), jnp.float32)
        return {
            "ln1": jnp.ones((D,), jnp.float32),
            "ln2": jnp.ones((D,), jnp.float32),
            "tm": {
                "mu_r": mu(ks[0]), "mu_k": mu(jax.random.fold_in(ks[0], 1)),
                "mu_v": mu(jax.random.fold_in(ks[0], 2)),
                "mu_g": mu(jax.random.fold_in(ks[0], 3)),
                "mu_w": mu(jax.random.fold_in(ks[0], 4)),
                "wr": dense_init(ks[1], D, D, dtype),
                "wk": dense_init(ks[2], D, D, dtype),
                "wv": dense_init(ks[3], D, D, dtype),
                "wg": dense_init(ks[4], D, D, dtype),
                "wo": dense_init(ks[5], D, D, dtype),
                "w0": jnp.full((D,), 1.0, jnp.float32),   # exp(-exp(1)) ≈ .066 decay/step
                "wa": dense_init(ks[6], D, R, jnp.float32),
                "wb": (jax.random.normal(jax.random.fold_in(ks[6], 1), (R, D), jnp.float32)
                       * 0.01).astype(jnp.float32),
                "u": jnp.zeros((self.H, self.cfg.hd), jnp.float32),
                "gn": jnp.ones((D,), jnp.float32),
            },
            "cm": {
                "mu_k": mu(ks[7]), "mu_r": mu(jax.random.fold_in(ks[7], 1)),
                "wk": dense_init(ks[8], D, F, dtype),
                "wv": dense_init(ks[9], F, D, dtype, scale=F ** -0.5),
                "wr": dense_init(jax.random.fold_in(ks[9], 1), D, D, dtype),
            },
        }

    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k_emb, k_head, k_blocks = jax.random.split(rng, 3)
        keys = jax.random.split(k_blocks, cfg.num_layers)
        blocks = jax.vmap(lambda k: self._init_layer(k, dtype))(keys)
        return {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "blocks": blocks,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype),
        }

    # ------------------------------------------------------------- sublayers
    def _decay(self, xw, tm):
        lw = tm["w0"] + jnp.tanh(xw.astype(jnp.float32) @ tm["wa"]) @ tm["wb"]
        return -jnp.exp(jnp.clip(lw, -8.0, 6.0))  # log-decay in (-e^6, 0)

    def _time_mix(self, x, xprev, p, state0=None, valid=None):
        """x: (B,S,D); xprev: previous-token x (B,S,D).  Returns (out, state).

        ``valid`` (traced scalar, or a (B,) vector for per-row lengths)
        masks positions ≥ valid out of the wkv state update (k → 0,
        log-decay → 0), so a fixed-shape prefill chunk's garbage tail
        leaves the carried state exactly as if the chunk had ended at
        ``valid``."""
        cfg, H, hd = self.cfg, self.H, self.cfg.hd
        B, S, D = x.shape
        tm = p["tm"]
        lerp = lambda m: x + (xprev - x) * m
        r = apply_linear(lerp(tm["mu_r"]), tm["wr"]).reshape(B, S, H, hd)
        k = apply_linear(lerp(tm["mu_k"]), tm["wk"]).reshape(B, S, H, hd)
        v = apply_linear(lerp(tm["mu_v"]), tm["wv"]).reshape(B, S, H, hd)
        g = jax.nn.silu(apply_linear(lerp(tm["mu_g"]), tm["wg"]).astype(jnp.float32))
        logw = self._decay(lerp(tm["mu_w"]), tm).reshape(B, S, H, hd)
        if valid is not None:
            valid = jnp.asarray(valid, jnp.int32).reshape(-1)  # scalar -> (1,)
            keep = (jnp.arange(S)[None, :] < valid[:, None])[:, :, None, None]
            k = jnp.where(keep, k, jnp.zeros_like(k))
            logw = jnp.where(keep, logw, jnp.zeros_like(logw))
        if state0 is None:
            state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        if S == 1:
            o, state = wkv6_step(
                r[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
                v[:, 0].astype(jnp.float32), logw[:, 0], tm["u"], state0)
            o = o[:, None]
        else:
            o, state = wkv6_chunked(r, k, v, logw, tm["u"], state0, cfg.chunk_size)
        o = o.reshape(B, S, D)
        # per-head group norm
        oh = o.reshape(B, S, H, hd)
        mean = jnp.mean(oh, -1, keepdims=True)
        var = jnp.var(oh, -1, keepdims=True)
        o = ((oh - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D) * tm["gn"]
        o = (o * g).astype(x.dtype)
        return apply_linear(o, tm["wo"]), state

    def _channel_mix(self, x, xprev, p):
        cm = p["cm"]
        lerp = lambda m: x + (xprev - x) * m
        kx = apply_linear(lerp(cm["mu_k"]), cm["wk"])
        kx = jnp.square(jax.nn.relu(kx.astype(jnp.float32))).astype(x.dtype)
        val = apply_linear(kx, cm["wv"])
        gate = jax.nn.sigmoid(apply_linear(lerp(cm["mu_r"]), cm["wr"]).astype(jnp.float32))
        return (gate * val.astype(jnp.float32)).astype(x.dtype)

    def _shift(self, x, last=None):
        """Previous-token stream; ``last`` (B,1,D) = final token of prefix."""
        init = jnp.zeros_like(x[:, :1]) if last is None else last.astype(x.dtype)
        return jnp.concatenate([init, x[:, :-1]], axis=1)

    def _layer(self, x, p, *, tm_state=None, x_tm_last=None, x_cm_last=None):
        h1 = rms_norm(x, p["ln1"], self.cfg.norm_eps)
        tm_out, tm_state = self._time_mix(h1, self._shift(h1, x_tm_last), p, tm_state)
        x = x + constrain(tm_out, batch_axes(), seq_axis(), None)
        h2 = rms_norm(x, p["ln2"], self.cfg.norm_eps)
        cm_out = self._channel_mix(h2, self._shift(h2, x_cm_last), p)
        x = x + constrain(cm_out, batch_axes(), seq_axis(), None)
        return x, (tm_state, h1[:, -1:], h2[:, -1:])

    # ------------------------------------------------------------- forwards
    def forward(self, params, tokens=None, embeds=None, positions=None,
                remat: str = "none", return_hidden: bool = False):
        cfg = self.cfg
        emb = _embed_table(params)
        h = embeds.astype(jnp.dtype(cfg.dtype)) if embeds is not None else jnp.take(emb, tokens, axis=0)
        h = constrain(h, batch_axes(), None, None)

        def block(h, p):
            h, _ = self._layer(h, p)
            return h, jnp.asarray(0.0, jnp.float32)

        if remat == "full":
            block = jax.checkpoint(block)
        elif remat == "dots":
            block = jax.checkpoint(
                block, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        h, _ = jax.lax.scan(block, h, params["blocks"])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if return_hidden:
            return h, jnp.asarray(0.0, jnp.float32)
        return self._readout(params, h), jnp.asarray(0.0, jnp.float32)

    def _readout(self, params, h):
        h = constrain(h, readout_axes(), None, None)
        logits = apply_linear(h, params["lm_head"]).astype(jnp.float32)
        return constrain(logits, readout_axes(), None, "model")

    def loss(self, params, batch, remat: str = "none"):
        from repro.models.common import chunked_ce

        h, _ = self.forward(params, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"), remat=remat,
                            return_hidden=True)
        nll, z2 = chunked_ce(lambda hc: self._readout(params, hc),
                             h, batch["labels"])
        return nll + 1e-4 * z2, {"nll": nll, "aux": 0.0}

    # --------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        L, D = cfg.num_layers, cfg.d_model
        return {
            "wkv": jnp.zeros((L, batch, self.H, cfg.hd, cfg.hd), jnp.float32),
            "x_tm": jnp.zeros((L, batch, 1, D), dtype),
            "x_cm": jnp.zeros((L, batch, 1, D), dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def _layer_slice(self, params, l):
        blocks = params["blocks"]
        if isinstance(blocks, list):
            return blocks[l]
        return jax.tree.map(lambda a: a[l], blocks)

    def decode_step(self, params, cache, tokens, positions=None):
        cfg = self.cfg
        cache = dict(cache)
        h = jnp.take(_embed_table(params), tokens, axis=0)  # (B,1,D)
        for l in range(cfg.num_layers):
            p = self._layer_slice(params, l)
            h, (st, xtm, xcm) = self._layer(
                h, p, tm_state=cache["wkv"][l],
                x_tm_last=cache["x_tm"][l], x_cm_last=cache["x_cm"][l])
            cache["wkv"] = cache["wkv"].at[l].set(st)
            cache["x_tm"] = cache["x_tm"].at[l].set(xtm.astype(cache["x_tm"].dtype))
            cache["x_cm"] = cache["x_cm"].at[l].set(xcm.astype(cache["x_cm"].dtype))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = apply_linear(h, params["lm_head"]).astype(jnp.float32)
        cache["length"] = cache["length"] + 1
        return logits, cache

    def prefill(self, params, tokens=None, embeds=None, max_len: int | None = None):
        """Scan-based prefill collecting per-layer states (max_len unused:
        the wkv state is O(1) in sequence length)."""
        cfg = self.cfg
        emb = _embed_table(params)
        h = embeds.astype(jnp.dtype(cfg.dtype)) if embeds is not None else jnp.take(emb, tokens, axis=0)
        B, S, _ = h.shape

        def block(h, p):
            h, (st, xtm, xcm) = self._layer(h, p)
            return h, (st, xtm, xcm)

        blocks = params["blocks"]
        if isinstance(blocks, list):
            # serving layout: per-layer list (packed buffers differ in plane
            # count across layers, so a scan cannot stack them) — unroll
            states = []
            for p in blocks:
                h, st = self._layer(h, p)
                states.append(st)
            sts, xtms, xcms = (jnp.stack(x) for x in zip(*states))
        else:
            h, (sts, xtms, xcms) = jax.lax.scan(block, h, blocks)
        hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = apply_linear(hn[:, -1:], params["lm_head"]).astype(jnp.float32)
        cache = {
            "wkv": sts, "x_tm": xtms.astype(jnp.dtype(cfg.dtype)),
            "x_cm": xcms.astype(jnp.dtype(cfg.dtype)),
            "length": jnp.full((B,), S, jnp.int32),
        }
        return logits, cache

    def _chunk_body(self, params, cache, tokens, rows, starts, valids):
        """Shared fixed-shape chunk forward over pooled-cache rows.

        The wkv/token-shift state is O(1) per sequence, so "paged" RWKV is
        plain slot semantics: each lane continues its row's carried state
        (padding masked out of the update — see ``_time_mix``) and writes
        it back.  ``tokens`` (B, C) int32 with garbage past each lane's
        ``valid``; ``rows``/``starts``/``valids`` (B,) int32 data — one
        executable for every (prompt length × chunk index × batch
        composition).  Drives both the admission prefill (B = 1) and the
        speculative verifier (B = every pool row).  Returns (final-norm
        hidden (B, C, D), cache).
        """
        cfg = self.cfg
        cache = dict(cache)
        h = jnp.take(_embed_table(params), tokens, axis=0)   # (B, C, D)
        # first chunk (start == 0): zero the carried state — a fresh
        # admission may be reusing a row whose previous occupant's state
        # is still cached.  Later chunks carry the cached state through.
        continuing = (starts > 0)[:, None, None]
        last_idx = jnp.maximum(valids - 1, 0)[:, None, None]
        for l in range(cfg.num_layers):
            p = self._layer_slice(params, l)
            h1 = rms_norm(h, p["ln1"], cfg.norm_eps)
            xtm0 = jnp.where(continuing, cache["x_tm"][l, rows],
                             0).astype(cache["x_tm"].dtype)
            wkv0 = jnp.where(continuing[..., None], cache["wkv"][l, rows], 0.0)
            tm_out, st = self._time_mix(
                h1, self._shift(h1, xtm0), p, state0=wkv0, valid=valids)
            h = h + constrain(tm_out, batch_axes(), seq_axis(), None)
            h2 = rms_norm(h, p["ln2"], cfg.norm_eps)
            xcm0 = jnp.where(continuing, cache["x_cm"][l, rows],
                             0).astype(cache["x_cm"].dtype)
            cm_out = self._channel_mix(h2, self._shift(h2, xcm0), p)
            h = h + constrain(cm_out, batch_axes(), seq_axis(), None)
            cache["wkv"] = cache["wkv"].at[l, rows].set(st)
            cache["x_tm"] = cache["x_tm"].at[l, rows].set(
                jnp.take_along_axis(h1, last_idx, axis=1)
                .astype(cache["x_tm"].dtype))
            cache["x_cm"] = cache["x_cm"].at[l, rows].set(
                jnp.take_along_axis(h2, last_idx, axis=1)
                .astype(cache["x_cm"].dtype))
        hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
        cache["length"] = cache["length"].at[rows].set(starts + valids)
        return hn, cache

    def prefill_chunk(self, params, cache, tokens, seq, start, valid):
        """One fixed-shape prompt chunk into pooled-cache row ``seq``.

        Same one-executable contract as the transformer path — see
        ``_chunk_body``.  Returns (logits (1, 1, V) f32 for the last valid
        token, cache).
        """
        hn, cache = self._chunk_body(
            params, cache, tokens,
            jnp.asarray(seq, jnp.int32).reshape(1),
            jnp.asarray(start, jnp.int32).reshape(1),
            jnp.asarray(valid, jnp.int32).reshape(1))
        last = jax.lax.dynamic_slice_in_dim(hn, valid - 1, 1, axis=1)
        logits = apply_linear(last, params["lm_head"]).astype(jnp.float32)
        return logits, cache

    def verify_chunk(self, params, cache, tokens, starts, valids):
        """Score a speculative window for EVERY pool row in one batched
        fixed-shape call (the chunked verifier behind ``repro.spec``).

        ``tokens`` (B, C): lane r is pool row r — [last committed token,
        draft_1..draft_k, garbage pad]; ``starts``/``valids`` (B,) data
        (valid = 0 marks a dead lane whose state update is fully masked).
        Returns (logits (B, C, V) f32 at *every* position — index j scores
        the continuation after tokens[:, :j+1] — and the cache with each
        live row's wkv/token-shift state advanced through its window).
        """
        B = tokens.shape[0]
        hn, cache = self._chunk_body(
            params, cache, tokens, jnp.arange(B, dtype=jnp.int32),
            starts, valids)
        logits = apply_linear(hn, params["lm_head"]).astype(jnp.float32)
        return logits, cache

    # ------------------------------------------------------------ quant API
    def quant_groups(self, seq_len: int = 4096) -> list[QuantGroup]:
        cfg = self.cfg
        D, F = cfg.d_model, cfg.d_ff
        groups: list[QuantGroup] = []

        def add(name, path, layer, shape, macs_per_token):
            groups.append(QuantGroup(name, path, layer, tuple(shape),
                                     math.prod(shape), int(macs_per_token * seq_len)))

        add("embed", ("embed",), None, (cfg.vocab_size, D), 0)
        for l in range(cfg.num_layers):
            pre, base = f"L{l:02d}.", ("blocks",)
            for m in ("wr", "wk", "wv", "wg", "wo"):
                add(pre + f"tm.{m}", base + ("tm", m), l, (D, D), D * D)
            add(pre + "cm.wk", base + ("cm", "wk"), l, (D, F), D * F)
            add(pre + "cm.wv", base + ("cm", "wv"), l, (F, D), D * F)
            add(pre + "cm.wr", base + ("cm", "wr"), l, (D, D), D * D)
        add("lm_head", ("lm_head",), None, (D, cfg.vocab_size), D * cfg.vocab_size)
        return groups

    def frozen_bits(self) -> dict[str, int]:
        out = {}
        for g in self.quant_groups():
            if any(g.name.startswith(p) or p in g.name for p in self.cfg.frozen_at_8):
                out[g.name] = 8
        return out
