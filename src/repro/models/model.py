"""Model protocol + dispatch.  Filled in by transformer.py / rwkv.py etc."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class QuantGroup:
    """One quantizable weight group = one RL action step (DESIGN.md §5).

    For scan-stacked transformer layers a group is (layer l, matrix name);
    ``path`` addresses the leaf in the params pytree, ``layer`` the index
    into its stacked leading axis (None for unstacked leaves like lm_head).
    ``n_weights``/``n_macs`` feed the paper's State-of-Quantization metric.
    """

    name: str
    path: tuple[str, ...]
    layer: int | None
    shape: tuple[int, ...]
    n_weights: int
    n_macs: int


def cache_batch_axis(key: str) -> int:
    """Axis of the batch/slot dimension in a decode-cache leaf.

    Every model family lays per-layer state out as ``(L, B, ...)`` and
    per-sequence bookkeeping (``"length"``) as ``(B,)``.  The serving slot
    pool (repro.serve.cache) uses this to splice a batch-1 prefill cache
    into one slot of the pooled cache without knowing the family.

    ``"kv_qmax"`` — the paged pool's per-layer KV code ceiling, shape
    ``(L,)`` — has NO per-sequence axis; returns -1 (replicate).  Only the
    paged pool carries it, so the slot pool's splice never sees -1.
    """
    if key == "kv_qmax":
        return -1
    return 0 if key == "length" else 1


def build_model(cfg):
    """Config -> model object (family dispatch)."""
    from repro.models.transformer import TransformerLM
    from repro.models.rwkv import RWKV6LM

    if cfg.family == "ssm":
        return RWKV6LM(cfg)
    return TransformerLM(cfg)
