"""Mixture-of-Experts FFN: sort-based dispatch with capacity dropping.

Why sort-based (vs the one-hot dispatch einsum): the dispatch einsum is
O(T²·k·cf) FLOPs in local token count T — at 65k tokens/shard it costs more
than the experts themselves by 100×.  Sorting tokens by expert and
scatter/gathering into a (E, C, D) capacity buffer is O(T·D + T log T) and
maps onto GSPMD expert parallelism: the buffer is sharded over experts
("model" axis) while tokens stay batch-sharded — the scatter across those
two shardings is exactly the MoE all-to-all.

Top-k routing follows the configs: softmax gates, renormalized over the
selected k (moonshot top-6, llama4 top-1).  Tokens beyond an expert's
capacity C = ceil(cf · T·k/E) are dropped (standard TPU practice; the
residual path carries them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import batch_axes, constrain, swiglu


def moe_params_shape(E: int, D: int, F: int):
    return {
        "router": (D, E),
        "wg": (E, D, F),
        "wu": (E, D, F),
        "wd": (E, F, D),
    }


def init_moe(key, E: int, D: int, F: int, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    s_in, s_out = D ** -0.5, F ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * s_in).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * s_in).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * s_in).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * s_out).astype(dtype),
    }


def _maybe_dequant_bank(w, dtype):
    """Serving path: Packed expert bank (planes (E,bits,K/8,F), scale
    (E,1,F)) -> dequantized bank (E, K, F).  Traffic from HBM is the packed
    buffer (k/8 bytes/weight); the bf16 bank is a transient."""
    from repro.quant.pack import Packed, dequant_packed

    if not isinstance(w, Packed):
        return w
    deq = jax.vmap(lambda pl, sc: dequant_packed(pl, sc, w.bits))
    bank = deq(w.planes, w.scale)
    return constrain(bank.astype(dtype), "model", None, None)


def _dispatch(x2: jax.Array, idx_k: jax.Array, gate_k: jax.Array, E: int,
              C: int, k: int):
    """Per-group sort-based dispatch: x2 (T, D) -> (buf (E, C, D), meta).

    Vmapped over the batch dim by moe_ffn, so every sort/scatter is LOCAL
    to one sequence's shard — GSPMD partitions batched ops on their batch
    dim natively.  (A single global sort/scatter over all tokens does NOT
    partition: the compiler falls back to full rematerialization — the
    172-334 GB/device failure mode; EXPERIMENTS.md §Perf.)
    """
    T, D = x2.shape
    flat_e = idx_k.reshape(-1)                                 # (T·k,)
    order = jnp.argsort(flat_e, stable=True)                   # priority = token order
    se = flat_e[order]
    src = order // k
    first = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - first[se]
    valid = pos < C
    dst = jnp.where(valid, se * C + pos, E * C)                # E*C = OOB sentinel
    xs = x2[src] * valid[:, None].astype(x2.dtype)
    buf = jnp.zeros((E * C, D), x2.dtype).at[dst].set(xs, mode="drop")
    return buf.reshape(E, C, D), (order, src, dst, valid)


def _undispatch(y_e: jax.Array, gate_k: jax.Array, meta, T: int, k: int):
    order, src, dst, valid = meta
    E, C, D = y_e.shape
    y_flat = y_e.reshape(E * C, D)
    y_sorted = y_flat[jnp.minimum(dst, E * C - 1)] * valid[:, None].astype(y_flat.dtype)
    gate_sorted = gate_k.reshape(-1)[order].astype(y_flat.dtype)
    return jnp.zeros((T, D), y_flat.dtype).at[src].add(
        y_sorted * gate_sorted[:, None])


def _route(x: jax.Array, router: jax.Array, k: int):
    """(gates (…,E), gate_k, idx_k, aux-loss ingredients)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates, k)
    gate_k = gate_k / jnp.maximum(jnp.sum(gate_k, -1, keepdims=True), 1e-9)
    return gates, gate_k, idx_k


def _expert_ffn(bufe, p, dtype):
    """bufe (E, C, D) or (B, E, C, D) — batched expert SwiGLU."""
    wg, wu, wd = (_maybe_dequant_bank(p[m], dtype) for m in ("wg", "wu", "wd"))
    eq_in = "becd,edf->becf" if bufe.ndim == 4 else "ecd,edf->ecf"
    eq_out = "becf,efd->becd" if bufe.ndim == 4 else "ecf,efd->ecd"
    g = jnp.einsum(eq_in, bufe, wg)
    u = jnp.einsum(eq_in, bufe, wu)
    h = swiglu(g, u)
    return jnp.einsum(eq_out, h, wd)


def _aux_loss(gates, idx_k, E: int, k: int):
    me = jnp.mean(gates.reshape(-1, E), axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx_k.reshape(-1)].add(
        1.0).astype(jnp.float32) / max(idx_k.size, 1)
    return E * jnp.sum(me * ce)


def moe_ffn(
    x: jax.Array,              # (B, S, D)
    p: dict,
    *,
    k: int,
    capacity_factor: float = 1.25,
    no_drop: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).

    Under a mesh with a "model" axis this runs the explicit
    expert-parallel path (shard_map + all-to-all, see ``_moe_ep``): the
    GSPMD-auto formulation replicates the dispatch gather/scatter inside
    the layer scan (hundreds of GB/device; EXPERIMENTS.md §Perf).
    Meshless (smoke tests / CPU search): local per-sequence dispatch.
    """
    from repro.compat import ambient_mesh

    mesh = ambient_mesh()
    if mesh is not None and not mesh.empty and "model" in mesh.axis_names:
        return _moe_ep(x, p, k=k, capacity_factor=capacity_factor,
                       no_drop=no_drop, mesh=mesh)
    B, S, D = x.shape
    E = p["router"].shape[1]
    gates, gate_k, idx_k = _route(x, p["router"], k)
    aux = _aux_loss(gates, idx_k, E, k)
    C = S * k if no_drop else int(-(-S * k * capacity_factor // E))
    C = max(8, -(-C // 8) * 8)
    bufe, meta = jax.vmap(
        lambda x2, i, g: _dispatch(x2, i, g, E, C, k))(x, idx_k, gate_k)
    y_e = _expert_ffn(bufe, p, x.dtype)
    y = jax.vmap(lambda ye, gk, m: _undispatch(ye, gk, m, S, k))(
        y_e, gate_k, meta)
    return y.astype(x.dtype), aux


def _moe_ep(x, p, *, k, capacity_factor, no_drop, mesh):
    """Explicit expert parallelism: shard_map over the whole mesh.

    Per device: local top-k + sort-based dispatch into an (E, C_loc, D)
    buffer; all-to-all over "model" exchanges expert slices (each model
    shard owns E/m experts); batched expert FFN; inverse all-to-all;
    local un-dispatch.  Everything inside is device-local — no GSPMD
    guessing — and the a2a is the canonical MoE collective.
    """
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E = p["router"].shape[1]
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.axis_sizes))
    m_sz = sizes["model"]
    if E % m_sz:
        raise ValueError(f"experts {E} must divide model axis {m_sz}")
    # activation layout: tokens sharded over EVERY axis inside the MoE —
    # batch over the (profile) batch axes, sequence over "model" when the
    # batch doesn't already cover it.  Tokens replicated over model would
    # make each expert shard process every token m× (caught by the VMA
    # check); sequence-sharding on entry removes the redundancy.
    from repro.models.common import batch_axes

    baxes = tuple(batch_axes() or ())
    prod = 1
    for a in baxes:
        prod *= sizes[a]
    while baxes and B % prod:
        prod //= sizes[baxes[0]]
        baxes = baxes[1:]
    seq_over_model = "model" not in baxes and S % m_sz == 0
    check_vma = True
    if not seq_over_model and "model" not in baxes:
        check_vma = False  # decode fallback: tiny redundant compute over model
    x_spec = P(baxes if len(baxes) > 1 else (baxes[0] if baxes else None),
               "model" if seq_over_model else None, None)
    B_loc = B // max(prod, 1)
    T_loc = B_loc * (S // m_sz if seq_over_model else S)
    C = T_loc * k if no_drop else int(-(-T_loc * k * capacity_factor // E))
    C = max(8, -(-C // 8) * 8)

    bank_spec = P("model", None, None)

    def local(x_loc, router, wg, wu, wd):
        Bl, Sl, Dl = x_loc.shape
        x2 = x_loc.reshape(Bl * Sl, Dl)
        gates, gate_k, idx_k = _route(x2, router, k)
        aux = _aux_loss(gates, idx_k, E, k)
        red = baxes + (("model",) if seq_over_model else ())
        if red:  # aux varies only across the token-sharded axes
            aux = jax.lax.pmean(aux, axis_name=red if len(red) > 1 else red[0])
        buf, meta = _dispatch(x2, idx_k, gate_k, E, C, k)  # (E, C, D)
        # a2a: (m, E_loc, C, D) -> (E_loc, m·C, D) on each model shard
        buf = buf.reshape(m_sz, E // m_sz, C, Dl)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=2,
                                  tiled=True)          # (1, E_loc, m·C, D)
        recv = recv.reshape(E // m_sz, m_sz * C, Dl)
        y_loc = _expert_ffn(recv, {"wg": wg, "wu": wu, "wd": wd}, x_loc.dtype)
        y_loc = y_loc.reshape(1, E // m_sz, m_sz * C, Dl)
        back = jax.lax.all_to_all(y_loc, "model", split_axis=2, concat_axis=0,
                                  tiled=True)          # (m, E_loc, C, D)
        y_e = back.reshape(E, C, Dl)
        y = _undispatch(y_e, gate_k, meta, Bl * Sl, k)
        return y.reshape(Bl, Sl, Dl).astype(x_loc.dtype), aux

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(x_spec, P(), bank_spec, bank_spec, bank_spec),
        out_specs=(x_spec, P()),
        check_vma=check_vma,
    )
    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    if hasattr(wg, "planes"):  # Packed serving bank: dequant before entry
        wg, wu, wd = (_maybe_dequant_bank(p[m], x.dtype)
                      for m in ("wg", "wu", "wd"))
    y, aux = fn(x, p["router"], wg, wu, wd)
    return y, aux[()] if hasattr(aux, "shape") and aux.shape else aux
