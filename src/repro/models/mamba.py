"""Mamba-style selective SSM branch (used by the Hymba hybrid blocks).

Diagonal selective state space:  per channel d with state size N,

    h_t = exp(Δ_t · A) ⊙ h_{t-1} + (Δ_t · B_t) · x_t
    y_t = C_t · h_t + D ⊙ x_t

with input-dependent Δ (low-rank), B, C.  The recurrence is evaluated as a
``lax.scan`` over chunks with a log-depth ``associative_scan`` inside each
chunk, so peak memory is O(B·chunk·Di·N) instead of O(B·S·Di·N) — the
difference between 105 MB and 13 GB per layer at the train_4k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_linear, dense_init


def init_mamba(key, D: int, Di: int, N: int, ks: int, dtype=jnp.bfloat16):
    R = max(8, D // 16)  # dt low-rank
    keys = jax.random.split(key, 8)
    return {
        "in_x": dense_init(keys[0], D, Di, dtype),
        "in_z": dense_init(keys[1], D, Di, dtype),
        "conv": (jax.random.normal(keys[2], (ks, Di), jnp.float32) * ks ** -0.5).astype(jnp.float32),
        "w_B": dense_init(keys[3], Di, N, jnp.float32),
        "w_C": dense_init(keys[4], Di, N, jnp.float32),
        "dt1": dense_init(keys[5], Di, R, jnp.float32),
        "dt2": dense_init(keys[6], R, Di, jnp.float32),
        "dt_bias": jnp.full((Di,), -4.0, jnp.float32),  # softplus(-4) ≈ 0.018
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))),
        "D_skip": jnp.ones((Di,), jnp.float32),
        "out": dense_init(keys[7], Di, D, dtype, scale=Di ** -0.5),
    }


def _causal_conv(xb: jax.Array, conv: jax.Array, init_state=None):
    """Depthwise causal conv, kernel (ks, Di).  xb: (B, S, Di)."""
    ks = conv.shape[0]
    B, S, Di = xb.shape
    if init_state is None:
        init_state = jnp.zeros((B, ks - 1, Di), xb.dtype)
    xpad = jnp.concatenate([init_state.astype(xb.dtype), xb], axis=1)
    out = jnp.zeros_like(xb, dtype=jnp.float32)
    for j in range(ks):  # static, ks = 4
        out = out + conv[j] * xpad[:, j:j + S].astype(jnp.float32)
    return out.astype(xb.dtype), xpad[:, -(ks - 1):] if ks > 1 else init_state


def _ssm_features(xc, p):
    """(Δ, B_t, C_t) from the conv'd activation."""
    xf = xc.astype(jnp.float32)
    dt = jax.nn.softplus(xf @ p["dt1"] @ p["dt2"] + p["dt_bias"])  # (B,S,Di)
    Bm = xf @ p["w_B"]  # (B,S,N)
    Cm = xf @ p["w_C"]  # (B,S,N)
    return dt, Bm, Cm


def mamba_forward(x: jax.Array, p: dict, *, chunk: int = 64,
                  return_state: bool = False, init_state: dict | None = None,
                  valid=None):
    """x: (B, S, D) (already normalized).  Returns (y (B,S,D), state|None).

    ``init_state`` ({"h", "conv"}, as returned here) continues a cached
    sequence — chunked prefill feeds each chunk the previous chunk's state.
    ``valid`` (traced scalar, or a (B,) vector for per-row lengths) masks
    the Δ of positions ≥ valid to zero so a fixed-shape chunk's garbage
    tail neither decays nor drives the state, and the returned conv state
    ends at the last *valid* token.
    """
    B, S, D = x.shape
    xb = apply_linear(x, p["in_x"])          # (B,S,Di)
    z = apply_linear(x, p["in_z"])
    conv0 = init_state["conv"] if init_state is not None else None
    xc, _ = _causal_conv(xb, p["conv"], init_state=conv0)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bm, Cm = _ssm_features(xc, p)
    if valid is not None:
        valid = jnp.asarray(valid, jnp.int32).reshape(-1)  # scalar -> (1,)
        # Δ = 0 at padding: decay exp(0·A) = 1 and input term 0 — the state
        # passes through the garbage tail untouched
        dt = dt * (jnp.arange(S)[None, :] < valid[:, None])[..., None]
    A = -jnp.exp(p["A_log"])                 # (Di,N), negative
    Di, N = A.shape

    c = min(chunk, S)
    Sp = -(-S // c) * c
    pad = Sp - S

    def pad_t(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xcp, dtp, Bp, Cp = map(pad_t, (xc, dt, Bm, Cm))
    nc = Sp // c
    # (nc, B, c, ...)
    xs = jnp.moveaxis(xcp.reshape(B, nc, c, Di), 1, 0)
    dts = jnp.moveaxis(dtp.reshape(B, nc, c, Di), 1, 0)
    Bs = jnp.moveaxis(Bp.reshape(B, nc, c, N), 1, 0)
    Cs = jnp.moveaxis(Cp.reshape(B, nc, c, N), 1, 0)

    # per-chunk recompute in bwd: don't save (nc, c, Di, N) stacked decays
    @jax.checkpoint
    def chunk_step(h_prev, inp):
        xcc, dtc, Bc, Cc = inp               # (B,c,Di) / (B,c,N)
        decay = jnp.exp(dtc[..., None] * A)  # (B,c,Di,N)
        u = (dtc * xcc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

        def comb(e1, e2):
            a1, u1 = e1
            a2, u2 = e2
            return a1 * a2, a2 * u1 + u2

        Acum, Ucum = jax.lax.associative_scan(comb, (decay, u), axis=1)
        h = Acum * h_prev[:, None] + Ucum    # (B,c,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, Cc)
        return h[:, -1], y

    h0 = (init_state["h"].astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, Di, N), jnp.float32))
    h_last, ys = jax.lax.scan(chunk_step, h0, (xs, dts, Bs, Cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, Di)[:, :S]
    y = y + p["D_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = apply_linear(y, p["out"])
    if return_state:
        ks = p["conv"].shape[0]
        if valid is not None or init_state is not None:
            # last ks-1 inputs of [carried conv state ; valid prefix]
            prev = (conv0.astype(xb.dtype) if conv0 is not None
                    else jnp.zeros((B, ks - 1, Di), xb.dtype))
            xpad = jnp.concatenate([prev, xb], axis=1)
            end = valid if valid is not None else jnp.full((1,), S, jnp.int32)
            idx = end[:, None] + jnp.arange(ks - 1, dtype=jnp.int32)[None, :]
            conv_state = jnp.take_along_axis(
                xpad, jnp.broadcast_to(idx, (B, ks - 1))[..., None], axis=1)
        else:
            conv_state = xb[:, -(ks - 1):]
            if S < ks - 1:
                conv_state = jnp.pad(xb, ((0, 0), (ks - 1 - S, 0), (0, 0)))
        return out, {"h": h_last, "conv": conv_state}
    return out, None


def mamba_step(x: jax.Array, p: dict, state: dict):
    """Single-token decode.  x: (B, 1, D); state: {h (B,Di,N), conv (B,ks-1,Di)}."""
    xb = apply_linear(x, p["in_x"])           # (B,1,Di)
    z = apply_linear(x, p["in_z"])
    xc, conv_state = _causal_conv(xb, p["conv"], init_state=state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bm, Cm = _ssm_features(xc, p)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt[:, 0, :, None] * A)    # (B,Di,N)
    u = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = decay * state["h"] + u
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["D_skip"] * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = apply_linear(y, p["out"])[:, None, :]
    return out, {"h": h, "conv": conv_state}
