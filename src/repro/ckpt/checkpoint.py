"""Dependency-free fault-tolerant checkpointing (no orbax offline).

Layout:  <dir>/step_<N>/
            manifest.json       {step, meta, num_leaves, leaf shapes/dtypes}
            treedef.pkl         pickled jax treedef (QTensor etc. register fine)
            leaves.npz          all array leaves, keyed leaf_<i>

Guarantees:
- **Atomic**: written to ``step_<N>.tmp`` then ``os.rename``d — a crash
  mid-write never corrupts the latest checkpoint (restart uses the newest
  complete directory).
- **Elastic**: leaves are stored as full (host-gathered) arrays; the
  restoring launcher re-places them under whatever mesh/sharding it builds,
  so a 256-chip checkpoint restores onto 512 chips and vice versa
  (dist/elastic.py wraps this).
- **Complete state**: model params, optimizer moments, data cursor, RNG key,
  and the ReLeQ search state all ride in one pytree + meta dict.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil

import jax
import numpy as np


def _step_dirs(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(directory, name)
            if os.path.exists(os.path.join(full, "manifest.json")):
                out.append((int(name.split("_")[1]), full))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    dirs = _step_dirs(directory)
    return dirs[-1][0] if dirs else None


def save(directory: str, step: int, tree, meta: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write a checkpoint; prune to the newest ``keep``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = [np.asarray(x) for x in leaves]
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "leaves.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
        pickle.dump(treedef, f)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({
            "step": step,
            "meta": meta or {},
            "num_leaves": len(arrays),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in arrays],
        }, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune old checkpoints
    for _, old in _step_dirs(directory)[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def restore(directory: str, step: int | None = None):
    """-> (tree, meta, step).  step=None loads the newest complete one."""
    dirs = _step_dirs(directory)
    if not dirs:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    if step is None:
        step, path = dirs[-1]
    else:
        match = [p for s, p in dirs if s == step]
        if not match:
            raise FileNotFoundError(f"step {step} not in {directory}")
        path = match[0]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "treedef.pkl"), "rb") as f:
        treedef = pickle.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves = []
    for i in range(manifest["num_leaves"]):
        arr = data[f"leaf_{i}"]
        want = manifest["leaves"][i]["dtype"]
        if arr.dtype.name != want:
            # npz round-trips ml_dtypes (bfloat16/float8) as raw void bytes
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"], step
