from repro.data.pipeline import SyntheticLMData, markov_batch  # noqa: F401
