"""Deterministic synthetic LM data pipeline (offline container: no corpora).

Design goals (matching a production input pipeline's contract):

- **Learnable**: tokens follow a sparse first-order Markov chain derived from
  the seed, so cross-entropy has real headroom below uniform (≈ log V), loss
  decreases under training, and — what ReLeQ needs — *quantizing weights
  measurably hurts the model's achievable likelihood*.
- **Deterministic & checkpointable**: batch ``i`` of host-shard ``h`` is a
  pure function of ``(seed, i, h)``; the checkpointed cursor is one integer.
- **Shardable / elastic**: the global batch is partitioned by ``(shard,
  num_shards)``; re-sharding after an elastic resize just changes the
  partition arithmetic, no state migration.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_BRANCH = 4  # successors per token: entropy = log2(4) bits/token << log2(V)


def _chain(seed: int, vocab: int) -> np.ndarray:
    """(V, _BRANCH) successor table, deterministic in seed."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, _BRANCH), dtype=np.int64)


def markov_batch(seed: int, index: int, batch: int, seq_len: int,
                 vocab: int, chain: np.ndarray | None = None) -> np.ndarray:
    """(batch, seq_len+1) int32 tokens for next-token training."""
    if chain is None:
        chain = _chain(seed, vocab)
    rng = np.random.default_rng((seed * 1_000_003 + index) % (2 ** 63))
    toks = np.empty((batch, seq_len + 1), np.int64)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    choices = rng.integers(0, _BRANCH, size=(batch, seq_len))
    for t in range(seq_len):
        toks[:, t + 1] = chain[toks[:, t], choices[:, t]]
    return toks.astype(np.int32)


@dataclass
class SyntheticLMData:
    seed: int
    global_batch: int
    seq_len: int
    vocab: int
    shard: int = 0
    num_shards: int = 1
    index: int = 0            # cursor (checkpointed)

    def __post_init__(self):
        if self.global_batch % self.num_shards:
            raise ValueError("global_batch must divide num_shards")
        self._chain = _chain(self.seed, self.vocab)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_shards

    def next(self) -> dict:
        """{"tokens": (B_local, S), "labels": (B_local, S)} int32."""
        # one RNG stream per (global batch index, shard) — deterministic
        toks = markov_batch(self.seed + 7919 * self.shard, self.index,
                            self.local_batch, self.seq_len, self.vocab,
                            self._chain)
        self.index += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def eval_batch(self, batch: int, index: int = 10_000_000) -> dict:
        """Held-out batch (indices far above any training cursor)."""
        toks = markov_batch(self.seed + 104729, index, batch,
                            self.seq_len, self.vocab, self._chain)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # ---- checkpoint protocol ------------------------------------------
    def state_dict(self) -> dict:
        return {"seed": self.seed, "index": self.index,
                "shard": self.shard, "num_shards": self.num_shards}

    def load_state_dict(self, d: dict, *, reshard: tuple[int, int] | None = None):
        assert d["seed"] == self.seed, "data seed mismatch on restore"
        self.index = int(d["index"])
        if reshard is not None:  # elastic resize: new (shard, num_shards)
            self.shard, self.num_shards = reshard
