"""Persistent Pareto archive over (rel-accuracy, SQ, measured latency).

Every candidate policy the search evaluates is offered to the archive;
only non-dominated points survive.  The archive is the durable artifact
of a ReLeQ run — JSON-checkpointed, warm-startable (a new search resumes
against the frontier of every previous run), and the thing ``deploy.py``
pulls winners from.

Dominance is *weak dominance with one strict improvement* over a fixed
objective tuple (maximize ``acc``, minimize ``sq`` and ``latency``).
Two consequences keep insertion **order-independent** (hypothesis-pinned
in tests/test_autotune.py):

- points are identified by (bits, objectives) — the same candidate
  re-measured to different numbers is a distinct point and the dominated
  one is pruned; exact re-insertions are idempotent;
- equal-objective points with different bits are mutually non-dominated
  and both survive (no arbitrary tie-break, which would make the final
  set depend on arrival order).

``core/pareto.py``'s exhaustive enumeration remains the small-network
oracle: ``from_enumeration`` ingests its points, and on enumerable nets
the 2-objective archive frontier equals ``pareto_frontier`` exactly.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

# objective name -> sense (+1 maximize, -1 minimize)
OBJECTIVE_SENSE = {"acc": 1.0, "sq": -1.0, "latency": -1.0}


@dataclass(frozen=True)
class ArchiveEntry:
    """One non-dominated candidate: canonical bits + measured objectives."""

    bits: tuple           # canonical ((name, bits), ...) sorted by name
    acc: float            # relative accuracy (maximize)
    sq: float             # State of Quantization (minimize)
    latency: float | None = None   # measured s/decode-step (minimize)
    reward: float | None = None    # shaped reward at evaluation time
    meta: tuple = ()      # ((key, value), ...) provenance, not compared

    def bits_dict(self) -> dict:
        return {n: b for n, b in self.bits}

    def objective(self, name: str) -> float:
        return getattr(self, name)

    def key(self) -> tuple:
        """Identity: bits + objective values (reward/meta excluded)."""
        return (self.bits, self.acc, self.sq, self.latency)


def dominates(a: ArchiveEntry, b: ArchiveEntry, objectives) -> bool:
    """a weakly dominates b with at least one strict improvement."""
    strict = False
    for name in objectives:
        s = OBJECTIVE_SENSE[name]
        va, vb = s * a.objective(name), s * b.objective(name)
        if va < vb:
            return False
        if va > vb:
            strict = True
    return strict


class ParetoArchive:
    """Dominance-pruned archive with JSON checkpointing and warm-start."""

    def __init__(self, objectives=("acc", "sq", "latency")):
        objectives = tuple(objectives)
        unknown = set(objectives) - set(OBJECTIVE_SENSE)
        if unknown or not objectives:
            raise ValueError(f"objectives={objectives!r}")
        self.objectives = objectives
        self._entries: dict[tuple, ArchiveEntry] = {}
        self.offered = 0
        self.accepted = 0

    # ------------------------------------------------------------- mutate
    def add(self, bits_by_name: dict, *, acc: float, sq: float,
            latency: float | None = None, reward: float | None = None,
            meta: dict | None = None) -> bool:
        """Offer a point; -> True iff it joins the archive (non-dominated).

        Dominated incumbents are pruned; exact duplicates are idempotent.
        """
        if "latency" in self.objectives and latency is None:
            raise ValueError("this archive ranks latency; none given "
                             "(use objectives=('acc', 'sq') without it)")
        entry = ArchiveEntry(
            bits=tuple(sorted((str(n), int(b))
                              for n, b in bits_by_name.items())),
            acc=float(acc), sq=float(sq),
            latency=None if latency is None else float(latency),
            reward=None if reward is None else float(reward),
            meta=tuple(sorted((meta or {}).items())))
        self.offered += 1
        key = entry.key()
        if key in self._entries:
            return False  # idempotent re-offer
        for old in self._entries.values():
            if dominates(old, entry, self.objectives):
                return False
        self._entries = {k: e for k, e in self._entries.items()
                         if not dominates(entry, e, self.objectives)}
        self._entries[key] = entry
        self.accepted += 1
        return True

    def merge(self, other: "ParetoArchive") -> int:
        """Warm-start composition: offer every entry of ``other``."""
        added = 0
        for e in other.entries():
            added += self.add(e.bits_dict(), acc=e.acc, sq=e.sq,
                              latency=e.latency, reward=e.reward,
                              meta=dict(e.meta))
        return added

    # -------------------------------------------------------------- query
    def entries(self) -> list[ArchiveEntry]:
        return sorted(self._entries.values(),
                      key=lambda e: (e.sq, -e.acc, e.bits))

    def __len__(self) -> int:
        return len(self._entries)

    def objective_set(self) -> set:
        return {tuple(e.objective(o) for o in self.objectives)
                for e in self._entries.values()}

    def select(self, mode: str = "knee", *, acc_floor: float = 0.95):
        """Pick a deployment winner from the frontier.

        - ``accuracy``: highest rel-accuracy (ties -> cheapest),
        - ``efficiency``: lowest SQ among entries with acc >= acc_floor,
        - ``latency``: lowest measured latency with acc >= acc_floor,
        - ``knee``: max (acc - sq), the paper's "desired region" utility,
        - ``reward``: highest recorded shaped reward.
        """
        entries = self.entries()
        if not entries:
            return None
        if mode == "accuracy":
            return max(entries, key=lambda e: (e.acc, -e.sq))
        ok = [e for e in entries if e.acc >= acc_floor] or entries
        if mode == "efficiency":
            return min(ok, key=lambda e: (e.sq, -e.acc))
        if mode == "latency":
            with_lat = [e for e in ok if e.latency is not None]
            if with_lat:
                return min(with_lat, key=lambda e: (e.latency, e.sq))
            return min(ok, key=lambda e: (e.sq, -e.acc))
        if mode == "reward":
            with_r = [e for e in entries if e.reward is not None]
            if with_r:
                return max(with_r, key=lambda e: e.reward)
            mode = "knee"
        if mode == "knee":
            return max(entries, key=lambda e: (e.acc - e.sq, -e.sq))
        raise ValueError(f"select mode {mode!r}")

    # ---------------------------------------------------------- persist
    def to_dict(self) -> dict:
        return {
            "objectives": list(self.objectives),
            "entries": [{
                "bits": {n: b for n, b in e.bits},
                "acc": e.acc, "sq": e.sq, "latency": e.latency,
                "reward": e.reward, "meta": dict(e.meta),
            } for e in self.entries()],
        }

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=2)
        os.replace(tmp, path)  # atomic checkpoint: never a torn archive

    @classmethod
    def from_dict(cls, d: dict) -> "ParetoArchive":
        arch = cls(objectives=tuple(d["objectives"]))
        for e in d["entries"]:
            arch.add(e["bits"], acc=e["acc"], sq=e["sq"],
                     latency=e.get("latency"), reward=e.get("reward"),
                     meta=e.get("meta") or {})
        return arch

    @classmethod
    def load(cls, path: str) -> "ParetoArchive":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    @classmethod
    def warm_start(cls, path: str | None,
                   objectives=("acc", "sq", "latency")) -> "ParetoArchive":
        """Load ``path`` if it exists, else a fresh archive — so searches
        resume and compose across runs with one call.

        A loaded archive whose objectives differ from the requested ones
        (e.g. a latency-ranked checkpoint resumed without a latency
        evaluator) is re-ranked under the requested objectives; entries
        missing a now-required objective are dropped (they cannot be
        compared) rather than crashing the search mid-run."""
        objectives = tuple(objectives)
        if path and os.path.exists(path):
            loaded = cls.load(path)
            if loaded.objectives == objectives:
                return loaded
            arch = cls(objectives=objectives)
            for e in loaded.entries():
                if "latency" in objectives and e.latency is None:
                    continue
                arch.add(e.bits_dict(), acc=e.acc, sq=e.sq,
                         latency=e.latency, reward=e.reward,
                         meta=dict(e.meta))
            return arch
        return cls(objectives=objectives)

    # ------------------------------------------------------------ oracle
    @classmethod
    def from_enumeration(cls, points, latency_fn=None) -> "ParetoArchive":
        """Ingest ``core.pareto.enumerate_space`` output (the small-network
        oracle).  ``latency_fn(bits_by_name)`` optionally adds the third
        objective; without it the archive ranks (acc, sq) only — exactly
        the frontier ``core.pareto.pareto_frontier`` extracts."""
        objectives = ("acc", "sq", "latency") if latency_fn else ("acc", "sq")
        arch = cls(objectives=objectives)
        for p in points:
            arch.add(p["bits"], acc=p["acc"], sq=p["quant"],
                     latency=latency_fn(p["bits"]) if latency_fn else None)
        return arch
