"""Asynchronous ReLeQ search: actor/learner orchestrator over a worker pool.

``ReLeQSearch`` (core/search.py) is a *lockstep* loop: every env steps
together, every PPO update waits for the slowest evaluation.  The service
decouples the three roles:

- **actor**: rolls episodes out through a ``deferred``-mode
  :class:`~repro.core.env.QuantEnv` — agent forwards + the analytic SQ
  trace only, never blocking on a retrain — and dispatches the finished
  candidate bits to the evaluator pool;
- **workers** (:mod:`repro.autotune.workers`): short-QAT accuracy and
  hardware-in-the-loop latency, running concurrently, results consumed
  in *completion order*;
- **learner**: finalizes each returned episode's terminal reward
  (``env.reward_for`` on the measured accuracy and the latency-blended
  quant state) into an off-policy buffer and runs a PPO update every
  ``batch_episodes`` completions.  Staleness is bounded: trajectories
  older than ``max_staleness`` policy versions are dropped; anything
  younger is corrected by PPO's own clipped likelihood ratio
  (``exp(logp_new - logp_old)`` *is* the importance weight, and the clip
  bounds its variance) — the standard staleness-bounded off-policy
  treatment for near-on-policy buffers.

Hardware in the reward: with a latency evaluator, the terminal quant
state becomes ``(1 - hw_weight) * SQ + hw_weight * latency/latency_8bit``
— both terms live in (0, 1] with "smaller is cheaper", so the paper's
shaped reward applies unchanged while measured serving cost (HAQ-style)
steers the search alongside the paper's analytic SQ.

Every evaluated candidate is offered to the Pareto archive, making the
search resumable and composable across runs (``archive.warm_start``).
"""
from __future__ import annotations

import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, wait
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune.archive import ParetoArchive
from repro.autotune.workers import AccuracyEvaluator, EvaluatorPool
from repro.core.agent import init_agent
from repro.core.env import STATE_DIM
from repro.core.ppo import PPO, PPOConfig
from repro.core.search import SearchResult


@dataclass
class ServiceConfig:
    num_workers: int = 4       # evaluation threads
    max_inflight: int = 8      # episodes awaiting evaluation
    batch_episodes: int = 4    # completed episodes per PPO update
    max_staleness: int = 3     # drop trajectories older than this many
    #                            policy versions (importance correction
    #                            only bounds variance near-on-policy)
    in_order: bool = False     # True: consume completions in submission
    #                            order (deterministic; used by tests)
    hw_weight: float = 0.5     # latency-ratio share of the terminal quant
    #                            state when a latency evaluator is present
    seed: int = 0


@dataclass
class _Episode:
    states: np.ndarray         # (T, STATE_DIM)
    actions: np.ndarray        # (T,)
    logps: np.ndarray          # (T,)
    values: np.ndarray         # (T,)
    rewards: np.ndarray        # (T,) — terminal entry provisional
    probs: np.ndarray          # (T, A)
    bits: dict
    quant: float               # final State of Quantization
    version: int               # policy version at rollout time
    index: int                 # submission order
    result: object = None      # EvalResult once evaluated
    final_reward: float = 0.0
    q_eff: float = 0.0


class AutotuneService:
    """Asynchronous hardware-in-the-loop ReLeQ search.

    ``make_env`` is any ReLeQSearch-compatible factory; the service runs
    its env in ``deferred`` mode and evaluates candidates through the
    worker pool.  Factories exposing ``.evaluate`` / ``.eval_cache``
    (``make_lm_env_factory``, ``CNNTask.make_env_factory``) share their
    memo-cache with the pool automatically.
    """

    def __init__(self, make_env, *, latency_eval=None,
                 ppo_config: PPOConfig | None = None,
                 archive: ParetoArchive | None = None,
                 config: ServiceConfig | None = None,
                 accuracy_thread_safe: bool = False,
                 registry=None, tracer=None):
        from repro.obs import Registry, get_logger
        from repro.obs.trace import NULL_TRACER

        self.cfg = config or ServiceConfig()
        self.obs = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._log = get_logger("autotune")
        self.env = make_env(0)
        self.env.eval_mode = "deferred"
        # prefer the factory's RAW compute + shared cache so the pool is
        # the single memo layer; a bare cached evaluate still works (the
        # EvalCache re-entrancy guard keeps self-layering deadlock-free)
        accuracy_fn = (getattr(make_env, "compute", None)
                       or getattr(make_env, "evaluate", None)
                       or self.env.evaluate)
        cache = getattr(make_env, "eval_cache", None)
        self.pool = EvaluatorPool(
            AccuracyEvaluator(accuracy_fn, cache=cache,
                              thread_safe=accuracy_thread_safe),
            latency_eval, num_workers=self.cfg.num_workers,
            registry=self.obs, tracer=self.tracer)
        objectives = ("acc", "sq", "latency") if latency_eval is not None \
            else ("acc", "sq")
        if archive is not None and "latency" in archive.objectives \
                and latency_eval is None:
            # fail at construction, not on the first completed episode
            raise ValueError(
                "archive ranks latency but no latency evaluator is "
                "configured — pass one, or warm-start the archive with "
                "objectives=('acc', 'sq')")
        self.archive = archive if archive is not None \
            else ParetoArchive(objectives=objectives)
        num_actions = len(self.env.bitset)
        key = jax.random.PRNGKey(self.cfg.seed)
        self.ppo = PPO(init_agent(key, STATE_DIM, num_actions),
                       ppo_config if ppo_config is not None else PPOConfig())
        self.rng = jax.random.PRNGKey(self.cfg.seed + 1)
        self.version = 0
        self._buffer: list[_Episode] = []
        self._stale_dropped = 0
        self._updates = 0
        # search-side instruments: evaluator staleness at consumption
        # (how off-policy the learner actually runs), episode/update
        # counters, archive level
        obs = self.obs
        self._c_episodes = obs.counter("autotune.episodes")
        self._c_updates = obs.counter("autotune.ppo_updates")
        self._c_stale = obs.counter("autotune.stale_dropped",
                                    desc="episodes older than max_staleness")
        self._h_staleness = obs.histogram(
            "autotune.staleness", unit="versions",
            buckets=(0, 1, 2, 3, 5, 8, 13),
            desc="policy versions between rollout and PPO consumption")
        self._g_archive = obs.gauge("autotune.archive_size")

    # ----------------------------------------------------------- actor
    def _rollout(self, index: int) -> _Episode:
        with self.tracer.span("episode.rollout", episode=index,
                              version=self.version):
            return self._rollout_inner(index)

    def _rollout_inner(self, index: int) -> _Episode:
        env = self.env
        obs = env.reset()
        T, A = env.T, len(env.bitset)
        states = np.zeros((T, STATE_DIM), np.float32)
        actions = np.zeros((T,), np.int32)
        logps = np.zeros((T,), np.float32)
        values = np.zeros((T,), np.float32)
        rewards = np.zeros((T,), np.float32)
        probs = np.zeros((T, A), np.float32)
        carry = self.ppo.initial_carry(1)
        info = {}
        for t in range(T):
            self.rng, sub = jax.random.split(self.rng)
            carry, act, logp, val, pr = self.ppo.act(
                carry, jnp.asarray(obs)[None], sub)
            a = int(np.asarray(act)[0])
            states[t] = obs
            actions[t] = a
            logps[t] = float(np.asarray(logp)[0])
            values[t] = float(np.asarray(val)[0])
            probs[t] = np.asarray(pr)[0]
            obs, reward, done, info = env.step(a)
            rewards[t] = reward  # terminal entry patched on completion
        return _Episode(states, actions, logps, values, rewards, probs,
                        bits=dict(info["bits"]), quant=float(info["quant"]),
                        version=self.version, index=index)

    # --------------------------------------------------------- learner
    def _finalize(self, ep: _Episode, result) -> None:
        q_eff = ep.quant
        ratio = result.latency_ratio()
        if ratio is not None and self.cfg.hw_weight > 0:
            w = self.cfg.hw_weight
            q_eff = (1.0 - w) * ep.quant + w * min(ratio, 1.0)
        ep.result = result
        ep.q_eff = q_eff
        ep.final_reward = self.env.reward_for(result.acc, q_eff)
        ep.rewards[-1] = ep.final_reward
        self._buffer.append(ep)

    def _maybe_update(self, force: bool = False) -> None:
        if not self._buffer:
            return
        if len(self._buffer) < self.cfg.batch_episodes and not force:
            return
        fresh = [e for e in self._buffer
                 if self.version - e.version <= self.cfg.max_staleness]
        dropped = len(self._buffer) - len(fresh)
        self._stale_dropped += dropped
        self._c_stale.inc(dropped)
        self._buffer.clear()
        if not fresh:
            return
        for e in fresh:  # staleness actually consumed by the learner
            self._h_staleness.observe(self.version - e.version)
        traj = {
            "states": np.stack([e.states for e in fresh]),
            "actions": np.stack([e.actions for e in fresh]),
            "logp_old": np.stack([e.logps for e in fresh]),
            "values": np.stack([e.values for e in fresh]),
            "rewards": np.stack([e.rewards for e in fresh]),
        }
        with self.tracer.span("ppo.update", episodes=len(fresh),
                              version=self.version, stale_dropped=dropped):
            self.ppo.update(traj)
        self.version += 1
        self._updates += 1
        self._c_updates.inc()

    # ------------------------------------------------------------- run
    def run(self, episodes: int, log_every: int = 0) -> SearchResult:
        cfg = self.cfg
        result = SearchResult(best_bits={}, best_reward=-np.inf)
        inflight: deque = deque()   # (future, episode) in submission order
        submitted = completed = 0
        evals_to_best = 0
        t_start = time.perf_counter()

        def consume(ep: _Episode, res) -> None:
            nonlocal completed, evals_to_best
            self._finalize(ep, res)
            completed += 1
            result.episodes.append({
                "episode": ep.index, "env": 0,
                "reward": ep.final_reward,
                "mean_reward": float(ep.rewards.mean()),
                "acc": res.acc, "quant": ep.quant, "q_eff": ep.q_eff,
                "latency": res.latency, "latency_ratio": res.latency_ratio(),
                "bits": dict(ep.bits), "version": ep.version,
                "staleness": self.version - ep.version,
                "cache_hit": res.acc_cache_hit,
            })
            result.prob_evolution.append(ep.probs)
            if ep.final_reward > result.best_reward:
                result.best_reward = ep.final_reward
                result.best_bits = dict(ep.bits)
                evals_to_best = completed
            self.archive.add(ep.bits, acc=res.acc, sq=ep.quant,
                             latency=res.latency, reward=ep.final_reward,
                             meta={"episode": ep.index})
            self.tracer.instant("archive.add", episode=ep.index,
                                reward=ep.final_reward, acc=res.acc,
                                size=len(self.archive))
            self._c_episodes.inc()
            self._g_archive.set(len(self.archive))
            self._maybe_update()
            if log_every and completed % log_every == 0:
                self._log.event(
                    "episode", episode=completed,
                    reward=ep.final_reward, acc=res.acc, quant=ep.quant,
                    staleness=self.version - ep.version,
                    version=self.version, archive=len(self.archive))

        while completed < episodes:
            # actor: keep the evaluation window full
            while submitted < episodes and len(inflight) < cfg.max_inflight:
                ep = self._rollout(submitted)
                inflight.append((self.pool.submit(ep.bits), ep))
                submitted += 1
            if cfg.in_order:
                fut, ep = inflight.popleft()
                consume(ep, fut.result())
                continue
            # out-of-order: drain whatever is done, else block for one
            done_idx = [i for i, (f, _) in enumerate(inflight) if f.done()]
            if not done_idx:
                wait([f for f, _ in inflight], return_when=FIRST_COMPLETED)
                done_idx = [i for i, (f, _) in enumerate(inflight)
                            if f.done()]
            for i in sorted(done_idx, reverse=True):
                fut, ep = inflight[i]
                del inflight[i]
                consume(ep, fut.result())

        self._maybe_update(force=True)
        wall = time.perf_counter() - t_start
        result.cache_stats = self.pool.accuracy.cache.stats()
        result.service_stats = {
            "episodes": completed,
            "wall_s": wall,
            "episodes_per_s": completed / wall if wall > 0 else 0.0,
            "updates": self._updates,
            "policy_version": self.version,
            "stale_dropped": self._stale_dropped,
            "evals_to_best": evals_to_best,
            "archive_size": len(self.archive),
            "pool": self.pool.stats(),
        }
        return result

    def shutdown(self) -> None:
        self.pool.shutdown()
