"""Evaluation workers: the two evaluator kinds behind one interface.

A candidate bitwidth policy is scored on up to two axes:

- **accuracy** (`AccuracyEvaluator`): the existing short-QAT proxy — any
  ``evaluate(bits_by_name) -> rel_acc`` callable (LM likelihood ratio,
  CNN accuracy ratio, or a synthetic oracle).  Results memoize in a
  shared :class:`~repro.core.evalcache.EvalCache`; evaluators that are
  not thread-safe (they advance a data cursor, e.g. the QAT retrain) are
  serialized behind a lock while distinct-candidate latency measurements
  still overlap.
- **latency** (hardware-in-the-loop): measured seconds per decode step
  of the candidate policy:

  * :class:`EngineLatencyEvaluator` packs the candidate's weights
    (``quant.pack``) and times real ``ServeEngine`` decode steps — the
    HAQ-style signal, on whatever accelerator is attached;
  * :class:`HLOLatencyEvaluator` lowers + compiles the packed decode
    step and rooflines the optimized HLO (``launch/hlo_analysis`` —
    trip-count-corrected flops/bytes) when no accelerator is present;
  * :class:`AnalyticLatencyEvaluator` is the free closed-form fallback
    (``costmodel.tpu_decode_time``) for tests and benches.

  Each reports ``ref_latency`` at the all-8-bit reference so the service
  can fold the *ratio* into the reward alongside SQ.

- **draftability** (:class:`DraftabilityEvaluator`): the candidate bits
  play the *quantized self-draft* under a fixed 8-bit target
  (``repro.spec``), and the measured quantity is end-to-end speculative
  seconds per emitted token — so the reward optimizes what the archive's
  frontier is actually consumed for by ``SpecConfig(draft_policy=...)``:
  serving throughput with this policy drafting, acceptance included.

:class:`EvaluatorPool` fans candidates out to a thread pool and returns
futures — the async service consumes them out of order.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass

from repro.core import costmodel
from repro.core.evalcache import EvalCache


@dataclass(frozen=True)
class EvalResult:
    """One worker's verdict on a candidate policy."""

    acc: float                      # relative accuracy in (0, ~1.2]
    sq: float | None = None         # filled by the service (analytic)
    latency: float | None = None    # s/decode-step at the candidate
    ref_latency: float | None = None  # same measurement at all-8-bit
    acc_cache_hit: bool = False
    eval_seconds: float = 0.0

    def latency_ratio(self) -> float | None:
        """latency / 8-bit reference, in (0, 1] for any sub-8-bit policy."""
        if self.latency is None or not self.ref_latency:
            return None
        return self.latency / self.ref_latency


class AccuracyEvaluator:
    """Short-QAT accuracy proxy behind the shared memo-cache.

    ``thread_safe=False`` (default) serializes the underlying callable —
    the QAT retrain advances a data cursor and shares jit buffers, so two
    threads inside it would race.  Device-parallel evaluators (one pod
    per worker, or a pure function) pass ``thread_safe=True``.
    """

    def __init__(self, fn, *, cache: EvalCache | None = None,
                 thread_safe: bool = False):
        self.fn = fn
        self.cache = cache if cache is not None else EvalCache()
        self._lock = None if thread_safe else threading.Lock()

    def __call__(self, bits_by_name: dict) -> tuple[float, bool]:
        def compute():
            if self._lock is not None:
                with self._lock:
                    return float(self.fn(bits_by_name))
            return float(self.fn(bits_by_name))

        value, hit = self.cache.get_or_compute(bits_by_name, compute)
        return float(value), hit


class _LatencyBase:
    """Shared cache + 8-bit reference plumbing for latency evaluators."""

    def __init__(self, group_names, frozen=None):
        self.group_names = tuple(group_names)
        self.frozen = dict(frozen or {})
        self.cache = EvalCache()
        self._ref: float | None = None

    def _measure(self, bits_by_name: dict) -> float:  # pragma: no cover
        raise NotImplementedError

    def __call__(self, bits_by_name: dict) -> tuple[float, float]:
        """-> (latency, ref_latency) in seconds per decode step."""
        if self._ref is None:
            ref_bits = {n: self.frozen.get(n, 8) for n in self.group_names}
            self._ref, _ = self.cache.get_or_compute(
                ref_bits, lambda: self._measure(ref_bits))
        lat, _ = self.cache.get_or_compute(
            bits_by_name, lambda: self._measure(bits_by_name))
        return float(lat), float(self._ref)


class AnalyticLatencyEvaluator(_LatencyBase):
    """Closed-form TPU decode roofline (``costmodel.tpu_decode_time``)."""

    def __init__(self, groups, frozen=None, *, batch: int = 1):
        super().__init__((g.name for g in groups), frozen)
        self.groups = list(groups)
        self.batch = batch

    def _measure(self, bits_by_name: dict) -> float:
        vec = [bits_by_name.get(g.name, 8) for g in self.groups]
        return costmodel.tpu_decode_time(vec, self.groups, batch=self.batch)


class HLOLatencyEvaluator(_LatencyBase):
    """No-accelerator stand-in: compile the candidate's packed decode step
    and roofline the optimized HLO (loop-corrected flops / HBM bytes per
    ``launch/hlo_analysis``) against TPU-v5e peaks.  Structure-accurate —
    it sees exactly the bitplane buffers ``quant.pack`` would serve — at
    one XLA compile per distinct candidate (memoized)."""

    def __init__(self, model, *, batch: int = 1, max_len: int = 32,
                 peak=costmodel.V5E_PEAK_FLOPS, bw=costmodel.V5E_HBM_BW):
        groups = model.quant_groups()
        super().__init__((g.name for g in groups), model.frozen_bits())
        self.model = model
        self.batch = batch
        self.max_len = max_len
        self.peak, self.bw = peak, bw

    def _measure(self, bits_by_name: dict) -> float:
        import jax

        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.specs import cache_struct, sds, serving_params_struct
        from repro.quant.policy import QuantPolicy

        policy = QuantPolicy.from_array(
            self.group_names, [bits_by_name[n] for n in self.group_names])
        sparams = serving_params_struct(self.model, policy)
        cache = cache_struct(self.model, self.batch, self.max_len)
        tokens = sds((self.batch, 1), "int32")
        model = self.model

        def step(sp, c, t):
            return model.decode_step(sp, c, t)

        compiled = jax.jit(step).lower(sparams, cache, tokens).compile()
        costs = analyze_hlo(compiled.as_text())
        return max(costs.flops / self.peak, costs.traffic_bytes / self.bw)


class EngineLatencyEvaluator(_LatencyBase):
    """Hardware-in-the-loop: pack the candidate policy and time real
    ``ServeEngine`` decode steps with every row occupied.  The measured
    wall time per engine step — prefill excluded, jit warmup excluded —
    is the serving cost the reward sees.

    Inside an :class:`EvaluatorPool` the timing runs under the pool's
    measurement lock, so it never overlaps a serialized QAT retrain (or
    another timing) on the shared device.  A ``thread_safe=True``
    accuracy evaluator opts out of that lock — only pair it with this
    evaluator when accuracy work runs on *different* devices, or the
    memoized first measurement will bake in their contention.

    ``kv_quant=True`` extends the candidate space with the model's
    per-layer KV-cache groups (``model.kv_quant_groups()``): any
    ``kv.L..`` keys in ``bits_by_name`` become the engine's per-layer
    ``kv_bits`` list, so the HAQ-style KV action is priced by the same
    wall-clock measurement as the weight bits.  The 8-bit reference then
    runs with an int8 KV pool (uniform ``kv.* = 8``), making the ratio a
    pure like-for-like bitwidth effect."""

    def __init__(self, model, params, *, num_slots: int = 2,
                 prompt_len: int = 4, decode_steps: int = 8,
                 warmup_steps: int = 2, cache: str = "paged",
                 block_size: int = 8, prefill_chunk: int = 8,
                 vocab: int | None = None, seed: int = 0,
                 kv_quant: bool = False):
        groups = model.quant_groups()
        names = [g.name for g in groups]
        self.weight_group_names = tuple(names)
        self.kv_group_names: tuple = ()
        if kv_quant:
            if cache != "paged":
                raise ValueError("kv_quant requires cache='paged'")
            self.kv_group_names = tuple(
                g.name for g in model.kv_quant_groups())
            names += list(self.kv_group_names)
        super().__init__(names, model.frozen_bits())
        self.model, self.params = model, params
        self.num_slots = num_slots
        self.prompt_len = prompt_len
        self.decode_steps = decode_steps
        self.warmup_steps = warmup_steps
        self.engine_kw = dict(cache=cache)
        if cache == "paged":
            self.engine_kw.update(block_size=block_size,
                                  prefill_chunk=prefill_chunk)
        self.vocab = vocab if vocab is not None else model.cfg.vocab_size
        self.seed = seed

    def _measure(self, bits_by_name: dict) -> float:
        import numpy as np

        from repro.quant.policy import QuantPolicy
        from repro.serve import ServeEngine

        policy = QuantPolicy.from_array(
            self.weight_group_names,
            [bits_by_name.get(n, 8) for n in self.weight_group_names])
        # "kv."-prefixed groups are serving-cache state, not weights: they
        # route to the pool's per-layer kv_bits knob, not the pack policy
        kv_kw = {}
        kv_named = {n: int(bits_by_name[n]) for n in self.kv_group_names
                    if n in bits_by_name}
        if kv_named:
            kv_kw["kv_bits"] = [kv_named.get(n, 8)
                                for n in self.kv_group_names]
        gen = self.warmup_steps + self.decode_steps + 2
        max_len = self.prompt_len + gen + 1
        engine = ServeEngine.from_params(
            self.model, self.params, policy, num_slots=self.num_slots,
            max_len=max_len, **self.engine_kw, **kv_kw)
        rng = np.random.default_rng(self.seed)
        for _ in range(self.num_slots):
            engine.submit(rng.integers(0, self.vocab, self.prompt_len), gen)
        while engine.num_running < self.num_slots:  # admit + prefill
            engine.step()
        for _ in range(self.warmup_steps):
            engine.step()
        t0 = time.perf_counter()
        for _ in range(self.decode_steps):
            engine.step()
        return (time.perf_counter() - t0) / self.decode_steps


class DraftabilityEvaluator(_LatencyBase):
    """Hardware-in-the-loop *draftability*: how fast does the fixed 8-bit
    target serve when the CANDIDATE policy plays the quantized self-draft?

    Measures end-to-end speculative seconds per emitted token over real
    ``ServeEngine`` steps — draft roll, batched verify, and rejection
    overhead all included, so a candidate that proposes quickly but gets
    rejected scores exactly as badly as it serves.  The reference is the
    all-8-bit "draft" (a draft as expensive as the target — speculation's
    no-win point), so ``latency_ratio() < 1`` iff the candidate actually
    accelerates serving end to end.  Like :class:`EngineLatencyEvaluator`
    this must run under the pool's measurement lock."""

    def __init__(self, model, params, *, k: int = 4, num_slots: int = 2,
                 prompt_len: int = 4, decode_steps: int = 6,
                 warmup_steps: int = 2, block_size: int = 8,
                 prefill_chunk: int = 8, vocab: int | None = None,
                 seed: int = 0):
        groups = model.quant_groups()
        super().__init__((g.name for g in groups), model.frozen_bits())
        self.model, self.params = model, params
        self.k = k
        self.num_slots = num_slots
        self.prompt_len = prompt_len
        self.decode_steps = decode_steps
        self.warmup_steps = warmup_steps
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.vocab = vocab if vocab is not None else model.cfg.vocab_size
        self.seed = seed
        self._sparams8 = None  # 8-bit target, packed once and reused

    def _measure(self, bits_by_name: dict) -> float:
        import numpy as np

        from repro.quant.policy import QuantPolicy
        from repro.quant.qat import policy_for
        from repro.serve import ServeEngine
        from repro.spec import SpecConfig
        from repro.train.serve import quantize_for_serving

        if self._sparams8 is None:
            self._sparams8 = quantize_for_serving(
                self.model, self.params, policy_for(self.model, 8))
        policy = QuantPolicy.from_array(
            self.group_names, [bits_by_name[n] for n in self.group_names])
        # budget so no request finishes mid-measurement (an idle row would
        # charge the candidate for scheduling, not drafting)
        gen = (self.warmup_steps + self.decode_steps + 2) * (self.k + 1)
        max_len = self.prompt_len + gen + 1
        engine = ServeEngine(
            self.model, self._sparams8, num_slots=self.num_slots,
            max_len=max_len, cache="paged", block_size=self.block_size,
            prefill_chunk=self.prefill_chunk,
            spec=SpecConfig(k=self.k, draft_policy=policy))
        rng = np.random.default_rng(self.seed)
        for _ in range(self.num_slots):
            engine.submit(rng.integers(0, self.vocab, self.prompt_len), gen)
        while engine.num_running < self.num_slots:  # admit + prefill
            engine.step()
        for _ in range(self.warmup_steps):
            engine.step()
        tok0 = engine.metrics()["tokens_total"]
        t0 = time.perf_counter()
        for _ in range(self.decode_steps):
            engine.step()
        dt = time.perf_counter() - t0
        emitted = engine.metrics()["tokens_total"] - tok0
        return dt / max(emitted, 1)


class EvaluatorPool:
    """Thread pool running (accuracy, latency) evaluations per candidate.

    ``submit`` returns a :class:`Future` resolving to :class:`EvalResult`;
    the service consumes completions out of order.  Accuracy results share
    one :class:`EvalCache` (hit-rate surfaced via :meth:`stats`); latency
    evaluators carry their own cache keyed on the same canonical tuple.
    """

    def __init__(self, accuracy: AccuracyEvaluator, latency=None, *,
                 num_workers: int = 4, registry=None, tracer=None):
        from repro.obs import Registry
        from repro.obs.trace import NULL_TRACER

        self.accuracy = accuracy
        self.latency = latency
        self.obs = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._h_eval = self.obs.histogram("autotune.eval_seconds", unit="s")
        self._c_acc_hits = self.obs.counter("autotune.acc_cache_hits")
        self.num_workers = max(1, int(num_workers))
        self._ex = ThreadPoolExecutor(
            max_workers=self.num_workers,
            thread_name_prefix="autotune-eval")
        self._submitted = 0
        self._completed = 0
        self._lock = threading.Lock()
        # wall-clock latency measurements must not overlap retrains (or
        # each other) on a shared device — one pool-wide measurement
        # lock serializes both, so a serialized accuracy evaluator and
        # an EngineLatencyEvaluator timing never contend.  thread_safe
        # accuracy evaluators (per-worker devices / pure oracles) opt
        # out of the shared lock and keep running concurrently.
        self._measure_lock = threading.Lock()
        if accuracy._lock is not None:
            accuracy._lock = self._measure_lock

    def _evaluate(self, bits_by_name: dict) -> EvalResult:
        # worker threads record into the shared tracer concurrently: each
        # shows up as its own Perfetto track (named after the executor's
        # thread_name_prefix), spans balance per-thread
        tr = self.tracer
        if tr.enabled:
            tr.name_thread(threading.current_thread().name)
        t0 = time.perf_counter()
        with tr.span("eval.accuracy") as sp:
            acc, hit = self.accuracy(bits_by_name)
            sp.set(cache_hit=hit)
        if hit:
            self._c_acc_hits.inc()
        lat = ref = None
        if self.latency is not None:
            with self._measure_lock, tr.span("eval.latency"):
                lat, ref = self.latency(bits_by_name)
        with self._lock:
            self._completed += 1
        dt = time.perf_counter() - t0
        self._h_eval.observe(dt)
        return EvalResult(acc=acc, latency=lat, ref_latency=ref,
                          acc_cache_hit=hit, eval_seconds=dt)

    def submit(self, bits_by_name: dict) -> Future:
        with self._lock:
            self._submitted += 1
        return self._ex.submit(self._evaluate, dict(bits_by_name))

    def stats(self) -> dict:
        out = {
            "workers": self.num_workers,
            "submitted": self._submitted,
            "completed": self._completed,
            "acc_cache": self.accuracy.cache.stats(),
        }
        if self.latency is not None and hasattr(self.latency, "cache"):
            out["latency_cache"] = self.latency.cache.stats()
        return out

    def shutdown(self) -> None:
        self._ex.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
