"""Deploy an archived ReLeQ policy into a live serving engine.

The search's output (a Pareto-archive entry) becomes a served model in
one call: select a winner, bit-pack the weights at its per-layer policy
(``quant.pack`` via ``train.serve.quantize_for_serving``), and hot-swap
the packed params into a *running* :class:`~repro.serve.ServeEngine`.

Hot-swap contract: the engine's running rows are drained first (their KV
caches were produced by the old weights — greedy continuations under new
weights would silently fork from the served stream) while queued
admissions are held back; queued and future requests then prefill and
decode entirely under the new policy.  Because the engine threads
``sparams`` through every jit'd prefill/decode call as data, the swap is
one attribute store; only genuinely new packed shapes recompile.

``ab_parity_check`` is the acceptance gate: the swapped engine must
serve token-identical greedy output to a fresh engine built directly
with the new policy (pinned in tests/test_autotune.py).
"""
from __future__ import annotations

from repro.autotune.archive import ArchiveEntry, ParetoArchive
from repro.quant.policy import QuantPolicy
from repro.serve.request import SamplingParams


def policy_from_entry(model, entry: ArchiveEntry) -> QuantPolicy:
    """Archive entry -> QuantPolicy aligned with the model's groups."""
    names = tuple(g.name for g in model.quant_groups())
    bits = entry.bits_dict()
    missing = [n for n in names if n not in bits]
    if missing:
        raise KeyError(f"archive entry lacks bits for groups: {missing}")
    return QuantPolicy.from_array(names, [bits[n] for n in names],
                                  frozen=model.frozen_bits())


def compile_policy(model, params, policy: QuantPolicy):
    """Bit-pack training params at ``policy`` (the serving layout)."""
    from repro.train.serve import quantize_for_serving

    return quantize_for_serving(model, params, policy)


def hot_swap(engine, sparams, *, drain: bool = True,
             max_steps: int = 100_000) -> dict:
    """Swap packed weights into a running engine; -> swap report.

    ``drain=True`` finishes every *mid-decode* sequence under the old
    weights first (their KV caches were prefilled by those weights).
    Queued requests are held back during the drain — a queued request
    has no KV yet, so it prefills *and* decodes entirely under the new
    policy, exactly like post-swap submissions.  The swap itself is
    atomic w.r.t. the engine loop: ``step()`` reads ``engine.sparams``
    once per call.  The paged pool's prefix trie is flushed either way:
    its cached KV blocks were computed under the old weights, and a
    post-swap request hitting them would decode against stale state —
    the weight policy is a key dimension of the prefix cache, realized
    as invalidation-on-swap.
    """
    drained_steps = 0
    if drain:
        # hold admissions back so the drain can't start old-weight prefills
        held = []
        while engine.queue:
            held.append(engine.queue.pop())
        try:
            while engine.num_running:
                if drained_steps >= max_steps:
                    raise RuntimeError(
                        f"hot_swap: engine not drained after {max_steps} "
                        f"steps")
                engine.step()
                drained_steps += 1
        finally:
            for req in reversed(held):  # restore FIFO order at the head
                engine.queue.push_front(req)
    engine.sparams = sparams
    flush = getattr(engine.pool, "flush_prefix_cache", None)
    if flush is not None:
        flush()
    return {"drained_steps": drained_steps,
            "swapped_at_step": engine.steps,
            "prefix_cache_flushed": flush is not None}


def _engine_geometry(engine) -> dict:
    kw = dict(num_slots=engine.pool.num_slots, max_len=engine.pool.max_len,
              cache=engine.cache_kind)
    if engine.cache_kind == "paged":
        kw.update(block_size=engine.pool.block_size,
                  num_blocks=engine.pool.num_blocks,
                  prefill_chunk=engine.prefill_chunk,
                  prefix_cache=engine.pool.prefix_cache)
    return kw


def ab_parity_check(engine, model, sparams, prompts, max_new_tokens: int,
                    *, max_steps: int = 100_000) -> dict:
    """A/B gate: the (swapped) engine vs a fresh engine at ``sparams``.

    Greedy-decodes every prompt on both engines and compares token
    streams.  -> report with ``match`` plus the per-prompt outputs.
    Raises nothing — the caller decides whether a mismatch is fatal.
    """
    from repro.serve.engine import ServeEngine

    fresh = ServeEngine(model, sparams, **_engine_geometry(engine))
    greedy = SamplingParams()  # temperature 0 = deterministic argmax
    outputs = {"live": [], "fresh": []}
    for label, eng in (("live", engine), ("fresh", fresh)):
        ids = [eng.submit(p, max_new_tokens, sampling=greedy)
               for p in prompts]
        eng.run_until_drained(max_steps=max_steps)
        outputs[label] = [eng.output(i) for i in ids]
    match = outputs["live"] == outputs["fresh"]
    return {"match": match, "prompts": len(prompts),
            "outputs": outputs}


def deploy(archive: ParetoArchive, model, params, engine, *,
           select: str = "knee", acc_floor: float = 0.95,
           parity_prompts=None, max_new_tokens: int = 8,
           drain: bool = True) -> tuple[QuantPolicy, dict]:
    """Archive winner -> packed weights -> hot-swap (+ optional parity).

    One-command path from "search finished" to "policy is serving":
    select an entry, compile it, swap it into ``engine``, and (when
    ``parity_prompts`` given) verify token parity against a fresh engine.
    -> (deployed policy, report).
    """
    entry = archive.select(select, acc_floor=acc_floor)
    if entry is None:
        raise ValueError("archive is empty — nothing to deploy")
    policy = policy_from_entry(model, entry)
    sparams = compile_policy(model, params, policy)
    report = {"entry": {"acc": entry.acc, "sq": entry.sq,
                        "latency": entry.latency, "reward": entry.reward},
              "select": select,
              "avg_bits": policy.average_bits()}
    old_sparams = engine.sparams
    report.update(hot_swap(engine, sparams, drain=drain))
    if parity_prompts is not None:
        report["parity"] = ab_parity_check(
            engine, model, sparams, parity_prompts, max_new_tokens)
        if not report["parity"]["match"]:
            # a policy that fails its own gate must not stay live
            engine.sparams = old_sparams
            raise AssertionError(f"A/B parity failed (rolled back to the "
                                 f"previous policy): {report['parity']}")
    return policy, report
