"""repro.autotune: asynchronous hardware-in-the-loop ReLeQ search service.

The paper's RL search, run as a service against the production serving
stack instead of a synchronous offline loop:

- service.py   actor/learner orchestrator — PPO updates decoupled from
               episode evaluation via an off-policy buffer with
               staleness-bounded importance correction
- workers.py   evaluator pool: short-QAT accuracy + hardware-in-the-loop
               latency (real ServeEngine decode steps, compiled-HLO
               roofline, or the analytic TPU model) + draftability
               (candidate drafts for a fixed 8-bit target via
               ``repro.spec``; reward = speculative seconds/token)
- archive.py   persistent Pareto archive over (rel-acc, SQ, latency)
               with dominance pruning, JSON checkpoints and warm-start
- deploy.py    archive winner -> packed weights -> hot-swap into a live
               ServeEngine with an A/B token-parity gate

CLI: ``python -m repro.launch.autotune`` (search, archive, deploy).
"""
from repro.autotune.archive import ArchiveEntry, ParetoArchive, dominates  # noqa: F401
from repro.autotune.deploy import (  # noqa: F401
    ab_parity_check,
    compile_policy,
    deploy,
    hot_swap,
    policy_from_entry,
)
from repro.autotune.service import AutotuneService, ServiceConfig  # noqa: F401
from repro.autotune.workers import (  # noqa: F401
    AccuracyEvaluator,
    AnalyticLatencyEvaluator,
    DraftabilityEvaluator,
    EngineLatencyEvaluator,
    EvalResult,
    EvaluatorPool,
    HLOLatencyEvaluator,
)
