"""Pallas TPU kernels: packed low-bit weight × activation matmul (qmm).

Two paths over the same bitplane storage (DESIGN.md §3):

``dequant`` (prefill / training-shape regime, compute-bound)
    Per (bm, bn, bk) tile: unpack the k bitplanes in VMEM, reconstruct the
    signed codes once, run ONE MXU matmul at bf16.  HBM traffic for weights
    is k/8 bytes/weight; MXU work identical to a dense matmul.

``bitserial`` (decode regime, memory-bound)
    The TPU analogue of Stripes: ``x @ W = (Σ_b 2^b (x @ plane_b) − n·Σ_k x)
    / n · scale``.  Each binary plane hits the MXU separately, so compute
    scales linearly with k — irrelevant at decode batch sizes where the MXU
    is starved anyway — and weight traffic is the same k/8 bytes/weight.
    Keeping the planes as {0,1} bf16 matmuls (instead of reconstructing)
    means the unpack loop never materializes an int tile: each plane is a
    byte-shift + mask, which Mosaic maps onto VPU lanes.

Both paths share the oracle :func:`repro.kernels.ref.qmm_ref`.

Layout notes
------------
- packed: ``(bits, K//8, N) uint8`` — N minor-most (lane axis), so the
  unpack broadcast `(K//8, 8, N)` keeps lanes contiguous and the
  `(K//8, 8, N) -> (K, N)` reshape is a sublane relayout Mosaic supports.
  The 8× sublane expansion is amortized over a (bm × bn) MXU tile.
- The k-grid accumulates into a VMEM f32 scratch; output is written on the
  last k step (revisited-output pattern), with the per-column scale applied
  once at the end.
- Tile defaults: (bm, bn, bk) = (128, 256, 512) → x tile 128·512·2 B=128 KiB,
  packed tile ≤ 8·64·256 B = 128 KiB, acc 128 KiB — comfortably in VMEM
  with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer pallas; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 256, 512


def _unpack_tile(p, bits: int):
    """(bits, bk//8, bn) uint8 -> (bits, bk, bn) int32 in {0,1}."""
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, None, :, None]
    bit = (p[:, :, None, :] >> shifts) & jnp.uint8(1)  # (bits, bk//8, 8, bn)
    b, k8, _, n = bit.shape
    return bit.reshape(b, k8 * 8, n).astype(jnp.int32)


def _qmm_dequant_kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, *, bits, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    planes = _unpack_tile(p_ref[...], bits)  # (bits, bk, bn) {0,1}
    n_lvl = 2 ** (bits - 1) - 1 if bits > 1 else 1
    u = planes[0]
    for b in range(1, bits):  # static unroll: Σ_b plane_b << b
        u = u + (planes[b] << b)
    w = (u - n_lvl).astype(jnp.bfloat16)  # signed codes, one tile
    x = x_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / n_lvl * s_ref[...]).astype(o_ref.dtype)


def _qmm_bitserial_kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, off_ref, *, bits, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        off_ref[...] = jnp.zeros_like(off_ref)

    x = x_ref[...].astype(jnp.bfloat16)
    planes = _unpack_tile(p_ref[...], bits)  # (bits, bk, bn)
    acc = acc_ref[...]
    for b in range(bits):  # static unroll: one binary MXU matmul per plane
        pb = planes[b].astype(jnp.bfloat16)
        acc += float(1 << b) * jnp.dot(x, pb, preferred_element_type=jnp.float32)
    acc_ref[...] = acc
    # rank-1 offset: n_lvl · rowsum(x), accumulated over the K grid
    off_ref[...] += jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)

    @pl.when(k == nk - 1)
    def _finish():
        n_lvl = 2 ** (bits - 1) - 1 if bits > 1 else 1
        y = (acc_ref[...] - n_lvl * off_ref[...]) / n_lvl * s_ref[...]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "path", "block", "interpret", "out_dtype")
)
def qmm_pallas(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    path: str = "dequant",
    block: tuple[int, int, int] = (DEFAULT_BM, DEFAULT_BN, DEFAULT_BK),
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """y[M,N] = x[M,K] @ dequant(packed[bits,K//8,N], scale[1,N]).

    Shapes must be tile-aligned (ops.qmm pads).  ``bits`` static (the packed
    buffer's plane count is structural).
    """
    M, K = x.shape
    bts, K8, N = packed.shape
    if bts != bits or K8 * 8 != K:
        raise ValueError(f"packed {packed.shape} inconsistent with x {x.shape}, bits={bits}")
    bm, bn, bk = (min(block[0], M), min(block[1], N), min(block[2], K))
    if M % bm or N % bn or K % bk or bk % 8:
        raise ValueError(f"shape {(M, K, N)} not divisible by block {(bm, bn, bk)}")
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    kernel = _qmm_dequant_kernel if path == "dequant" else _qmm_bitserial_kernel
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    if path == "bitserial":
        scratch.append(pltpu.VMEM((bm, 1), jnp.float32))
    return pl.pallas_call(
        functools.partial(kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bits, bk // 8, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=f"qmm_{path}_{bits}b",
    )(x, packed, scale)
