"""Pallas TPU kernel: fused bit-serial QKV projection + quantized paged
decode attention — one kernel, zero dequantized HBM round-trips.

The unfused decode step materializes three dequantized activation tensors
(q/k/v) plus a dequantized KV gather in HBM between four kernels.  This
kernel keeps the whole token step on-chip:

  grid (B, nb), scalar-prefetched block table + lengths (same trick as
  ``paged_attention.py`` — the block table IS the BlockSpec index map):

  j == 0      bit-serial q/k/v projections straight off the packed uint8
              bitplanes (``qmm.py`` bitserial math: ``x @ W = (Σ_b 2^b
              (x @ plane_b) − n·Σx) / n · scale``), RoPE from prefetched
              cos/sin rows, then the new token's K/V quantized in-VMEM
              (``quant.pack.kv_quantize`` numerics) and emitted as code +
              scale outputs — the *caller* scatters them into the pool,
              so the kernel has no aliased in-place operands.
  every j     one physical KV block DMA'd in, dequantized in VMEM
              (codes·scale), folded into an online-softmax accumulator.
  j == nb-1   the new token's (dequantized) K/V folded in from scratch —
              numerically identical to write-then-attend — and the
              normalized output written.

Weight planes ride in whole (index map pinned to block 0, so Mosaic DMAs
them once per row, not once per block step); ``ops.fused_qkv_paged_decode``
gates the fused path on the packed planes fitting a VMEM budget and falls
back to the unfused pipeline otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.qmm import _unpack_tile
from repro.quant.pack import kv_pack_int4, kv_quantize, kv_unpack_int4

_NEG = -1e30


def _bitserial_row(x, planes, scale, bits: int):
    """(1, D) f32 @ packed (bits, D//8, N) -> (1, N) f32."""
    n_lvl = 2 ** (bits - 1) - 1 if bits > 1 else 1
    pl_all = _unpack_tile(planes, bits).astype(jnp.float32)  # (bits, D, N)
    acc = jnp.zeros((1, pl_all.shape[-1]), jnp.float32)
    for b in range(bits):  # static unroll: one binary matmul per plane
        acc += float(1 << b) * jnp.dot(x, pl_all[b],
                                       preferred_element_type=jnp.float32)
    off = n_lvl * jnp.sum(x, axis=-1, keepdims=True)
    return (acc - off) / n_lvl * scale


def _rope_row(x, cos, sin):
    """x (KV, G?, hd) f32; cos/sin (hd//2,) for this row's position."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _fused_kernel(bt_ref, len_ref, x_ref, qp_ref, qs_ref, kp_ref, ks_ref,
                  vp_ref, vs_ref, k_ref, v_ref, ksc_ref, vsc_ref, cos_ref,
                  sin_ref, qmax_ref,
                  o_ref, kc_out, vc_out, ksc_out, vsc_out,
                  m_ref, l_ref, acc_ref, q_s, kn_s, vn_s, *,
                  bs: int, H: int, KV: int, hd: int, bits_q: int,
                  bits_k: int, bits_v: int, packed4: bool, act_dtype):
    b, j = pl.program_id(0), pl.program_id(1)
    nb = pl.num_programs(1)
    G = H // KV
    scale = hd ** -0.5

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        x = x_ref[...].astype(jnp.float32)                    # (1, D)
        qmax = qmax_ref[0, 0]
        cos, sin = cos_ref[0], sin_ref[0]                     # (hd//2,)
        # projections off the packed planes; mirror apply_linear's cast to
        # the activation dtype before RoPE (parity with the unfused path)
        q = _bitserial_row(x, qp_ref[...], qs_ref[...], bits_q)
        q = q.astype(act_dtype).astype(jnp.float32).reshape(KV, G, hd)
        k = _bitserial_row(x, kp_ref[...], ks_ref[...], bits_k)
        k = k.astype(act_dtype).astype(jnp.float32).reshape(KV, hd)
        v = _bitserial_row(x, vp_ref[...], vs_ref[...], bits_v)
        v = v.astype(act_dtype).astype(jnp.float32).reshape(KV, hd)
        # apply_rope returns in the activation dtype — mirror the round-trip
        q_s[...] = _rope_row(q, cos, sin).astype(act_dtype).astype(jnp.float32)
        k = _rope_row(k, cos, sin).astype(act_dtype).astype(jnp.float32)
        k_codes, k_sc = kv_quantize(k, qmax)                  # (KV, hd), (KV,)
        v_codes, v_sc = kv_quantize(v, qmax)
        kn_s[...] = k_codes.astype(jnp.float32) * k_sc[:, None]
        vn_s[...] = v_codes.astype(jnp.float32) * v_sc[:, None]
        if packed4:
            k_codes, v_codes = kv_pack_int4(k_codes), kv_pack_int4(v_codes)
        kc_out[0] = k_codes.astype(kc_out.dtype)
        vc_out[0] = v_codes.astype(vc_out.dtype)
        ksc_out[0] = k_sc
        vsc_out[0] = v_sc

    kc, vc = k_ref[0], v_ref[0]                               # (bs, KV, hd[/2])
    if packed4:
        kc, vc = kv_unpack_int4(kc), kv_unpack_int4(vc)
    k_blk = kc.astype(jnp.float32) * ksc_ref[0][..., None]
    v_blk = vc.astype(jnp.float32) * vsc_ref[0][..., None]
    q = q_s[...]
    s = jnp.einsum("kgh,tkh->kgt", q, k_blk,
                   preferred_element_type=jnp.float32) * scale
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    mask = pos < len_ref[b]                                   # pre-write length
    s = jnp.where(mask, s, _NEG)
    m_old, l_old = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_old - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_old * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgt,tkh->kgh", p, v_blk, preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _():
        # fold the new token in from scratch — write-then-attend semantics
        q = q_s[...]
        s_new = jnp.einsum("kgh,kh->kg", q, kn_s[...],
                           preferred_element_type=jnp.float32) * scale
        m_old, l_old = m_ref[...], l_ref[...]
        m_fin = jnp.maximum(m_old, s_new)
        p_new = jnp.exp(s_new - m_fin)
        corr = jnp.exp(m_old - m_fin)
        l_fin = l_old * corr + p_new                           # > 0 always
        acc = acc_ref[...] * corr[..., None] + p_new[..., None] * vn_s[...][:, None, :]
        o_ref[0] = (acc / jnp.maximum(l_fin[..., None], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bits_q", "bits_k", "bits_v", "num_heads", "interpret"))
def fused_qkv_paged_decode_pallas(
    x: jax.Array,             # (B, D) post-norm hidden, one token per row
    wq_planes, wq_scale,      # (bits_q, D//8, H*hd) u8, (1, H*hd) f32
    wk_planes, wk_scale,      # (bits_k, D//8, KV*hd)
    wv_planes, wv_scale,      # (bits_v, D//8, KV*hd)
    k_pool, v_pool,           # (NB, bs, KV, hd) int8 | (NB, bs, KV, hd//2) u8
    k_scale, v_scale,         # (NB, bs, KV) f32
    block_tables, lengths,    # (B, nb) i32, (B,) i32 — PRE-write lengths
    cos, sin,                 # (B, hd//2) f32 RoPE rows at position lengths[b]
    qmax,                     # scalar f32 — this layer's KV code ceiling
    *,
    bits_q: int, bits_k: int, bits_v: int, num_heads: int,
    interpret: bool = False,
):
    """Returns ``(attn (B, KV, G, hd) f32, k_codes (B, KV, hd_s),
    v_codes, k_sc (B, KV) f32, v_sc (B, KV) f32)``."""
    B, D = x.shape
    H = num_heads
    NB, bs, KV, hds = k_pool.shape
    packed4 = k_pool.dtype == jnp.uint8
    hd = hds * 2 if packed4 else hds
    G = H // KV
    nb = block_tables.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, j, bt, ln: (b, 0)),
            pl.BlockSpec((bits_q, D // 8, H * hd),
                         lambda b, j, bt, ln: (0, 0, 0)),
            pl.BlockSpec((1, H * hd), lambda b, j, bt, ln: (0, 0)),
            pl.BlockSpec((bits_k, D // 8, KV * hd),
                         lambda b, j, bt, ln: (0, 0, 0)),
            pl.BlockSpec((1, KV * hd), lambda b, j, bt, ln: (0, 0)),
            pl.BlockSpec((bits_v, D // 8, KV * hd),
                         lambda b, j, bt, ln: (0, 0, 0)),
            pl.BlockSpec((1, KV * hd), lambda b, j, bt, ln: (0, 0)),
            pl.BlockSpec((1, bs, KV, hds),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hds),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV), lambda b, j, bt, ln: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, bs, KV), lambda b, j, bt, ln: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, hd // 2), lambda b, j, bt, ln: (b, 0)),
            pl.BlockSpec((1, hd // 2), lambda b, j, bt, ln: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, j, bt, ln: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, KV, hds), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, KV, hds), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, KV), lambda b, j, bt, ln: (b, 0)),
            pl.BlockSpec((1, KV), lambda b, j, bt, ln: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),       # running max
            pltpu.VMEM((KV, G), jnp.float32),       # running denom
            pltpu.VMEM((KV, G, hd), jnp.float32),   # weighted-V accumulator
            pltpu.VMEM((KV, G, hd), jnp.float32),   # roped q (lives the row)
            pltpu.VMEM((KV, hd), jnp.float32),      # new-token K (dequantized)
            pltpu.VMEM((KV, hd), jnp.float32),      # new-token V (dequantized)
        ],
    )
    kernel = functools.partial(
        _fused_kernel, bs=bs, H=H, KV=KV, hd=hd, bits_q=bits_q,
        bits_k=bits_k, bits_v=bits_v, packed4=packed4, act_dtype=x.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, hds), k_pool.dtype),
            jax.ShapeDtypeStruct((B, KV, hds), v_pool.dtype),
            jax.ShapeDtypeStruct((B, KV), jnp.float32),
            jax.ShapeDtypeStruct((B, KV), jnp.float32),
        ],
        interpret=interpret,
        name=f"fused_qkv_paged_decode_{'int4' if packed4 else 'int8'}",
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      x, wq_planes, wq_scale.astype(jnp.float32),
      wk_planes, wk_scale.astype(jnp.float32),
      wv_planes, wv_scale.astype(jnp.float32),
      k_pool, v_pool, k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), cos.astype(jnp.float32),
      sin.astype(jnp.float32),
      jnp.asarray(qmax, jnp.float32).reshape(1, 1))
