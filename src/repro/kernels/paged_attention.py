"""Pallas TPU kernel: paged decode attention (gather-free block attention).

The paged KV pool stores every sequence's cache as fixed-size blocks
scattered through one big ``(NB, bs, KV, hd)`` pool; a per-sequence block
table maps logical block ``j`` to its physical block id.  The jnp fallback
(``ref.paged_attention_ref``) materializes the gather — ``nb*bs`` tokens
per sequence round-trip HBM twice.  This kernel never materializes it:
the grid is ``(B, nb)`` and the *block table itself is the BlockSpec index
map* (scalar-prefetched, the canonical Pallas paged-attention trick), so
each grid step DMAs exactly one physical block into VMEM and folds it into
an online-softmax accumulator.  HBM traffic is the minimum possible: each
live block is read once.

Numerics match ``models.common.decode_attention`` (fp32 scores/softmax,
finite -1e30 mask) — the paged-vs-slot parity contract.

``paged_attention_quant_pallas`` is the same online-softmax sweep over
*quantized* KV blocks (int8 codes, or nibble-packed uint8 at uniform
int4) with per-(token, KV-head) scales: each DMA'd block is dequantized
in VMEM — ``codes.f32 * scale`` — so HBM traffic shrinks by the code
width (2-4x vs bf16) and the dequantized values never round-trip HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.pack import kv_unpack_int4

_NEG = -1e30


def _paged_attn_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    b, j = pl.program_id(0), pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (KV, G, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bs, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.einsum("kgh,tkh->kgt", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    mask = pos < len_ref[b]                            # (1, 1, bs)
    s = jnp.where(mask, s, _NEG)
    m_old, l_old = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_old - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_old * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgt,tkh->kgh", p, v, preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-20)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jax.Array,             # (B, KV, G, hd)
    k_pool: jax.Array,        # (NB, bs, KV, hd)
    v_pool: jax.Array,        # (NB, bs, KV, hd)
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 — effective (clamped) lengths
    *,
    interpret: bool = False,
) -> jax.Array:
    """One decode step of attention over paged KV, out (B, KV, G, hd) f32."""
    B, KV, G, hd = q.shape
    NB, bs, KVk, hdk = k_pool.shape
    nb = block_tables.shape[1]
    assert (KV, hd) == (KVk, hdk), (q.shape, k_pool.shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,             # block table + lengths
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0)),
            # the block table IS the index map: grid step (b, j) pulls
            # physical block bt[b, j] straight from HBM
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd),
                               lambda b, j, bt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),       # running max
            pltpu.VMEM((KV, G), jnp.float32),       # running denom
            pltpu.VMEM((KV, G, hd), jnp.float32),   # weighted-V accumulator
        ],
    )
    kernel = functools.partial(_paged_attn_kernel, bs=bs, scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
        name="paged_decode_attention",
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, k_pool, v_pool)


def _paged_attn_quant_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref,
                             vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                             bs: int, scale: float, packed4: bool):
    b, j = pl.program_id(0), pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (KV, G, hd)
    kc, vc = k_ref[0], v_ref[0]                       # (bs, KV, hd[/2])
    if packed4:
        kc, vc = kv_unpack_int4(kc), kv_unpack_int4(vc)
    # dequantize in VMEM: codes * per-(token, head) scale
    k = kc.astype(jnp.float32) * ks_ref[0][..., None]
    v = vc.astype(jnp.float32) * vs_ref[0][..., None]
    s = jnp.einsum("kgh,tkh->kgt", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    mask = pos < len_ref[b]
    s = jnp.where(mask, s, _NEG)
    m_old, l_old = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_old - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_old * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgt,tkh->kgh", p, v, preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-20)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_quant_pallas(
    q: jax.Array,             # (B, KV, G, hd)
    k_pool: jax.Array,        # (NB, bs, KV, hd) int8 | (NB, bs, KV, hd//2) u8
    v_pool: jax.Array,        # same container as k_pool
    k_scale: jax.Array,       # (NB, bs, KV) float32
    v_scale: jax.Array,       # (NB, bs, KV) float32
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 — effective (clamped) lengths
    *,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over quantized paged KV, out (B, KV, G, hd) f32.

    Same scalar-prefetched block-table gather as the fp kernel — the block
    table IS the BlockSpec index map — but each grid step DMAs int8/int4
    codes plus a (bs, KV) scale sliver and dequantizes in VMEM.
    """
    B, KV, G, hd = q.shape
    NB, bs, KVk, hds = k_pool.shape
    nb = block_tables.shape[1]
    packed4 = k_pool.dtype == jnp.uint8
    assert KV == KVk and hds == (hd // 2 if packed4 else hd), (
        q.shape, k_pool.shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hds),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hds),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, bs, KV),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd),
                               lambda b, j, bt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_attn_quant_kernel, bs=bs,
                               scale=hd ** -0.5, packed4=packed4)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
        name=f"paged_decode_attention_{'int4' if packed4 else 'int8'}",
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, k_pool, v_pool, k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32))
