"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy (``REPRO_PALLAS`` env var):
- ``auto`` (default): compiled Pallas on TPU, pure-jnp ref off-TPU.
  (Interpret mode executes the kernel body in Python per grid step —
  correct but far slower than the jnp oracle, and inside a jit it unrolls
  the whole grid into the XLA graph.  The serving hot loop runs in
  ``auto``, so off-TPU it must take the fast oracle, never interpret.)
- ``interpret``: force interpret mode (kernel tests use this).
- ``ref``: force the pure-jnp oracle (what the CPU training loops use).
- ``on``: force compiled Pallas (real TPU runs).

The wrappers own all shape normalization: flattening batch dims, padding to
tile multiples, slicing back.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.fused_decode import fused_qkv_paged_decode_pallas
from repro.kernels.paged_attention import (paged_attention_pallas,
                                           paged_attention_quant_pallas)
from repro.kernels.qmm import qmm_pallas
from repro.quant.wrpn import tensor_scale

_INTERPRET_ELEM_CAP = 1 << 22  # don't interpret-execute tiles beyond ~4M elems
# fused decode keeps all three packed projection weights resident in VMEM;
# past this budget fall back to the unfused pipeline (qmm + paged attention)
_FUSED_VMEM_CAP = 8 << 20


def _mode() -> str:
    m = os.environ.get("REPRO_PALLAS", "auto")
    if m not in ("auto", "interpret", "ref", "on"):
        raise ValueError(f"REPRO_PALLAS={m!r}")
    return m


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def fake_quant(w: jax.Array, bits, scale=None) -> jax.Array:
    """WRPN QDQ on an arbitrary-shape tensor; runtime ``bits`` scalar."""
    bits = jnp.asarray(bits, jnp.int32)
    if scale is None:
        scale = tensor_scale(w)
    scale = jnp.asarray(scale, jnp.float32).reshape(())
    mode = _mode()
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return kref.fake_quant_ref(w, bits, scale)
    interpret = mode == "interpret"
    shape = w.shape
    w2 = w.reshape(-1, shape[-1]) if w.ndim != 2 else w
    M, N = w2.shape
    bm, bn = min(256, M), min(256, N)
    w2p = _pad_to(w2, (bm, bn))  # pad up to tile multiples, slice back below
    out = fake_quant_pallas(w2p, bits, scale, block=(bm, bn), interpret=interpret)
    out = out[:M, :N]
    return out.reshape(shape)


def paged_attention(
    q: jax.Array,             # (B, 1, H, hd) — one new token per sequence
    k_pool: jax.Array,        # (NB, bs, KV, hd[/2]) — one layer's paged blocks
    v_pool: jax.Array,        # same container as k_pool
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 effective lengths
    k_scale: jax.Array | None = None,  # (NB, bs, KV) f32 — quantized pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Decode attention over a paged KV pool -> (B, 1, H, hd).

    Pallas path DMAs each live block once (no gather materialization);
    ref path gathers pages then runs the identical decode_attention math.
    Passing ``k_scale``/``v_scale`` selects the quantized-block path
    (int8 codes, or nibble-packed uint8 at uniform int4): blocks are
    dequantized in VMEM / post-gather, never re-materialized in HBM.
    """
    B, _, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    mode = _mode()
    quantized = k_scale is not None
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        if quantized:
            out = kref.quant_paged_attention_ref(
                q, k_pool, v_pool, k_scale, v_scale, block_tables, lengths)
        else:
            out = kref.paged_attention_ref(q, k_pool, v_pool, block_tables,
                                           lengths)
        return out.astype(q.dtype)
    interpret = mode == "interpret"
    if quantized:
        out = paged_attention_quant_pallas(
            q.reshape(B, KV, G, hd), k_pool, v_pool, k_scale, v_scale,
            block_tables, lengths, interpret=interpret)
    else:
        out = paged_attention_pallas(
            q.reshape(B, KV, G, hd), k_pool, v_pool, block_tables, lengths,
            interpret=interpret)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def fused_qkv_paged_decode(
    x: jax.Array,             # (B, D) post-norm hidden, one token per row
    wq, wk, wv,               # quant.pack.Packed projection weights
    k_pool, v_pool,           # quantized paged blocks (pre-write)
    k_scale, v_scale,         # (NB, bs, KV) f32
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 — lengths BEFORE the new token
    qmax,                     # scalar f32 — this layer's KV code ceiling
    *,
    rope_theta: float,
    num_heads: int,
    num_kv_heads: int,
):
    """Fused bit-serial QKV + RoPE + KV-quantize + paged attention.

    Returns ``(attn (B, 1, H, hd) in x.dtype, k_codes, v_codes, k_sc,
    v_sc)`` — codes/scales for the new token, which the caller scatters
    into the pool (write-then-attend ≡ the kernel's attend-with-splice).

    TPU path is ONE kernel (``kernels.fused_decode``) when the packed
    planes fit the VMEM budget; otherwise, and off-TPU, the composed
    oracle (bitwise the unfused qmm + rope + quantize + attend chain).
    """
    B, D = x.shape
    H, KV = num_heads, num_kv_heads
    packed4 = k_pool.dtype == jnp.uint8
    hd = k_pool.shape[-1] * 2 if packed4 else k_pool.shape[-1]
    mode = _mode()
    w_bytes = sum(p.planes.size for p in (wq, wk, wv))
    fits = w_bytes <= _FUSED_VMEM_CAP
    if mode == "ref" or (mode == "auto" and not (_on_tpu() and fits)):
        out, kc, vc, ks, vs = kref.fused_qkv_paged_decode_ref(
            x, wq, wk, wv, k_pool, v_pool, k_scale, v_scale, block_tables,
            lengths, qmax, rope_theta, H, KV)
        return out.astype(x.dtype), kc, vc, ks, vs
    interpret = mode == "interpret"
    # RoPE rows for each sequence's write position (tiny: B × hd/2)
    from repro.models.common import rope_freqs

    inv = rope_freqs(hd, rope_theta)                          # (hd/2,)
    ang = lengths.astype(jnp.float32)[:, None] * inv          # (B, hd/2)
    out, kc, vc, ks, vs = fused_qkv_paged_decode_pallas(
        x, wq.planes, wq.scale, wk.planes, wk.scale, wv.planes, wv.scale,
        k_pool, v_pool, k_scale, v_scale, block_tables, lengths,
        jnp.cos(ang), jnp.sin(ang), qmax,
        bits_q=wq.bits, bits_k=wk.bits, bits_v=wv.bits, num_heads=H,
        interpret=interpret)
    return out.reshape(B, 1, H, hd).astype(x.dtype), kc, vc, ks, vs


def qmm(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    path: str = "auto",
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched y = x @ dequant(packed).  x: (..., K); packed: (bits, K//8, N).

    ``path='auto'`` picks bitserial when the flattened batch M ≤ 32 (decode
    regime: memory-bound, MXU idle) and dequant otherwise (DESIGN.md §3).
    """
    *batch, K = x.shape
    bts, K8, N = packed.shape
    assert bts == bits and K8 * 8 == K, (x.shape, packed.shape, bits)
    M = 1
    for b in batch:
        M *= b
    x2 = x.reshape(M, K)
    if path == "auto":
        path = "bitserial" if M <= 32 else "dequant"
    mode = _mode()
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        out = kref.qmm_ref(x2, packed, scale, bits)
        return out.astype(out_dtype).reshape(*batch, N)
    interpret = mode == "interpret"
    # tile alignment: pick divisors, pad M (cheap) rather than K/N (packed)
    bm = _pick_block(M, 128, pad_ok=True)
    bn = _pick_block(N, 256)
    bk = _pick_block(K, 512, multiple_of=8)
    x2p = _pad_to(x2, (bm, 1))
    out = qmm_pallas(
        x2p, packed, scale.reshape(1, N), bits=bits, path=path,
        block=(bm, bn, bk), interpret=interpret, out_dtype=out_dtype,
    )
    return out[:M].reshape(*batch, N)


def _pick_block(dim: int, target: int, multiple_of: int = 1, pad_ok: bool = False) -> int:
    """Largest divisor of ``dim`` ≤ target that's a multiple of multiple_of;
    if pad_ok, just return min(target, next multiple) and let caller pad."""
    if pad_ok:
        return min(target, dim) if dim >= target else dim
    b = min(target, dim)
    while b > 1 and (dim % b or b % multiple_of):
        b -= 1
    return max(b, 1)
