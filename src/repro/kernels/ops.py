"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy (``REPRO_PALLAS`` env var):
- ``auto`` (default): compiled Pallas on TPU, interpret-mode Pallas on CPU
  for any array small enough to test, pure-jnp ref otherwise.  Interpret
  mode executes the kernel body in Python per grid step — correct but slow —
  so the auto path caps interpreted problem sizes.
- ``interpret``: force interpret mode (kernel tests use this).
- ``ref``: force the pure-jnp oracle (what the CPU training loops use).
- ``on``: force compiled Pallas (real TPU runs).

The wrappers own all shape normalization: flattening batch dims, padding to
tile multiples, slicing back.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.qmm import qmm_pallas
from repro.quant.wrpn import tensor_scale

_INTERPRET_ELEM_CAP = 1 << 22  # don't interpret-execute tiles beyond ~4M elems


def _mode() -> str:
    m = os.environ.get("REPRO_PALLAS", "auto")
    if m not in ("auto", "interpret", "ref", "on"):
        raise ValueError(f"REPRO_PALLAS={m!r}")
    return m


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def fake_quant(w: jax.Array, bits, scale=None) -> jax.Array:
    """WRPN QDQ on an arbitrary-shape tensor; runtime ``bits`` scalar."""
    bits = jnp.asarray(bits, jnp.int32)
    if scale is None:
        scale = tensor_scale(w)
    scale = jnp.asarray(scale, jnp.float32).reshape(())
    mode = _mode()
    if mode == "ref" or (mode == "auto" and not _on_tpu() and w.size > _INTERPRET_ELEM_CAP):
        return kref.fake_quant_ref(w, bits, scale)
    interpret = mode == "interpret" or (mode == "auto" and not _on_tpu())
    shape = w.shape
    w2 = w.reshape(-1, shape[-1]) if w.ndim != 2 else w
    M, N = w2.shape
    bm, bn = min(256, M), min(256, N)
    w2p = _pad_to(w2, (bm, bn))  # pad up to tile multiples, slice back below
    out = fake_quant_pallas(w2p, bits, scale, block=(bm, bn), interpret=interpret)
    out = out[:M, :N]
    return out.reshape(shape)


def paged_attention(
    q: jax.Array,             # (B, 1, H, hd) — one new token per sequence
    k_pool: jax.Array,        # (NB, bs, KV, hd) — one layer's paged blocks
    v_pool: jax.Array,        # (NB, bs, KV, hd)
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 effective lengths
) -> jax.Array:
    """Decode attention over a paged KV pool -> (B, 1, H, hd).

    Pallas path DMAs each live block once (no gather materialization);
    ref path gathers pages then runs the identical decode_attention math.
    """
    B, _, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    mode = _mode()
    work = B * block_tables.shape[1] * k_pool.shape[1] * H * hd
    if mode == "ref" or (mode == "auto" and not _on_tpu()
                         and work > _INTERPRET_ELEM_CAP):
        out = kref.paged_attention_ref(q, k_pool, v_pool, block_tables,
                                       lengths)
        return out.astype(q.dtype)
    interpret = mode == "interpret" or (mode == "auto" and not _on_tpu())
    out = paged_attention_pallas(
        q.reshape(B, KV, G, hd), k_pool, v_pool, block_tables, lengths,
        interpret=interpret)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def qmm(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    bits: int,
    path: str = "auto",
    out_dtype=jnp.float32,
) -> jax.Array:
    """Batched y = x @ dequant(packed).  x: (..., K); packed: (bits, K//8, N).

    ``path='auto'`` picks bitserial when the flattened batch M ≤ 32 (decode
    regime: memory-bound, MXU idle) and dequant otherwise (DESIGN.md §3).
    """
    *batch, K = x.shape
    bts, K8, N = packed.shape
    assert bts == bits and K8 * 8 == K, (x.shape, packed.shape, bits)
    M = 1
    for b in batch:
        M *= b
    x2 = x.reshape(M, K)
    if path == "auto":
        path = "bitserial" if M <= 32 else "dequant"
    mode = _mode()
    work = M * K * N
    if mode == "ref" or (mode == "auto" and not _on_tpu() and work > _INTERPRET_ELEM_CAP):
        out = kref.qmm_ref(x2, packed, scale, bits)
        return out.astype(out_dtype).reshape(*batch, N)
    interpret = mode == "interpret" or (mode == "auto" and not _on_tpu())
    # tile alignment: pick divisors, pad M (cheap) rather than K/N (packed)
    bm = _pick_block(M, 128, pad_ok=True)
    bn = _pick_block(N, 256)
    bk = _pick_block(K, 512, multiple_of=8)
    x2p = _pad_to(x2, (bm, 1))
    out = qmm_pallas(
        x2p, packed, scale.reshape(1, N), bits=bits, path=path,
        block=(bm, bn, bk), interpret=interpret, out_dtype=out_dtype,
    )
    return out[:M].reshape(*batch, N)


def _pick_block(dim: int, target: int, multiple_of: int = 1, pad_ok: bool = False) -> int:
    """Largest divisor of ``dim`` ≤ target that's a multiple of multiple_of;
    if pad_ok, just return min(target, next multiple) and let caller pad."""
    if pad_ok:
        return min(target, dim) if dim >= target else dim
    b = min(target, dim)
    while b > 1 and (dim % b or b % multiple_of):
        b -= 1
    return max(b, 1)
