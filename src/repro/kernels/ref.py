"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are also the CPU fallback path used when Pallas interpret mode is
disabled (`REPRO_PALLAS=off`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.pack import unpack_bitplanes
from repro.quant.wrpn import fake_quant as _fake_quant_jnp


def fake_quant_ref(w: jax.Array, bits, scale: jax.Array) -> jax.Array:
    """WRPN mid-tread QDQ with externally supplied per-tensor scale."""
    return _fake_quant_jnp(w, bits, scale=scale)


def dequant_ref(packed: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Packed bitplanes (bits, K//8, N) + scale (1, N) -> float32 (K, N)."""
    n = float(2 ** (bits - 1) - 1) if bits > 1 else 1.0
    codes = unpack_bitplanes(packed, bits)
    return codes.astype(jnp.float32) / n * scale


def paged_attention_ref(
    q: jax.Array,             # (B, 1, H, hd) — single new token per sequence
    k_pool: jax.Array,        # (NB, bs, KV, hd) — one layer's paged KV blocks
    v_pool: jax.Array,        # (NB, bs, KV, hd)
    block_tables: jax.Array,  # (B, nb) int32 physical block ids
    lengths: jax.Array,       # (B,) valid tokens per sequence
) -> jax.Array:
    """Gather each sequence's pages into a contiguous (B, nb*bs, KV, hd)
    view, then run the exact :func:`models.common.decode_attention` math —
    bitwise what the slot pool computes on its contiguous rows, which is
    what pins paged-vs-slot token parity."""
    from repro.models.common import decode_attention

    B, nb = block_tables.shape
    bs = k_pool.shape[1]
    kg = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    vg = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    return decode_attention(q, kg, vg, lengths)


def qmm_ref(
    x: jax.Array, packed: jax.Array, scale: jax.Array, bits: int
) -> jax.Array:
    """y = x @ dequant(packed).  x: (M, K) float; out: (M, N) float32.

    Oracle for BOTH qmm paths (dequant and bitserial compute the same
    function; they differ only in where the Σ_b 2^b reduction happens).
    """
    w = dequant_ref(packed, scale, bits)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
