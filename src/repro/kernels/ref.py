"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are also the CPU fallback path used when Pallas interpret mode is
disabled (`REPRO_PALLAS=off`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.pack import (kv_dequantize, kv_quantize, kv_unpack_int4,
                              unpack_bitplanes)
from repro.quant.wrpn import fake_quant as _fake_quant_jnp


def fake_quant_ref(w: jax.Array, bits, scale: jax.Array) -> jax.Array:
    """WRPN mid-tread QDQ with externally supplied per-tensor scale."""
    return _fake_quant_jnp(w, bits, scale=scale)


def dequant_ref(packed: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Packed bitplanes (bits, K//8, N) + scale (1, N) -> float32 (K, N)."""
    n = float(2 ** (bits - 1) - 1) if bits > 1 else 1.0
    codes = unpack_bitplanes(packed, bits)
    return codes.astype(jnp.float32) / n * scale


def paged_attention_ref(
    q: jax.Array,             # (B, 1, H, hd) — single new token per sequence
    k_pool: jax.Array,        # (NB, bs, KV, hd) — one layer's paged KV blocks
    v_pool: jax.Array,        # (NB, bs, KV, hd)
    block_tables: jax.Array,  # (B, nb) int32 physical block ids
    lengths: jax.Array,       # (B,) valid tokens per sequence
) -> jax.Array:
    """Gather each sequence's pages into a contiguous (B, nb*bs, KV, hd)
    view, then run the exact :func:`models.common.decode_attention` math —
    bitwise what the slot pool computes on its contiguous rows, which is
    what pins paged-vs-slot token parity."""
    from repro.models.common import decode_attention

    B, nb = block_tables.shape
    bs = k_pool.shape[1]
    kg = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    vg = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    return decode_attention(q, kg, vg, lengths)


def quant_paged_attention_ref(
    q: jax.Array,             # (B, 1, H, hd)
    k_pool: jax.Array,        # (NB, bs, KV, hd) int8 | (NB, bs, KV, hd//2) u8
    v_pool: jax.Array,        # same container as k_pool
    k_scale: jax.Array,       # (NB, bs, KV) float32 per-(token, head) scales
    v_scale: jax.Array,       # (NB, bs, KV) float32
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32
) -> jax.Array:
    """Decode attention over *quantized* KV blocks: gather codes + scales,
    dequantize (``codes * scale`` in f32 — exactly the write-side product
    the fp-KV oracle stores), then the shared decode_attention math.  This
    is the parity contract: a quantized pool and an oracle pool holding
    the QDQ values must produce bitwise-identical logits."""
    from repro.models.common import decode_attention

    B, nb = block_tables.shape
    bs = k_pool.shape[1]
    kc = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    vc = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    if k_pool.dtype == jnp.uint8:  # nibble-packed uniform int4
        kc, vc = kv_unpack_int4(kc), kv_unpack_int4(vc)
    ks = k_scale[block_tables].reshape(B, nb * bs, k_scale.shape[2])
    vs = v_scale[block_tables].reshape(B, nb * bs, v_scale.shape[2])
    return decode_attention(q, kv_dequantize(kc, ks), kv_dequantize(vc, vs),
                            lengths)


def fused_qkv_paged_decode_ref(
    x: jax.Array,             # (B, D) post-norm hidden, one token per row
    wq, wk, wv,               # quant.pack.Packed projection weights
    k_pool, v_pool,           # quantized blocks (pre-write, see below)
    k_scale, v_scale,         # (NB, bs, KV) float32
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32 — length BEFORE the new token
    qmax: jax.Array,          # scalar f32 code ceiling for this layer's KV
    rope_theta: float,
    num_heads: int,
    num_kv_heads: int,
):
    """Composed oracle for the fused decode kernel.

    Computes the q/k/v projections with :func:`qmm_ref`, applies RoPE at
    position ``lengths``, quantizes the new token's K/V, and attends over
    the *pre-write* pool with the new token spliced into the gathered view
    (write-then-attend ≡ attend-with-splice).  Returns
    ``(attn (B, 1, H, hd) f32, k_codes, v_codes, k_sc, v_sc)`` — the codes
    and scales are handed back so the caller scatters them into the pool,
    keeping the kernel free of aliased in-place outputs.
    """
    from repro.models.common import apply_rope, decode_attention

    B, D = x.shape
    H, KV = num_heads, num_kv_heads
    hd = wq.scale.shape[-1] // H
    # mirror apply_linear's astype(x.dtype) round-trips exactly — the
    # bitwise contract with the *unfused* oracle-engine decode path
    dt = x.dtype
    q = qmm_ref(x, wq.planes, wq.scale, wq.bits).astype(dt).reshape(B, 1, H, hd)
    k = qmm_ref(x, wk.planes, wk.scale, wk.bits).astype(dt).reshape(B, 1, KV, hd)
    v = qmm_ref(x, wv.planes, wv.scale, wv.bits).astype(dt).reshape(B, 1, KV, hd)
    pos = lengths.astype(jnp.int32)[:, None]                  # (B, 1)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)
    k_codes, k_sc = kv_quantize(k[:, 0], qmax)                # (B, KV, hd)
    v_codes, v_sc = kv_quantize(v[:, 0], qmax)

    nb = block_tables.shape[1]
    bs = k_pool.shape[1]
    Tc = nb * bs
    kc = k_pool[block_tables].reshape(B, Tc, *k_pool.shape[2:])
    vc = v_pool[block_tables].reshape(B, Tc, *v_pool.shape[2:])
    if k_pool.dtype == jnp.uint8:
        kc, vc = kv_unpack_int4(kc), kv_unpack_int4(vc)
    ks = k_scale[block_tables].reshape(B, Tc, KV)
    vs = v_scale[block_tables].reshape(B, Tc, KV)
    kg = kv_dequantize(kc, ks)
    vg = kv_dequantize(vc, vs)
    # splice the new token's QDQ value at its slot (linear addressing; the
    # caller clamps `lengths` so slot < Tc)
    slot = jnp.minimum(lengths, Tc - 1)
    rows = jnp.arange(B)
    kg = kg.at[rows, slot].set(kv_dequantize(k_codes, k_sc))
    vg = vg.at[rows, slot].set(kv_dequantize(v_codes, v_sc))
    eff_len = jnp.minimum(lengths + 1, Tc)
    out = decode_attention(q, kg, vg, eff_len)
    if k_pool.dtype == jnp.uint8:
        from repro.quant.pack import kv_pack_int4

        k_codes, v_codes = kv_pack_int4(k_codes), kv_pack_int4(v_codes)
    return out, k_codes, v_codes, k_sc, v_sc


def qmm_ref(
    x: jax.Array, packed: jax.Array, scale: jax.Array, bits: int
) -> jax.Array:
    """y = x @ dequant(packed).  x: (M, K) float; out: (M, N) float32.

    Oracle for BOTH qmm paths (dequant and bitserial compute the same
    function; they differ only in where the Σ_b 2^b reduction happens).
    """
    w = dequant_ref(packed, scale, bits)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
