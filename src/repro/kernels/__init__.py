"""Pallas TPU kernels for the quantization hot paths.

- fake_quant: fused WRPN quantize-dequantize (QAT inner loop).
- qmm: packed low-bit weight matmul — ``dequant`` path (one MXU matmul)
  and ``bitserial`` path (one binary matmul per plane; the TPU analogue of
  the paper's Stripes bit-serial execution, see DESIGN.md §3).

``ops`` holds the public wrappers (padding, dispatch, CPU fallbacks);
``ref`` holds the pure-jnp oracles every kernel is tested against.
"""
from repro.kernels import ops, ref  # noqa: F401
