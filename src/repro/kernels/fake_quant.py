"""Pallas TPU kernel: WRPN mid-tread fake-quant (quantize-dequantize).

This is the QAT hot path: every train step applies QDQ to every quantizable
weight tile (DESIGN.md §3).  As a fused elementwise kernel it is trivially
memory-bound; the point of the Pallas version is (a) to fuse clip/round/
rescale into one VMEM pass instead of XLA's multi-op HLO chain, and (b) to
take ``bits`` as *data* (SMEM scalar) so one executable serves every
bitwidth policy — including a vectorized batch of ReLeQ environments.

Grid: 2-D over (M/bm, N/bn) row-major tiles.  Tiles are (128, 128)-aligned
by the ops.py wrapper (pad + slice) so VREG lanes stay full.

Sharding contract: the kernel takes a per-tensor SMEM scale, so the SPMD
question never reaches it.  The jnp path's per-output-COLUMN scale is the
one that broadcasts against the weight — under fsdp that broadcast used to
trigger involuntary full rematerializations of the stacked tensor.  The
fix lives where the broadcast lowers: ``quant/qat._qdq`` computes the
stacked scale explicitly and pins scale + QDQ output to the leaf's
``dist/sharding.py`` rule-table spec whenever an ambient mesh is set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 256)  # 256·256·4 B = 256 KiB/tile in VMEM — far under 16 MiB


def _fake_quant_kernel(bits_ref, scale_ref, w_ref, o_ref):
    bits = bits_ref[0]
    scale = scale_ref[0]
    n = jnp.maximum(jnp.exp2(bits.astype(jnp.float32) - 1.0) - 1.0, 1.0)
    w = w_ref[...].astype(jnp.float32)
    wc = jnp.clip(w / scale, -1.0, 1.0)
    wq = jnp.round(wc * n) / n * scale
    out = jnp.where(bits >= 32, w, wq)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fake_quant_pallas(
    w: jax.Array,
    bits: jax.Array,
    scale: jax.Array,
    *,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """QDQ ``w`` (2-D, tile-aligned) at runtime-``bits`` with per-tensor scale.

    ``bits``: int32 scalar array.  ``scale``: float32 scalar array (max|w|).
    Shape alignment/padding is the caller's job (see ops.fake_quant).
    """
    M, N = w.shape
    bm, bn = min(block[0], M), min(block[1], N)
    if M % bm or N % bn:
        raise ValueError(f"shape {(M, N)} not divisible by block {(bm, bn)}")
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # bits (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scale (1,)
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), w.dtype),
        interpret=interpret,
        name="wrpn_fake_quant",
    )(
        jnp.asarray(bits, jnp.int32).reshape(1),
        jnp.asarray(scale, jnp.float32).reshape(1),
        w,
    )
