"""Synthetic-but-learnable image classification datasets (offline stand-ins).

Generator: class anchors in a latent space, pushed through a fixed random
two-layer nonlinear decoder into image space, plus per-sample latent jitter
and pixel noise.  Deterministic in (dataset name, split, index).  Networks
fit these to 90%+ accuracy in a few hundred CPU steps, and — validated in
tests — accuracy degrades monotonically as weights are quantized below
4 bits and recovers with fine-tuning: the signal ReLeQ consumes.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

_SPECS = {
    # name: (hw, channels, classes, latent_dim, jitter)
    "mnist-like": (28, 1, 10, 16, 0.55),
    "cifar-like": (32, 3, 10, 24, 0.6),
    "svhn-like": (32, 3, 10, 24, 0.6),
    "imagenet-like": (32, 3, 20, 32, 0.5),
}


@dataclass
class SyntheticImages:
    name: str
    seed: int = 0

    def __post_init__(self):
        hw, c, k, latent, jitter = _SPECS[self.name]
        self.hw, self.channels, self.classes = hw, c, k
        self.latent, self.jitter = latent, jitter
        # zlib.crc32, NOT hash(): str hashing is randomized per process
        # (PYTHONHASHSEED), which made the dataset — and every accuracy
        # threshold downstream — nondeterministic across runs
        rng = np.random.default_rng(
            (zlib.crc32(self.name.encode()) * 31 + self.seed) % (2 ** 31))
        self.anchors = rng.normal(size=(k, latent)).astype(np.float32) * 1.6
        hidden = 64
        self.w1 = rng.normal(size=(latent, hidden)).astype(np.float32) / latent ** 0.5
        self.w2 = rng.normal(size=(hidden, hw * hw * c)).astype(np.float32) / hidden ** 0.5

    def batch(self, batch: int, index: int, split: str = "train"):
        salt = {"train": 0, "val": 7_000_003, "test": 13_000_017}[split]
        rng = np.random.default_rng((self.seed * 97 + salt + index) % (2 ** 63))
        y = rng.integers(0, self.classes, size=batch)
        z = self.anchors[y] + self.jitter * rng.normal(size=(batch, self.latent))
        h = np.tanh(z @ self.w1)
        x = (h @ self.w2).reshape(batch, self.hw, self.hw, self.channels)
        x += 0.25 * rng.normal(size=x.shape)
        return x.astype(np.float32), y.astype(np.int32)


def make_dataset(name: str, seed: int = 0) -> SyntheticImages:
    return SyntheticImages(name, seed)


# paper's network -> dataset mapping (Table 2)
DATASET_FOR = {
    "lenet": "mnist-like",
    "simplenet": "cifar-like",
    "svhn10": "svhn-like",
    "vgg11": "cifar-like",
    "resnet20": "cifar-like",
    "alexnet": "imagenet-like",
    "mobilenet": "imagenet-like",
}
