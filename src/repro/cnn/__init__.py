"""Paper-faithful substrate: the CNN benchmark family of Table 2, in JAX.

ImageNet/CIFAR/MNIST/SVHN are not available in this offline container, so
each network trains on a deterministic synthetic-but-learnable classifier
dataset with the original input geometry (DESIGN.md §3): networks reach
high accuracy in seconds on CPU, accuracy degrades monotonically with
weight bitwidth, and short fine-tuning recovers it — the exact signal the
ReLeQ environment consumes.  AlexNet / MobileNet / VGG-11 keep their layer
*structure* with reduced channel widths (CPU budget); LeNet / SimpleNet /
SVHN-10 / ResNet-20 are full-structure.

Quantization here is per-tensor WRPN (the paper's §4.2 recipe, scale =
max|w|), unlike the LM path's per-column scales — fidelity first.
"""
from repro.cnn.models import CNN_ZOO, build_cnn  # noqa: F401
from repro.cnn.data import make_dataset  # noqa: F401
from repro.cnn.train import CNNTask  # noqa: F401
