"""The paper's CNN benchmark family as explicit-params JAX functions.

Every model exposes the same protocol as the LMs where it matters to
ReLeQ: ``init``, ``apply(params, x) -> logits``, ``quant_groups()``.
Layer list = quantizable weight groups in forward order, matching the
paper's episode walk.  MACs are computed per-sample from the actual
conv/fc geometry — the inputs to the State-of-Quantization metric.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.model import QuantGroup


@dataclass(frozen=True)
class ConvSpec:
    name: str
    kind: str          # conv | dwconv | fc
    c_in: int
    c_out: int
    k: int = 3
    stride: int = 1
    residual_from: str | None = None   # resnet shortcuts


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


class CNNModel:
    """Sequential(+residual) CNN from a list of ConvSpecs."""

    def __init__(self, name: str, specs: list[ConvSpec], input_hw: int,
                 c_in: int, num_classes: int, frozen_first_last: bool = True):
        self.name = name
        self.specs = specs
        self.input_hw = input_hw
        self.c_in = c_in
        self.num_classes = num_classes
        self.frozen_first_last = frozen_first_last
        self._plan_shapes()

    def _plan_shapes(self):
        hw = self.input_hw
        self._hw_at = {}
        for s in self.specs:
            if s.kind == "fc":
                hw = 1
            self._hw_at[s.name] = hw
            if s.kind in ("conv", "dwconv") and s.stride > 1:
                hw = -(-hw // s.stride)
        self._hw_out = hw

    def init(self, rng):
        params = {}
        hw = self.input_hw
        flat_in = None
        for s in self.specs:
            # crc32, NOT hash(): str hashing is randomized per process,
            # which made init — and accuracy thresholds — nondeterministic
            key = jax.random.fold_in(rng, zlib.crc32(s.name.encode()) % (2 ** 31))
            if s.kind == "conv":
                w = jax.random.normal(key, (s.k, s.k, s.c_in, s.c_out), jnp.float32)
                w *= (2.0 / (s.k * s.k * s.c_in)) ** 0.5
            elif s.kind == "dwconv":
                w = jax.random.normal(key, (s.k, s.k, 1, s.c_in), jnp.float32)
                w *= (2.0 / (s.k * s.k)) ** 0.5
            else:  # fc
                n_in = s.c_in if flat_in is None else flat_in
                w = jax.random.normal(key, (n_in, s.c_out), jnp.float32)
                w *= (2.0 / n_in) ** 0.5
            params[s.name] = {"w": w, "b": jnp.zeros((w.shape[-1] if s.kind != "dwconv" else s.c_in,), jnp.float32)}
            if s.kind in ("conv", "dwconv") and s.stride > 1:
                hw = -(-hw // s.stride)
            if s.kind == "fc":
                flat_in = s.c_out
        return params

    def apply(self, params, x):
        """x: (B, H, W, C) -> logits (B, classes)."""
        taps = {}
        flat = False
        for i, s in enumerate(self.specs):
            p = params[s.name]
            if s.kind == "fc":
                if not flat:
                    x = jnp.mean(x, axis=(1, 2))  # global average pool
                    flat = True
                x = x @ p["w"] + p["b"]
            elif s.kind == "dwconv":
                x = _conv(x, p["w"], s.stride, groups=s.c_in) + p["b"]
            else:
                x = _conv(x, p["w"], s.stride) + p["b"]
            if s.residual_from is not None and s.residual_from in taps:
                r = taps[s.residual_from]
                if r.shape == x.shape:
                    x = x + r
            taps[s.name] = x
            if i < len(self.specs) - 1:
                x = jax.nn.relu(x)
        return x

    # ---- quantization interface ----------------------------------------
    def quant_groups(self, seq_len: int = 0) -> list[QuantGroup]:
        out = []
        for s in self.specs:
            hw = self._hw_at[s.name]
            if s.kind == "conv":
                nw = s.k * s.k * s.c_in * s.c_out
                macs = nw * (hw // s.stride) * (hw // s.stride)
            elif s.kind == "dwconv":
                nw = s.k * s.k * s.c_in
                macs = nw * (hw // s.stride) * (hw // s.stride)
            else:
                nw = None  # resolved from params at env build (flatten dim)
                nw = s.c_in * s.c_out
                macs = nw
            out.append(QuantGroup(s.name, (s.name, "w"), None,
                                  (0,), nw, macs))
        return out

    def frozen_bits(self) -> dict[str, int]:
        """Paper keeps boundary layers high-precision (Table 2: first/last 8)."""
        if not self.frozen_first_last:
            return {}
        return {self.specs[0].name: 8, self.specs[-1].name: 8}


def lenet() -> CNNModel:
    # paper LeNet on MNIST: conv1, conv2, fc1, fc2 (Table 2: {2,2,3,2})
    specs = [
        ConvSpec("conv1", "conv", 1, 6, k=5, stride=2),
        ConvSpec("conv2", "conv", 6, 16, k=5, stride=2),
        ConvSpec("fc1", "fc", 16, 120),
        ConvSpec("fc2", "fc", 120, 10),
    ]
    return CNNModel("lenet", specs, 28, 1, 10, frozen_first_last=False)


def simplenet5() -> CNNModel:
    # paper "CIFAR-10 (SimpleNet, 5 layers)": {5,5,5,5,5}
    specs = [
        ConvSpec("conv1", "conv", 3, 32, stride=1),
        ConvSpec("conv2", "conv", 32, 32, stride=2),
        ConvSpec("conv3", "conv", 32, 64, stride=2),
        ConvSpec("conv4", "conv", 64, 64, stride=2),
        ConvSpec("fc", "fc", 64, 10),
    ]
    return CNNModel("simplenet", specs, 32, 3, 10, frozen_first_last=False)


def svhn10() -> CNNModel:
    # paper "SVHN-10 (10 layers)": {8,4,4,4,4,4,4,4,4,8}
    chans = [32, 32, 48, 48, 64, 64, 80, 80]
    specs, c = [], 3
    for i, co in enumerate(chans):
        specs.append(ConvSpec(f"conv{i+1}", "conv", c, co,
                              stride=2 if i % 2 == 1 else 1))
        c = co
    specs += [ConvSpec("fc1", "fc", c, 128), ConvSpec("fc2", "fc", 128, 10)]
    return CNNModel("svhn10", specs, 32, 3, 10)


def vgg11() -> CNNModel:
    # VGG-11 structure (8 conv + 3 fc), channels /4 for CPU budget
    cfg = [(16, 1), (32, 2), (64, 1), (64, 2), (128, 1), (128, 2), (128, 1), (128, 2)]
    specs, c = [], 3
    for i, (co, st) in enumerate(cfg):
        specs.append(ConvSpec(f"conv{i+1}", "conv", c, co, stride=st))
        c = co
    specs += [ConvSpec("fc1", "fc", c, 128), ConvSpec("fc2", "fc", 128, 128),
              ConvSpec("fc3", "fc", 128, 10)]
    return CNNModel("vgg11", specs, 32, 3, 10)


def resnet20() -> CNNModel:
    # full ResNet-20 structure: stem + 3 stages × 3 blocks × 2 convs + fc
    specs = [ConvSpec("stem", "conv", 3, 16)]
    c = 16
    idx = 0
    for stage, co in enumerate([16, 32, 64]):
        for blk in range(3):
            st = 2 if (stage > 0 and blk == 0) else 1
            a = f"s{stage}b{blk}a"
            b = f"s{stage}b{blk}b"
            prev = specs[-1].name
            specs.append(ConvSpec(a, "conv", c, co, stride=st))
            specs.append(ConvSpec(b, "conv", co, co, residual_from=prev))
            c = co
            idx += 1
    specs.append(ConvSpec("fc", "fc", c, 10))
    return CNNModel("resnet20", specs, 32, 3, 10)


def alexnet() -> CNNModel:
    # AlexNet structure (5 conv + 3 fc), width /8, 32×32 synthetic-imagenet
    specs = [
        ConvSpec("conv1", "conv", 3, 12, k=5, stride=2),
        ConvSpec("conv2", "conv", 12, 32, k=5, stride=2),
        ConvSpec("conv3", "conv", 32, 48),
        ConvSpec("conv4", "conv", 48, 48),
        ConvSpec("conv5", "conv", 48, 32, stride=2),
        ConvSpec("fc1", "fc", 32, 256),
        ConvSpec("fc2", "fc", 256, 256),
        ConvSpec("fc3", "fc", 256, 20),
    ]
    return CNNModel("alexnet", specs, 32, 3, 20)


def mobilenet_v1() -> CNNModel:
    # MobileNet-V1 structure: stem + 13 (dw, pw) pairs + fc, width /8.
    # ReLeQ's Table 2 lists 30 quantizable layers; ours: 1+26+1 = 28 + fc.
    plan = [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1), (128, 2), (128, 1),
            (128, 1), (128, 1), (128, 1), (128, 1), (256, 2), (256, 1)]
    specs = [ConvSpec("stem", "conv", 3, 8, stride=2)]
    c = 8
    for i, (co, st) in enumerate(plan):
        specs.append(ConvSpec(f"dw{i+1}", "dwconv", c, c, stride=st))
        specs.append(ConvSpec(f"pw{i+1}", "conv", c, co, k=1))
        c = co
    specs.append(ConvSpec("fc", "fc", c, 20))
    return CNNModel("mobilenet", specs, 32, 3, 20)


CNN_ZOO = {
    "lenet": lenet,
    "simplenet": simplenet5,
    "svhn10": svhn10,
    "vgg11": vgg11,
    "resnet20": resnet20,
    "alexnet": alexnet,
    "mobilenet": mobilenet_v1,
}


def build_cnn(name: str) -> CNNModel:
    return CNN_ZOO[name]()
