"""CNN training/eval with WRPN QAT + the ReLeQ environment glue.

``CNNTask`` owns one (network, dataset) pair:
- ``pretrain``: full-precision training to convergence (the paper starts
  the agent from a pre-trained model),
- ``evaluate_bits``: the environment's accuracy oracle — short QAT retrain
  at a candidate bitwidth assignment (paper's "shortened amount of
  epochs"), then validation accuracy relative to the fp baseline,
- ``long_retrain``: the paper's final step after the agent converges.

Quantization is per-tensor WRPN with STE (paper §4.2), bits as jit data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cnn.data import DATASET_FOR, make_dataset
from repro.cnn.models import build_cnn
from repro.core.env import QuantEnv
from repro.quant.wrpn import fake_quant_ste


def _quantize_cnn_params(params, bits_by_name: dict):
    new = {}
    for name, p in params.items():
        if name in bits_by_name:
            new[name] = {"w": fake_quant_ste(p["w"], bits_by_name[name]),
                         "b": p["b"]}
        else:
            new[name] = p
    return new


class CNNTask:
    def __init__(self, net_name: str, seed: int = 0, batch: int = 128,
                 lr: float = 2e-3):
        self.model = build_cnn(net_name)
        self.data = make_dataset(DATASET_FOR[net_name], seed)
        self.batch = batch
        self.seed = seed
        self.groups = self.model.quant_groups()
        self.frozen = self.model.frozen_bits()
        self.names = [g.name for g in self.groups]
        self._index = 0

        opt_lr = lr

        def loss_fn(params, x, y, bits_vec):
            bits = {n: bits_vec[i] for i, n in enumerate(self.names)}
            qp = _quantize_cnn_params(params, bits)
            logits = self.model.apply(qp, x)
            logp = jax.nn.log_softmax(logits)
            nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))
            return nll

        @jax.jit
        def train_step(params, mom, x, y, bits_vec):
            g = jax.grad(loss_fn)(params, x, y, bits_vec)
            mom = jax.tree.map(lambda m, gg: 0.9 * m + gg, mom, g)
            params = jax.tree.map(lambda p, m: p - opt_lr * m, params, mom)
            return params, mom

        @jax.jit
        def acc_fn(params, x, y, bits_vec):
            bits = {n: bits_vec[i] for i, n in enumerate(self.names)}
            qp = _quantize_cnn_params(params, bits)
            logits = self.model.apply(qp, x)
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

        self._train_step = train_step
        self._acc_fn = acc_fn
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.mom = jax.tree.map(jnp.zeros_like, self.params)
        self._fp_vec = jnp.full((len(self.names),), 32, jnp.int32)
        # fixed validation set
        self._val = [self.data.batch(256, i, "val") for i in range(2)]
        self.fp_acc = None

    def _bits_vec(self, bits_by_name: dict | None):
        if bits_by_name is None:
            return self._fp_vec
        return jnp.asarray([bits_by_name.get(n, 32) for n in self.names], jnp.int32)

    # ------------------------------------------------------------------
    def train(self, steps: int, bits_by_name: dict | None = None,
              params=None, mom=None):
        params = self.params if params is None else params
        mom = self.mom if mom is None else mom
        vec = self._bits_vec(bits_by_name)
        for _ in range(steps):
            x, y = self.data.batch(self.batch, self._index, "train")
            self._index += 1
            params, mom = self._train_step(params, mom, jnp.asarray(x),
                                           jnp.asarray(y), vec)
        return params, mom

    def accuracy(self, params, bits_by_name: dict | None = None) -> float:
        vec = self._bits_vec(bits_by_name)
        accs = [float(self._acc_fn(params, jnp.asarray(x), jnp.asarray(y), vec))
                for x, y in self._val]
        return float(np.mean(accs))

    def pretrain(self, steps: int = 400) -> float:
        self.params, self.mom = self.train(steps)
        self.fp_acc = self.accuracy(self.params)
        return self.fp_acc

    # ------------------------------------------------------------------
    def evaluate_bits(self, bits_by_name: dict, retrain_steps: int = 4) -> float:
        """ReLeQ accuracy oracle: short retrain then rel. val accuracy."""
        params, _ = self.train(retrain_steps, bits_by_name,
                               params=self.params, mom=jax.tree.map(jnp.zeros_like, self.mom))
        acc = self.accuracy(params, bits_by_name)
        return acc / max(self.fp_acc, 1e-6)

    def long_retrain(self, bits_by_name: dict, steps: int = 200) -> float:
        """Paper's final step: long QAT retrain at the chosen bitwidths."""
        params, _ = self.train(steps, bits_by_name, params=self.params,
                               mom=jax.tree.map(jnp.zeros_like, self.mom))
        return self.accuracy(params, bits_by_name) / max(self.fp_acc, 1e-6)

    # ------------------------------------------------------------------
    def weight_std(self) -> dict:
        return {n: float(jnp.std(self.params[n]["w"])) for n in self.names}

    def weights_by_name(self) -> dict:
        return {n: self.params[n]["w"] for n in self.names}

    def make_env_factory(self, *, retrain_steps: int = 4,
                         reward_mode: str = "proposed",
                         bitset=(2, 3, 4, 5, 6, 7, 8),
                         eval_mode: str = "per_step", cache=None):
        """Env factory for ReLeQSearch / the async autotune service.

        ``cache=None`` builds a fresh :class:`EvalCache`; pass one to share
        retrain results across searches (warm-started runs).  The cache is
        exposed as ``factory.eval_cache`` so the search record can report
        its hit rate."""
        from repro.core.evalcache import EvalCache

        memo = cache if cache is not None else EvalCache()

        def evaluate(bits: dict) -> float:
            value, _ = memo.get_or_compute(
                bits, lambda: self.evaluate_bits(bits, retrain_steps))
            return value

        def factory(env_id: int) -> QuantEnv:
            return QuantEnv(
                groups=self.groups,
                evaluate=evaluate,
                weight_std=self.weight_std(),
                bitset=bitset,
                frozen=self.frozen,
                reward_mode=reward_mode,
                eval_mode=eval_mode,
            )

        factory.eval_cache = memo
        factory.evaluate = evaluate
        factory.compute = lambda bits: self.evaluate_bits(bits, retrain_steps)
        return factory
