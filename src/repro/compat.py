"""Compatibility shims for the container's pinned jax (0.4.x).

The codebase is written against the jax 0.6-era mesh API
(``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh``).  On 0.4.x the
ambient mesh lives in ``thread_resources.env.physical_mesh`` and mesh
contexts are entered with ``with mesh:``.  ``ambient_mesh()`` papers over
the read side; importing this module installs a ``jax.set_mesh`` fallback
for the write side.  Every shim defers to the real API when present, so
the same source runs unchanged on newer jax.
"""
from __future__ import annotations

import contextlib
import inspect

import jax


def ambient_mesh():
    """The mesh enclosing the current trace/context, or None.

    Callers treat ``None`` and an empty mesh identically (no sharding).
    An empty abstract mesh falls through to the legacy thread-resources
    mesh: on versions that have ``get_abstract_mesh`` but not
    ``set_mesh``, our ``set_mesh`` shim enters the legacy context, and
    preferring the (empty) abstract mesh would silently disable sharding.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        m = get()
        if m is not None and not m.empty:
            return m
    try:
        from jax._src import mesh as mesh_lib

        pm = mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - future jax drops the legacy path
        return None
    return None if pm.empty else pm


if not hasattr(jax, "set_mesh"):

    @contextlib.contextmanager
    def _set_mesh(mesh):
        with mesh:
            yield mesh

    jax.set_mesh = _set_mesh


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=True,
                          **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)

    jax.shard_map = _shard_map_compat


if not hasattr(jax.sharding, "AxisType"):
    import enum

    class _AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisType


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _make_mesh = jax.make_mesh

    def _make_mesh_compat(axis_shapes, axis_names, *a, axis_types=None, **kw):
        return _make_mesh(axis_shapes, axis_names, *a, **kw)

    jax.make_mesh = _make_mesh_compat
