"""Flash attention (custom_vjp) vs naive attention: values and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import blocked_attention, decode_attention

RNG = np.random.default_rng(3)
B, S, H, KV, hd = 2, 29, 4, 2, 16


def naive(q, k, v, causal=True, window=None):
    G = q.shape[2] // k.shape[2]
    Bq, Sq = q.shape[:2]
    qf = q.reshape(Bq, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qf, k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sq)[None, :]
    m = jnp.ones((Sq, Sq), bool)
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32)).reshape(
        Bq, Sq, H, hd)


@pytest.fixture(scope="module")
def qkv():
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("chunks", [(512, 512), (16, 8), (7, 5)])
def test_forward_matches_naive(qkv, window, chunks):
    q, k, v = qkv
    got = blocked_attention(q, k, v, causal=True, window=window,
                            q_chunk=chunks[0], kv_chunk=chunks[1])
    want = naive(q, k, v, window=window)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-4


@pytest.mark.parametrize("window", [None, 8])
def test_gradients_match_naive(qkv, window):
    q, k, v = qkv

    def loss_flash(q, k, v):
        o = blocked_attention(q, k, v, causal=True, window=window,
                              q_chunk=16, kv_chunk=8)
        return jnp.sum(jnp.sin(o))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, window=window)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_decode_attention_masks_by_length(qkv):
    q, k, v = qkv
    lengths = jnp.asarray([5, 17], jnp.int32)
    got = decode_attention(q[:, :1], k, v, lengths)
    for b in range(B):
        L = int(lengths[b])
        qf = q[b, 0].reshape(KV, H // KV, hd)
        s = jnp.einsum("kgh,tkh->kgt", qf, k[b, :L]) * hd ** -0.5
        o = jnp.einsum("kgt,tkh->kgh", jax.nn.softmax(s, -1),
                       v[b, :L]).reshape(H, hd)
        assert float(jnp.max(jnp.abs(got[b, 0] - o))) < 1e-5
