"""Paged KV-cache engine: paged-vs-slot token parity across all three
model families, one-executable chunked prefill, block-allocator
invariants (hypothesis property test), preemption-not-crash on block
exhaustion, and speculative decoding gates (greedy spec token parity,
rejection-sampler distribution exactness, zero-extra-block invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: skip ONLY property tests
    import types

    st = types.SimpleNamespace(integers=lambda *a, **k: None,
                               lists=lambda *a, **k: None,
                               sampled_from=lambda *a, **k: None)

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import PagedCachePool, ServeEngine
from repro.spec import SpecConfig
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_verify_chunk,
    quantize_for_serving,
)

RNG = jax.random.PRNGKey(0)


def _served(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(RNG),
                                   policy_for(model, default_bits=4))
    return cfg, model, sparams


@pytest.fixture(scope="module")
def glm4():
    """Shared glm4 model + one chunked-prefill/decode jit cache for the
    whole module (compile budget)."""
    cfg, model, sparams = _served("glm4-9b")
    fns = {"prefill_fn": make_chunked_prefill(model, donate=False),
           "decode_fn": make_decode_step(model, donate=False)}
    return cfg, model, sparams, fns


def _prompt(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def _run(model, sparams, prompts, gens, *, cache, num_slots=3, max_len=24,
         **kw):
    eng = ServeEngine(model, sparams, num_slots=num_slots, max_len=max_len,
                      cache=cache, **kw)
    rids = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    eng.run_until_drained()
    return [eng.output(r) for r in rids], eng


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("arch", ["glm4-9b", "hymba-1.5b", "rwkv6-1.6b"])
def test_paged_matches_slot_all_families(arch):
    """Token-for-token parity paged-vs-slot for the same request stream:
    dense transformer (paged KV), hybrid transformer+Mamba (paged KV +
    slot SSM state, sliding-window ring blocks), RWKV (pure O(1) state)."""
    cfg, model, sparams = _served(arch)
    prompts = [_prompt(cfg, 3 + 2 * s, seed=s) for s in (1, 2, 3)]
    gens = [4, 5, 6]
    want, _ = _run(model, sparams, prompts, gens, cache="slot")
    got, eng = _run(model, sparams, prompts, gens, cache="paged",
                    block_size=4, prefill_chunk=4)
    assert got == want
    assert eng.pool.num_free == eng.pool.num_slots  # rows drained


@pytest.mark.parametrize("arch", ["hymba-1.5b", "rwkv6-1.6b"])
def test_row_reuse_fresh_state(arch):
    """More requests than rows forces row recycling: a fresh admission
    into a reused row must see ZERO carried SSM/wkv/token-shift state,
    not the previous occupant's (the slot engine splices a fresh cache;
    the paged chunk path masks the carry on chunk 0)."""
    cfg, model, sparams = _served(arch)
    prompts = [_prompt(cfg, 4 + s % 3, seed=10 + s) for s in range(5)]
    gens = [3, 2, 4, 3, 2]
    want, _ = _run(model, sparams, prompts, gens, cache="slot", num_slots=2)
    got, _ = _run(model, sparams, prompts, gens, cache="paged", num_slots=2,
                  block_size=4, prefill_chunk=4)
    assert got == want


def test_o1_state_family_still_batches_concurrently():
    """The admission watermark must not apply to O(1)-state families —
    they have no blocks at all, so `free >= needed + reserve` would read
    `0 >= running` and silently serialize RWKV serving to one sequence."""
    cfg, model, sparams = _served("rwkv6-1.6b")
    prompts = [_prompt(cfg, 4, seed=s) for s in range(4)]
    eng = ServeEngine(model, sparams, num_slots=3, max_len=24, cache="paged")
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    peak = 0
    while eng.scheduler.has_work():
        eng.step()
        peak = max(peak, eng.num_running)
    assert peak == 3, peak  # all rows busy, not sequential


def test_one_prefill_one_decode_executable():
    """Mixed prompt lengths compile exactly ONE prefill and ONE decode
    executable (the slot engine compiles a prefill per distinct length)."""
    cfg, model, sparams = _served("glm4-9b")
    prompts = [_prompt(cfg, n, seed=n) for n in (2, 3, 5, 7, 11, 13)]
    prefill_fn = make_chunked_prefill(model, donate=False)
    decode_fn = make_decode_step(model, donate=False)
    _run(model, sparams, prompts, [3] * len(prompts), cache="paged",
         max_len=32, block_size=4, prefill_chunk=4,
         prefill_fn=prefill_fn, decode_fn=decode_fn)
    assert prefill_fn._cache_size() == 1
    assert decode_fn._cache_size() == 1


def test_preemption_preserves_tokens(glm4):
    """Block exhaustion preempts-and-requeues instead of raising, and the
    replayed sequences still emit the slot engine's exact tokens."""
    cfg, model, sparams, fns = glm4
    prompts = [_prompt(cfg, 4, seed=s) for s in range(4)]
    gens = [10] * 4
    want, _ = _run(model, sparams, prompts, gens, cache="slot", num_slots=4,
                   max_len=16)
    # 8 usable blocks of 4 tokens < 4 seqs x 14 tokens: must preempt
    got, eng = _run(model, sparams, prompts, gens, cache="paged", num_slots=4,
                    max_len=16, block_size=4, num_blocks=9, prefill_chunk=4,
                    **fns)
    m = eng.metrics()
    assert got == want
    assert m["preemptions"] > 0
    assert eng.pool.num_free_blocks == eng.pool.num_blocks - 1  # no leak
    assert all(r["state"] == "finished" for r in m["requests"])


def test_resume_after_preemption_midstream(glm4):
    """A request preempted mid-decode keeps its already-delivered tokens
    and continues the same stream (no re-emission, no gap).  The pool is
    sized so the admission watermark passes two sequences but their
    decode GROWTH (1 -> 4 blocks each) outruns the reserve — preemption
    must come from growth, not from an admit-then-preempt cycle."""
    cfg, model, sparams, fns = glm4
    prompts = [_prompt(cfg, 4, seed=s) for s in range(3)]
    want, _ = _run(model, sparams, prompts, [10] * 3, cache="paged",
                   num_slots=3, max_len=16, block_size=4, prefill_chunk=4,
                   **fns)
    got, eng = _run(model, sparams, prompts, [10] * 3, cache="paged",
                    num_slots=3, max_len=16, block_size=4, num_blocks=8,
                    prefill_chunk=4, **fns)
    assert got == want
    preempted = [r for r in eng.metrics()["requests"] if r["preemptions"]]
    assert preempted  # the scarce pool actually exercised the path
    assert all(r["new_tokens"] == 10 for r in eng.metrics()["requests"])


def test_paged_oversubscription_more_seqs_at_equal_bytes(glm4):
    """At equal KV bytes the paged pool runs strictly more concurrent
    sequences than the slot pool when actual lengths < max_len — the
    memory win paging exists for."""
    cfg, model, sparams, fns = glm4
    max_len, bs = 32, 4
    slot_seqs = 2
    # paged pool with the slot pool's byte budget (2 x 32 tokens = 16
    # blocks + garbage) but 6 sequence rows
    prompts = [_prompt(cfg, 3, seed=s) for s in range(6)]
    eng = ServeEngine(model, sparams, num_slots=6, max_len=max_len,
                      cache="paged", block_size=bs,
                      num_blocks=slot_seqs * max_len // bs + 1,
                      prefill_chunk=4, **fns)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    peak = 0
    while eng.scheduler.has_work():
        eng.step()
        peak = max(peak, eng.num_running)
    assert peak > slot_seqs, peak
    assert all(len(eng.output(i)) == 4 for i in range(6))


# ------------------------------------------------- allocator property test
@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                 max_size=60),
    num_seqs=st.integers(min_value=1, max_value=4),
    usable=st.integers(min_value=4, max_value=12),
)
def test_block_allocator_invariants(ops, num_seqs, usable):
    """Random alloc/ensure/free traffic: no double-alloc, no leak, and
    exhaustion reports False (→ preemption) instead of raising."""

    class _FakeModel:
        class cfg:
            sliding_window = None

        def init_cache(self, batch, max_len, dtype=None):
            return {"k": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                    "v": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                    "length": jnp.zeros((batch,), jnp.int32)}

    bs = 4
    pool = PagedCachePool(_FakeModel(), num_seqs, max_len=4 * bs,
                          block_size=bs, num_blocks=usable + 1)
    live: dict[int, int] = {}  # seq -> ensured tokens
    for op in ops:
        if op <= 2 and pool.num_free:  # alloc a new sequence
            seq = pool.alloc_seq()
            assert seq not in live
            live[seq] = 0
        elif op <= 4 and live:         # grow an arbitrary live sequence
            seq = sorted(live)[op % len(live)]
            want = live[seq] + bs
            if pool.ensure(seq, want):
                live[seq] = want
            else:  # exhaustion: allocator must not have changed anything
                assert pool.blocks_needed(want) - len(
                    pool._seq_blocks[seq]) > pool.num_free_blocks
        elif live:                      # free a sequence
            seq = sorted(live)[op % len(live)]
            pool.free_seq(seq)
            del live[seq]
        # global invariants after every op
        owned = [b for s in pool._seq_blocks.values() for b in s]
        assert len(owned) == len(set(owned))          # no double-alloc
        assert 0 not in owned                          # garbage block safe
        assert len(owned) + pool.num_free_blocks == pool.num_blocks - 1
    for seq in list(live):
        pool.free_seq(seq)
    assert pool.num_free_blocks == pool.num_blocks - 1  # no leak
    assert pool.num_free == pool.num_seqs


def test_allocator_errors_and_garbage_block():
    """Deterministic allocator edges (run even without hypothesis)."""

    class _FakeModel:
        class cfg:
            sliding_window = None

        def init_cache(self, batch, max_len, dtype=None):
            return {"k": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                    "v": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                    "length": jnp.zeros((batch,), jnp.int32)}

    pool = PagedCachePool(_FakeModel(), 2, max_len=8, block_size=4,
                          num_blocks=3)  # 2 usable blocks
    assert pool.blocks_per_seq == 2 and pool.num_free_blocks == 2
    s0 = pool.alloc_seq()
    assert pool.ensure(s0, 8)                 # takes both blocks
    assert pool.num_free_blocks == 0
    s1 = pool.alloc_seq()
    assert not pool.ensure(s1, 4)             # exhausted -> False, no raise
    with pytest.raises(ValueError):
        pool.free_seq(7)                      # never allocated
    pool.free_seq(s0)
    with pytest.raises(ValueError):
        pool.free_seq(s0)                     # double free
    assert pool.ensure(s1, 4)                 # freed blocks reusable
    assert (pool.block_tables[s1, 0] != 0).all()  # never hands out block 0
    with pytest.raises(ValueError):
        PagedCachePool(_FakeModel(), 1, max_len=8, block_size=4,
                       num_blocks=2)          # < one full sequence


def test_shared_block_free_decrefs_never_frees():
    """Freeing a sequence whose blocks are shared (refcount > 1) must
    DECREF them — a shared block on the free heap would let a third
    sequence overwrite KV another sequence still reads.  Only the last
    owner parks it in the trie (evictable), and over-freeing raises the
    same ValueError as any double free."""

    class _FakeModel:
        class cfg:
            sliding_window = None

        def init_cache(self, batch, max_len, dtype=None):
            return {"k": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                    "v": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                    "length": jnp.zeros((batch,), jnp.int32)}

    pool = PagedCachePool(_FakeModel(), 2, max_len=16, block_size=4,
                          num_blocks=7)  # 6 usable
    toks = list(range(8))  # two full blocks of content
    s0 = pool.alloc_seq()
    assert pool.ensure(s0, 8)
    pool.record_tokens(s0, toks)          # publish both blocks
    s1 = pool.alloc_seq()
    assert pool.map_shared(s1, toks + [9]) == 8  # incref, no COW cap
    shared = list(pool._seq_blocks[s0])
    assert pool._seq_blocks[s1] == shared
    assert all(pool._refcount[b] == 2 for b in shared)
    pool.free_seq(s0)                     # first owner gone: decref only
    assert all(pool._refcount[b] == 1 for b in shared)
    assert not (set(shared) & set(pool._free_blocks))
    assert not (set(shared) & set(pool._cached))
    with pytest.raises(ValueError):
        pool.free_seq(s0)                 # double free still raises
    pool.free_seq(s1)                     # last owner: park in the trie
    assert set(shared) <= set(pool._cached)
    assert not (set(shared) & set(pool._free_blocks))
    with pytest.raises(ValueError):
        pool._decref(shared[0])           # block-level over-free raises
    # conservation: free heap + cached == usable
    assert len(pool._free_blocks) + len(pool._cached) == pool.num_blocks - 1


# ------------------------------------------------------------- speculation
@pytest.mark.parametrize("arch", ["glm4-9b", "hymba-1.5b", "rwkv6-1.6b"])
def test_spec_greedy_parity_all_families(arch):
    """Greedy speculative decode is token-identical to plain paged decode
    on all three families.  Random weights + a 2-bit draft put acceptance
    near zero, so this is the HARD regime: every window exercises
    rejection, the recurrent-state fix-up (hymba SSM / rwkv wkv), and the
    hymba sliding-window ring cap — and the fix-up reuses the one verify
    executable (same fixed C = k + 1 shapes)."""
    cfg, model, sparams = _served(arch)
    prompts = [_prompt(cfg, 3 + 2 * s, seed=s) for s in (1, 2, 3)]
    gens = [4, 5, 6]
    want, _ = _run(model, sparams, prompts, gens, cache="paged",
                   block_size=4, prefill_chunk=4)
    ver = make_verify_chunk(model, donate=False)
    got, eng = _run(model, sparams, prompts, gens, cache="paged",
                    block_size=4, prefill_chunk=4, verify_fn=ver,
                    spec=SpecConfig(k=3, draft_bits=2))
    assert got == want
    assert eng.metrics()["spec"]["windows"] > 0
    assert ver._cache_size() == 1  # windows AND fix-ups: one executable
    assert eng.pool.num_free == eng.pool.num_slots
    assert eng.pool.num_free_blocks == eng.pool.num_blocks - 1  # no leak


def test_spec_rejection_sampler_preserves_target_distribution():
    """Chi-square pin on the speculative rejection sampler: for a draft q
    deliberately far from the target p, the emitted token must still be
    EXACTLY p-distributed — both for a sampled draft (accept ratio p/q +
    residual) and for a point-mass draft (q=None: accept p(d), residual
    p with d removed)."""
    from repro.serve.request import SamplingParams, warp_probs
    from repro.spec import KIND_DRAFT, draft_token, spec_window

    t_logits = np.asarray([1.0, 0.3, -0.5, 2.0, 0.0, -1.2])
    q_logits = np.asarray([2.0, -1.0, 1.5, 0.0, 0.5, -2.0])  # far from p
    bonus = np.zeros_like(t_logits)  # row 1: only read on acceptance
    sp = SamplingParams(temperature=1.0, seed=0)
    p = warp_probs(t_logits, sp)
    N, V = 4000, t_logits.size
    crit = 20.515  # chi2 critical value, df = V - 1 = 5, alpha = 0.001

    counts = np.zeros(V)
    for s in range(N):
        rng_for = lambda pos, kind, s=s: np.random.default_rng(
            (11, s, pos, kind))
        d, q = draft_token(q_logits, sp, rng_for(0, KIND_DRAFT))
        emitted, _ = spec_window([d], np.stack([t_logits, bonus]), sp,
                                 rng_for, base_pos=0, q_probs=[q])
        counts[emitted[0]] += 1
    chi2 = float(((counts - N * p) ** 2 / (N * p)).sum())
    assert chi2 < crit, (chi2, counts)

    counts = np.zeros(V)
    d = int(np.argmax(q_logits))  # greedy draft under a sampled target
    for s in range(N):
        rng_for = lambda pos, kind, s=s: np.random.default_rng(
            (13, s, pos, kind))
        emitted, _ = spec_window([d], np.stack([t_logits, bonus]), sp,
                                 rng_for, base_pos=0, q_probs=[None])
        counts[emitted[0]] += 1
    chi2 = float(((counts - N * p) ** 2 / (N * p)).sum())
    assert chi2 < crit, (chi2, counts)


def test_spec_zero_extra_blocks_under_pressure(glm4):
    """Speculation allocates from the SAME pool the target owns: after
    every step no block is double-owned, conservation holds, and no row
    ever covers more cache than its request's own total_len — i.e. zero
    KV allocation attributable to the draft.  The pool is scarce enough
    to force preemption WITH speculation on, and the greedy streams must
    still match an ample-pool non-spec run (preempt-replay under spec)."""
    cfg, model, sparams, fns = glm4
    prompts = [_prompt(cfg, 4, seed=s) for s in range(4)]
    gens = [10] * 4
    want, _ = _run(model, sparams, prompts, gens, cache="paged", num_slots=4,
                   max_len=16, block_size=4, prefill_chunk=4, **fns)
    eng = ServeEngine(model, sparams, num_slots=4, max_len=16, cache="paged",
                      block_size=4, num_blocks=9, prefill_chunk=4,
                      spec=SpecConfig(k=3, draft_bits=2), **fns)
    rids = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    pool = eng.pool
    while eng.scheduler.has_work():
        eng.step()
        owned = [b for s in pool._seq_blocks.values() for b in s]
        assert len(owned) == len(set(owned))               # no double-alloc
        assert len(owned) + pool.num_free_blocks == pool.num_blocks - 1
        for slot, seq in eng.scheduler.running.items():
            assert len(pool._seq_blocks[slot]) <= pool.blocks_needed(
                seq.request.total_len())                   # draft adds zero
    assert [eng.output(r) for r in rids] == want
    assert eng.metrics()["preemptions"] > 0                # pressure was real
    assert pool.num_free_blocks == pool.num_blocks - 1     # no leak


def test_spec_sampled_stream_batch_invariant(glm4):
    """Per-request PRNG streams fold (seed, request id, position, kind):
    a sampled request's token stream must not depend on batch composition
    — in plain decode AND in speculative mode, where the same position
    can be resolved by different windowings."""
    from repro.serve.request import SamplingParams

    cfg, model, sparams, fns = glm4
    ver = make_verify_chunk(model, donate=False)
    sp = SamplingParams(temperature=1.0, top_p=0.9, seed=7)

    def run(companion, spec):
        kw = dict(fns)
        if spec is not None:
            kw["verify_fn"] = ver
        eng = ServeEngine(model, sparams, num_slots=3, max_len=24,
                          cache="paged", block_size=4, prefill_chunk=4,
                          spec=spec, **kw)
        rid = eng.submit(_prompt(cfg, 5, seed=1), max_new_tokens=6,
                         sampling=sp)
        if companion:
            eng.submit(_prompt(cfg, 3, seed=2), max_new_tokens=8,
                       sampling=SamplingParams(temperature=0.8, seed=99))
        eng.run_until_drained()
        return eng.output(rid)

    assert run(False, None) == run(True, None)
    spec_cfg = SpecConfig(k=3, draft_bits=2)
    assert run(False, spec_cfg) == run(True, spec_cfg)


def test_spec_executables_one_verify_two_decode(glm4):
    """A speculative engine compiles exactly ONE verify executable (fixed
    C = k + 1 width, short windows pad) and exactly TWO decode entries
    under the one jit wrapper — target bits + draft bits, keyed by the
    Packed leaves' static bit counts."""
    cfg, model, sparams, _ = glm4
    decode_fn = make_decode_step(model, donate=False)
    verify_fn = make_verify_chunk(model, donate=False)
    prompts = [_prompt(cfg, n, seed=n) for n in (3, 5, 7)]
    _run(model, sparams, prompts, [5, 4, 6], cache="paged", block_size=4,
         prefill_chunk=4, decode_fn=decode_fn, verify_fn=verify_fn,
         spec=SpecConfig(k=3, draft_bits=2))
    assert verify_fn._cache_size() == 1
    assert decode_fn._cache_size() == 2


# --------------------------------------------------------------- sampling
def test_top_p_sampling_deterministic_and_nucleus(glm4):
    """top-p: deterministic per seed, equals greedy as top_p -> 0, and
    never samples outside the nucleus."""
    from repro.serve.request import SamplingParams, select_token

    logits = np.asarray([0.0, 4.0, 3.0, -2.0, 3.5])
    rng = lambda s: np.random.default_rng(s)
    tiny = SamplingParams(temperature=1.0, top_p=1e-6, seed=0)
    assert select_token(logits, tiny, rng(0)) == 1  # nucleus = argmax only
    sp = SamplingParams(temperature=1.0, top_p=0.8, seed=3)
    a = [select_token(logits, sp, rng(3)) for _ in range(1)]
    b = [select_token(logits, sp, rng(3)) for _ in range(1)]
    assert a == b                                   # per-seed deterministic
    draws = {select_token(logits, sp, rng(s)) for s in range(50)}
    assert draws <= {1, 2, 4}                       # 0.8-mass nucleus
    # end-to-end through the paged engine: same seed -> same stream
    cfg, model, sparams, fns = glm4
    prompt = _prompt(cfg, 5, seed=9)

    def run(seed):
        eng = ServeEngine(model, sparams, num_slots=2, max_len=16,
                          cache="paged", block_size=4, prefill_chunk=4, **fns)
        rid = eng.submit(prompt, max_new_tokens=6,
                         sampling=SamplingParams(temperature=1.0, top_p=0.9,
                                                 seed=seed))
        eng.run_until_drained()
        return eng.output(rid)

    assert run(5) == run(5)
