"""Substrates: data pipeline, optimizer, checkpointing, trainer restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro.data import SyntheticLMData
from repro.optim import AdamW, cosine_schedule
from repro.quant.int8_opt import QTensor, quantize_state


class TestData:
    def test_deterministic(self):
        a = SyntheticLMData(seed=3, global_batch=4, seq_len=16, vocab=97)
        b = SyntheticLMData(seed=3, global_batch=4, seq_len=16, vocab=97)
        np.testing.assert_array_equal(a.next()["tokens"], b.next()["tokens"])

    def test_cursor_restore(self):
        a = SyntheticLMData(seed=1, global_batch=4, seq_len=8, vocab=50)
        a.next(); a.next()
        state = a.state_dict()
        want = a.next()
        b = SyntheticLMData(seed=1, global_batch=4, seq_len=8, vocab=50)
        b.load_state_dict(state)
        np.testing.assert_array_equal(b.next()["tokens"], want["tokens"])

    def test_shards_disjoint_streams(self):
        a = SyntheticLMData(seed=1, global_batch=8, seq_len=8, vocab=50,
                            shard=0, num_shards=2)
        b = SyntheticLMData(seed=1, global_batch=8, seq_len=8, vocab=50,
                            shard=1, num_shards=2)
        assert a.local_batch == 4
        assert not np.array_equal(a.next()["tokens"], b.next()["tokens"])

    def test_learnable_structure(self):
        """Markov chain: every next token is one of 4 successors."""
        from repro.data.pipeline import _chain

        d = SyntheticLMData(seed=5, global_batch=2, seq_len=64, vocab=31)
        chain = _chain(5, 31)
        batch = d.next()
        toks = np.concatenate([batch["tokens"][:, :1],
                               batch["labels"]], axis=1)
        for b in range(2):
            for t in range(63):
                assert toks[b, t + 1] in chain[toks[b, t]]


class TestAdamW:
    def test_quadratic_convergence_both_moments(self):
        w0 = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(300,)),
                               jnp.float32)}
        for moments in ("fp32", "int8"):
            opt = AdamW(lr=0.1, moments=moments, clip_norm=None)
            st, p = opt.init(w0), w0
            for _ in range(60):
                g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
                p, st = opt.update(p, g, st)
            assert float(jnp.sum(p["w"] ** 2)) < 0.5, moments

    def test_clip_norm(self):
        opt = AdamW(lr=0.0, clip_norm=1.0)
        p = {"w": jnp.zeros((4,))}
        st = opt.init(p)
        p2, st = opt.update(p, {"w": jnp.full((4,), 100.0)}, st)
        np.testing.assert_allclose(np.asarray(p2["w"]), 0.0)

    def test_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert float(lr(5)) == pytest.approx(0.5)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(110)) == pytest.approx(0.1, abs=1e-3)


class TestCheckpoint:
    def test_roundtrip_with_qtensor(self, tmp_path):
        tree = {"a": jnp.arange(5.0), "q": quantize_state(jnp.ones((512,))),
                "nested": {"b": jnp.ones((2, 3), jnp.bfloat16)}}
        ckpt_lib.save(str(tmp_path), 7, tree, meta={"x": 1})
        back, meta, step = ckpt_lib.restore(str(tmp_path))
        assert step == 7 and meta["x"] == 1
        assert isinstance(back["q"], QTensor)
        np.testing.assert_array_equal(back["a"], np.arange(5.0))
        assert back["nested"]["b"].dtype == jnp.bfloat16

    def test_prune_and_latest(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            ckpt_lib.save(str(tmp_path), s, {"x": jnp.asarray(s)}, keep=2)
        assert ckpt_lib.latest_step(str(tmp_path)) == 5
        _, _, step = ckpt_lib.restore(str(tmp_path), step=4)
        assert step == 4
        with pytest.raises(FileNotFoundError):
            ckpt_lib.restore(str(tmp_path), step=1)  # pruned

    def test_incomplete_tmp_ignored(self, tmp_path):
        ckpt_lib.save(str(tmp_path), 1, {"x": jnp.asarray(1)})
        os.makedirs(tmp_path / "step_00000009.tmp")  # crashed write
        assert ckpt_lib.latest_step(str(tmp_path)) == 1


class TestTrainerRestart:
    def test_resume_identical_loss_curve(self, tmp_path):
        """Crash after step 6, restart; steps 7-10 must match a straight run."""
        from repro.configs import get_config
        from repro.models import build_model
        from repro.quant.qat import bits_assignment, policy_for
        from repro.train.train_step import init_state, make_train_step
        from repro.train.trainer import Trainer

        cfg = get_config("phi3-mini-3.8b", smoke=True)
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        bm = {k: jnp.asarray(v) for k, v in bits_assignment(
            model.quant_groups(), policy_for(model, 8)).items()}
        step_fn = make_train_step(model, opt, donate=False)

        def mk_trainer(ckpt_dir):
            data = SyntheticLMData(seed=0, global_batch=4, seq_len=16,
                                   vocab=cfg.vocab_size)
            return Trainer(model=model, optimizer=opt, data=data,
                           step_fn=step_fn, bits_map=bm, ckpt_dir=ckpt_dir,
                           ckpt_interval=3, log_every=0)

        # straight 10-step run (no checkpointing)
        t0 = mk_trainer(None)
        s0 = init_state(model, opt, jax.random.PRNGKey(0))
        t0.run(s0, 10)
        ref = [h["loss"] for h in t0.history]

        # run to 6, "crash", resume to 10
        t1 = mk_trainer(str(tmp_path))
        s1 = init_state(model, opt, jax.random.PRNGKey(0))
        t1.run(s1, 6)
        t2 = mk_trainer(str(tmp_path))
        s2 = init_state(model, opt, jax.random.PRNGKey(0))  # fresh; restored inside
        t2.run(s2, 10)
        resumed = [h["loss"] for h in t2.history]
        np.testing.assert_allclose(resumed, ref[6:], rtol=1e-4)

    def test_straggler_detection(self):
        import time as _t

        from repro.configs import get_config
        from repro.models import build_model
        from repro.quant.qat import bits_assignment, policy_for
        from repro.train.train_step import init_state, make_train_step
        from repro.train.trainer import Trainer

        cfg = get_config("phi3-mini-3.8b", smoke=True)
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        bm = {k: jnp.asarray(v) for k, v in bits_assignment(
            model.quant_groups(), policy_for(model, 8)).items()}
        inner = make_train_step(model, opt, donate=False)
        calls = {"n": 0}

        def slow_step(state, batch, bmm):
            calls["n"] += 1
            if calls["n"] == 8:
                _t.sleep(1.0)  # injected straggler
            return inner(state, batch, bmm)

        flagged = []
        tr = Trainer(model=model, optimizer=opt,
                     data=SyntheticLMData(seed=0, global_batch=4, seq_len=16,
                                          vocab=cfg.vocab_size),
                     step_fn=slow_step, bits_map=bm, ckpt_dir=None,
                     straggler_factor=3.0, log_every=0,
                     on_straggler=lambda s, dt, ema: flagged.append(s))
        tr.run(init_state(model, opt, jax.random.PRNGKey(0)), 10)
        assert tr.straggler_count >= 1 and flagged
