"""One-token hotpath gates: on-device fused sampling + the pipelined
decode loop.

- Greedy device sampling is BITWISE-identical to the host oracle
  (``Request.select_token``) across all three model families, under
  preemption, with quantized KV, and through speculative windows.
- Sampled draws (temperature > 0, top-p < 1) are exactly distributed per
  the host-warped probabilities (chi-square gate) and are deterministic,
  batch-composition-invariant, and pipeline-invariant (hypothesis).
- ``warp_probs``'s argpartition nucleus path is bitwise-equal to the
  full-sort reference, ties included.
- ``greedy_window`` (the spec fast path's resolver) equals
  ``spec_window`` for greedy windows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: skip ONLY property tests
    import types

    st = types.SimpleNamespace(integers=lambda *a, **k: None,
                               lists=lambda *a, **k: None,
                               floats=lambda *a, **k: None,
                               sampled_from=lambda *a, **k: None)

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import SamplingParams, ServeEngine
from repro.serve.request import Request, warp_probs
from repro.serve.sampler import row_arrays, sample_rows
from repro.spec import SpecConfig
from repro.spec.sampler import greedy_window, spec_window
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_verify_chunk,
    quantize_for_serving,
)

RNG = jax.random.PRNGKey(0)


def _served(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(RNG),
                                   policy_for(model, default_bits=4))
    return cfg, model, sparams


@pytest.fixture(scope="module")
def glm4():
    cfg, model, sparams = _served("glm4-9b")
    fns = {"prefill_fn": make_chunked_prefill(model, donate=False),
           "decode_fn": make_decode_step(model, donate=False)}
    return cfg, model, sparams, fns


def _prompt(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def _serve(model, sparams, prompts, gens, **kw):
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 4)
    eng = ServeEngine(model, sparams, cache="paged", **kw)
    rids = [eng.submit(p, max_new_tokens=g,
                       sampling=kw.get("_sampling") or SamplingParams())
            for p, g in zip(prompts, gens)]
    eng.run_until_drained()
    return [eng.output(r) for r in rids], eng


# ------------------------------------------------- device sampler unit level
def _draw_device(logits, sampling, request_id=0, position=0):
    """One device draw through the packed-row entry point."""
    B = 1
    req = Request(request_id, [1], 8, sampling)
    arrs = row_arrays(B, [(0, req)])
    out = sample_rows(jnp.asarray(logits[None, :]),
                      *map(jnp.asarray, arrs),
                      jnp.asarray(np.array([position], np.int32)))
    return int(np.asarray(out)[0])


def test_device_greedy_bitwise_equals_host_oracle():
    """Including exact-tie rows: both sides must break toward the first
    index after the same monotone cast."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        row = rng.normal(size=(97,)).astype(np.float32)
        if trial % 3 == 0:  # manufacture ties at the max
            m = row.max()
            row[rng.integers(0, 97, size=3)] = m
        req = Request(trial, [1], 8, SamplingParams())
        assert _draw_device(row, SamplingParams(), trial) == \
            req.select_token(row)


def test_device_sampling_chi_square_exact():
    """temperature > 0 / top-p < 1: device draws across many positions
    must match the HOST-warped distribution (the single definition in
    request.warp_probs) by chi-square."""
    sp = SamplingParams(temperature=1.0, top_k=0, top_p=0.8, seed=11)
    rng = np.random.default_rng(7)
    row = (rng.normal(size=(12,)) * 1.5).astype(np.float32)
    p = warp_probs(row, sp)
    N = 4000
    req = Request(3, [1], 8, sp)
    arrs = row_arrays(N, [(i, req) for i in range(N)])
    draws = np.asarray(sample_rows(
        jnp.asarray(np.broadcast_to(row, (N, row.size)).copy()),
        *map(jnp.asarray, arrs),
        jnp.asarray(np.arange(N, dtype=np.int32))))
    counts = np.bincount(draws, minlength=row.size)
    live = p > 1e-12
    assert counts[~live].sum() == 0, "drew a nucleus-masked token"
    exp = p[live] * N
    chi2 = float(((counts[live] - exp) ** 2 / exp).sum())
    # df = live-1; p=0.001 critical value for df<=11 is < 31.3
    assert chi2 < 31.3, (chi2, counts, p)


def test_device_sampling_deterministic_and_position_keyed():
    sp = SamplingParams(temperature=0.9, top_k=6, seed=5)
    rng = np.random.default_rng(1)
    row = rng.normal(size=(33,)).astype(np.float32)
    a = _draw_device(row, sp, request_id=2, position=4)
    b = _draw_device(row, sp, request_id=2, position=4)
    assert a == b
    # the stream is keyed by (seed, request, position): over many
    # positions/requests the draws cannot all collapse to one value
    alts = {_draw_device(row, sp, request_id=r, position=pos)
            for r in range(4) for pos in range(16)}
    assert len(alts) > 1


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
       st.integers(0, 6), st.integers(0, 40))
@settings(max_examples=25, deadline=None)
def test_device_stream_batch_composition_invariant(seed, nrows, slot,
                                                   position):
    """Hypothesis: the token drawn for a request depends only on its own
    (logits, sampling params, position) — not on which slot it occupies
    or who shares the batch.  This is the property that makes device
    sampling safe under preemption/re-admission AND under the lookahead
    pipeline (whose chained dispatches reuse the same per-position
    streams)."""
    rng = np.random.default_rng(seed)
    V = 29
    slot = slot % nrows
    sp = SamplingParams(temperature=0.7 + (seed % 5) * 0.1,
                        top_k=int(seed % 7), top_p=0.9, seed=seed % 997)
    req = Request(int(seed % 1009), [1], 8, sp)
    row = rng.normal(size=(V,)).astype(np.float32)
    # batch A: the request alone in slot 0
    arrs_a = row_arrays(1, [(0, req)])
    tok_a = np.asarray(sample_rows(
        jnp.asarray(row[None, :]), *map(jnp.asarray, arrs_a),
        jnp.asarray(np.array([position], np.int32))))[0]
    # batch B: same request in `slot` among nrows random companions
    comps = [Request(2000 + i, [1], 8,
                     SamplingParams(temperature=1.0, seed=i))
             for i in range(nrows)]
    pairs = [(i, comps[i]) for i in range(nrows) if i != slot]
    pairs.append((slot, req))
    logits_b = rng.normal(size=(nrows, V)).astype(np.float32)
    logits_b[slot] = row
    positions = rng.integers(0, 50, size=nrows).astype(np.int32)
    positions[slot] = position
    arrs_b = row_arrays(nrows, pairs)
    tok_b = np.asarray(sample_rows(
        jnp.asarray(logits_b), *map(jnp.asarray, arrs_b),
        jnp.asarray(positions)))[slot]
    assert int(tok_a) == int(tok_b)


# ------------------------------------------------------ warp_probs satellite
def _warp_probs_fullsort(logits, sampling):
    """The pre-PR-9 reference: full stable vocab sort in the nucleus."""
    logits = np.asarray(logits, np.float64).reshape(-1)
    if sampling.temperature <= 0.0:
        return None
    z = logits / sampling.temperature
    if sampling.top_k:
        kth = np.partition(z, -sampling.top_k)[-sampling.top_k]
        z = np.where(z < kth, -np.inf, z)
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    if sampling.top_p < 1.0:
        order = np.argsort(-p, kind="stable")
        csum = np.cumsum(p[order])
        cut = int(np.searchsorted(csum, sampling.top_p) + 1)
        mask = np.zeros_like(p, bool)
        mask[order[:cut]] = True
        p = np.where(mask, p, 0.0)
        p /= p.sum()
    return p


@pytest.mark.parametrize("top_p", [0.05, 0.5, 0.9, 0.999])
@pytest.mark.parametrize("shape", ["peaked", "flat", "ties"])
def test_warp_probs_partial_selection_bitwise(top_p, shape):
    """The argpartition nucleus must reproduce the full-sort warp
    BITWISE — including heavy ties (stable original-index ordering) and
    flat distributions (the doubling loop's worst case), and for vocabs
    on both sides of the 64-candidate seed."""
    rng = np.random.default_rng(42)
    for V in (17, 63, 64, 65, 500, 4096):
        if shape == "peaked":
            logits = (rng.normal(size=V) * 4).astype(np.float64)
        elif shape == "flat":
            logits = np.zeros(V) + rng.normal(size=V) * 1e-9
        else:
            logits = np.round(rng.normal(size=V) * 2)  # many exact ties
        sp = SamplingParams(temperature=0.8, top_p=top_p, seed=0)
        got = warp_probs(logits, sp)
        want = _warp_probs_fullsort(logits, sp)
        assert np.array_equal(got, want), (V, shape, top_p)
        # and the downstream draw is unchanged for the same stream
        req = Request(1, [1], 4, sp)
        r1 = req.rng_for(0)
        r2 = req.rng_for(0)
        assert int(r1.choice(got.size, p=got)) == \
            int(r2.choice(want.size, p=want))


def test_warp_probs_top_k_still_partial_and_exact():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=300)
    sp = SamplingParams(temperature=1.0, top_k=10, top_p=0.7, seed=0)
    got = warp_probs(logits, sp)
    want = _warp_probs_fullsort(logits, sp)
    assert np.array_equal(got, want)
    assert np.count_nonzero(got) <= 10


# ------------------------------------------------------- greedy_window unit
def test_greedy_window_equals_spec_window():
    rng = np.random.default_rng(9)
    sp = SamplingParams()  # greedy
    for _ in range(30):
        k = int(rng.integers(0, 5))
        V = 19
        target = rng.normal(size=(k + 1, V)).astype(np.float32)
        tops = np.argmax(np.asarray(target, np.float64), axis=-1)
        # mix of agreeing and disagreeing drafts
        drafts = [int(tops[j]) if rng.random() < 0.6
                  else int(rng.integers(0, V)) for j in range(k)]
        req = Request(0, [1], 64, sp)
        want = spec_window(drafts, target, sp, req.rng_for, base_pos=0)
        got = greedy_window(drafts, tops)
        assert got == want


# ----------------------------------------------------- engine-level parity
@pytest.mark.parametrize("arch", ["glm4-9b", "hymba-1.5b", "rwkv6-1.6b"])
def test_device_vs_host_greedy_parity_all_families(arch):
    cfg, model, sparams = _served(arch)
    prompts = [_prompt(cfg, 3 + 2 * s, seed=s) for s in (1, 2, 3)]
    gens = [4, 5, 6]
    want, _ = _serve(model, sparams, prompts, gens,
                     sample_device=False, pipeline=False)
    got, eng = _serve(model, sparams, prompts, gens)
    assert got == want
    m = eng.metrics()
    assert m["pipeline"]["enabled"]
    assert m["sampler"]["device"] and m["sampler"]["fallbacks"] == 0


def test_device_parity_under_preemption(glm4):
    """A pool too small for all rows forces preempt-and-requeue; replay
    + device greedy must still match the host path token-for-token."""
    cfg, model, sparams, fns = glm4
    # shared prompt: the prefix trie makes admission cheap for all three,
    # then decode growth (3 -> 5 blocks each) outruns the 11-block pool —
    # same geometry as test_prefix_cache's preemption gate
    P = _prompt(cfg, 8, seed=40)
    prompts = [P, P, P]
    gens = [12, 12, 12]
    kw = dict(num_blocks=11, num_slots=3, max_len=20, **fns)
    want, weng = _serve(model, sparams, prompts, gens,
                        sample_device=False, pipeline=False, **kw)
    got, eng = _serve(model, sparams, prompts, gens, **kw)
    assert got == want
    assert eng.scheduler.preemptions > 0  # the scenario actually bites


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_device_parity_quantized_kv(glm4, kv_bits):
    cfg, model, sparams, fns = glm4
    prompts = [_prompt(cfg, 5, seed=60 + s) for s in range(2)]
    kw = dict(kv_bits=kv_bits, num_slots=2, **fns)
    want, _ = _serve(model, sparams, prompts, [6, 6],
                     sample_device=False, pipeline=False, **kw)
    got, _ = _serve(model, sparams, prompts, [6, 6], **kw)
    assert got == want


def test_device_parity_through_spec_windows(glm4):
    """Greedy spec with the accepted-token-vector fast path must equal
    both the host-sampling spec engine and plain non-spec decode."""
    cfg, model, sparams, fns = glm4
    verify_fn = make_verify_chunk(model, donate=False)
    prompts = [_prompt(cfg, 5, seed=70 + s) for s in range(2)]
    gens = [8, 8]
    spec = SpecConfig(k=3, draft_bits=4)
    kw = dict(num_slots=2, spec=spec, verify_fn=verify_fn, **fns)
    want, _ = _serve(model, sparams, prompts, gens,
                     sample_device=False, pipeline=False, **kw)
    got, eng = _serve(model, sparams, prompts, gens, **kw)
    plain, _ = _serve(model, sparams, prompts, gens, **fns,
                      num_slots=2)
    assert got == want == plain
    m = eng.metrics()
    assert m["sampler"]["fallbacks"] == 0  # all-greedy -> fast path
    assert m["spec"]["accepted"] > 0


def test_pipeline_invariant_and_counters(glm4):
    """pipeline=True vs pipeline=False (both device sampling) must be
    token-identical — the lookahead only moves WHEN work is dispatched —
    and the bubble/lookahead counters must cover every pipeline-on
    decode step."""
    cfg, model, sparams, fns = glm4
    prompts = [_prompt(cfg, 4, seed=80 + s) for s in range(3)]
    gens = [9, 9, 9]
    base, _ = _serve(model, sparams, prompts, gens, pipeline=False, **fns)
    piped, eng = _serve(model, sparams, prompts, gens, **fns)
    assert piped == base
    m = eng.metrics()
    assert m["pipeline"]["lookahead_steps"] > 0  # steady state engaged
    # every decode step in a pipeline-on engine either synced a
    # lookahead or counted a bubble (spec/none excluded by construction)
    assert (m["pipeline"]["lookahead_steps"] + m["pipeline"]["bubbles"]
            == m["decode_steps"])


def test_pipeline_invariant_sampled_stream(glm4):
    """temperature > 0: the device threefry stream is position-keyed, so
    pipelined and synchronous runs draw identical tokens."""
    cfg, model, sparams, fns = glm4
    sp = SamplingParams(temperature=0.9, top_p=0.9, seed=123)
    prompts = [_prompt(cfg, 4, seed=90 + s) for s in range(2)]

    def run(pipeline):
        eng = ServeEngine(model, sparams, cache="paged", num_slots=2,
                          max_len=32, block_size=4, prefill_chunk=4,
                          pipeline=pipeline, **fns)
        rids = [eng.submit(p, max_new_tokens=7, sampling=sp)
                for p in prompts]
        eng.run_until_drained()
        return [eng.output(r) for r in rids]

    assert run(True) == run(False)


def test_mid_run_submission_breaks_pipeline_cleanly(glm4):
    """A request arriving while the loop is pipelining must be admitted
    (within two steps: the inflight syncs, then admission runs) and the
    final outputs must match a fully synchronous run."""
    cfg, model, sparams, fns = glm4

    def run(pipeline):
        eng = ServeEngine(model, sparams, cache="paged", num_slots=3,
                          max_len=32, block_size=4, prefill_chunk=4,
                          pipeline=pipeline, **fns)
        r0 = eng.submit(_prompt(cfg, 4, seed=7), max_new_tokens=10)
        outs = {}
        for i in range(6):
            eng.step()
        r1 = eng.submit(_prompt(cfg, 5, seed=8), max_new_tokens=6)
        eng.run_until_drained()
        outs[0], outs[1] = eng.output(r0), eng.output(r1)
        return outs

    assert run(True) == run(False)
