"""Observability gates: registry instruments + concurrent-snapshot
consistency (hypothesis), windowed percentile exactness, tracer span
balance under exceptions / preemption / spec rejection on a REAL engine,
Chrome-trace schema validation, near-zero disabled cost, engine
``metrics()`` key compatibility, and structured-log rate limiting."""
import io
import json
import threading

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: skip ONLY property tests
    import types

    st = types.SimpleNamespace(integers=lambda *a, **k: None,
                               lists=lambda *a, **k: None,
                               floats=lambda *a, **k: None)

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.obs import Counter, Gauge, Histogram, Registry, run_provenance
from repro.obs.log import StructuredLogger, configure, json_mode
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Tracer

RNG = jax.random.PRNGKey(0)


# ------------------------------------------------------------- instruments
def test_counter_gauge_basics():
    reg = Registry()
    c = reg.counter("c", unit="tok")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(7)
    g.add(-2)
    assert g.value == 5.0
    # same name -> same instrument (independent call sites share a series)
    assert reg.counter("c") is c
    snap = reg.snapshot()
    assert snap["c"] == {"type": "counter", "unit": "tok", "value": 5.0}
    assert snap["g"]["value"] == 5.0


def test_registry_kind_collision_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_histogram_bucket_counts_and_snapshot():
    h = Histogram("h", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 5 and s["sum"] == pytest.approx(56.05)
    assert s["min"] == 0.05 and s["max"] == 50.0
    assert s["buckets"] == {"0.1": 1, "1.0": 2, "10.0": 1, "+inf": 1}
    json.dumps(s)  # snapshot must be JSON-safe as-is
    # bucket-interpolated percentiles stay inside the data range
    assert 0.05 <= h.percentile(50) <= 50.0
    assert h.percentile(99) >= h.percentile(50)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=1e-6, max_value=10.0,
                          allow_nan=False), min_size=1, max_size=60),
       st.integers(min_value=1, max_value=20))
def test_windowed_percentile_is_exact_np_percentile(values, window):
    """The ``metrics_window`` contract: with ``window=N`` the histogram's
    percentile is EXACTLY np.percentile over the last N observations —
    what the serve engine's latency deques always reported."""
    h = Histogram("h", window=window)
    for v in values:
        h.observe(v)
    tail = np.asarray(values[-window:])
    for q in (50, 90, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(tail, q)))
    assert h.window_sum() == pytest.approx(float(tail.sum()))
    assert h.window_mean() == pytest.approx(float(tail.mean()))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=20, max_value=100))
def test_snapshot_consistent_under_concurrent_writers(threads, per_thread):
    """Evaluator-pool regime: writer threads hammer shared instruments
    while a reader snapshots.  Every mid-flight snapshot must be
    JSON-safe and monotone (counters never regress), and the final
    snapshot must account for every observation exactly."""
    reg = Registry()
    c = reg.counter("n")
    h = reg.histogram("lat", window=8)
    stop = threading.Event()
    seen = []

    def writer():
        for i in range(per_thread):
            c.inc()
            h.observe(0.001 * (i + 1))

    def reader():
        while not stop.is_set():
            seen.append(reg.snapshot()["n"]["value"])

    ws = [threading.Thread(target=writer) for _ in range(threads)]
    rd = threading.Thread(target=reader)
    rd.start()
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    rd.join()
    assert all(a <= b for a, b in zip(seen, seen[1:]))  # monotone reads
    final = reg.snapshot()
    json.dumps(final)
    assert final["n"]["value"] == threads * per_thread
    assert final["lat"]["count"] == threads * per_thread
    assert len(h.samples()) == min(8, threads * per_thread)


def test_run_provenance_is_json_safe_and_complete():
    prov = run_provenance()
    for key in ("git_sha", "git_dirty", "timestamp_utc", "python",
                "jax", "device_count", "device_platform"):
        assert key in prov
    assert json.loads(json.dumps(prov)) == prov


# ------------------------------------------------------------------ tracer
def test_disabled_tracer_is_free_and_silent():
    tr = Tracer(enabled=False)
    assert tr.span("a") is NULL_SPAN          # shared no-op, no allocation
    assert tr.span("b", x=1) is tr.span("c")  # same singleton every call
    with tr.span("a"):
        tr.instant("i")
        tr.complete("c", start=0.0, dur=1.0)
    assert tr.num_events == 0 and tr.dropped == 0
    assert NULL_TRACER.span("x") is NULL_SPAN


def test_span_balance_survives_exceptions():
    """``__exit__`` records the span even when the body raises — the
    error path (preemption, rejected window, failed admission) can never
    leave a dangling open span, and the exception type is attached."""
    tr = Tracer(enabled=True)
    with pytest.raises(RuntimeError):
        with tr.span("outer", step=1):
            with tr.span("inner"):
                raise RuntimeError("boom")
    assert tr.depth() == 0
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    assert all(e["args"]["error"] == "RuntimeError" for e in evs)
    assert all(e["dur_s"] >= 0 for e in evs)


def test_span_set_args_and_nesting_depth():
    tr = Tracer(enabled=True)
    with tr.span("a") as sp:
        assert tr.depth() == 1
        with tr.span("b"):
            assert tr.depth() == 2
        sp.set(tokens=3, mode="spec")
    assert tr.depth() == 0
    a = tr.events("a")[0]
    assert a["args"] == {"tokens": 3, "mode": "spec"}


def test_ring_bound_and_dropped_count():
    tr = Tracer(capacity=8, enabled=True)
    for i in range(20):
        tr.instant("tick", i=i)
    assert tr.num_events == 8
    assert tr.dropped == 12
    # the ring keeps the NEWEST events
    assert [e["args"]["i"] for e in tr.events()] == list(range(12, 20))
    tr.clear()
    assert tr.num_events == 0 and tr.dropped == 0


def test_complete_retro_dates_and_clamps():
    tr = Tracer(enabled=True)
    tr.complete("queue.wait", start=0.5, dur=0.25, request=3)
    tr.complete("neg", start=1.0, dur=-0.1)   # clock skew clamps to 0
    ev = tr.events("queue.wait")[0]
    assert ev["dur_s"] == pytest.approx(0.25)
    assert ev["args"]["request"] == 3
    assert tr.events("neg")[0]["dur_s"] == 0.0


def _validate_chrome(doc):
    """Chrome-trace schema: what ui.perfetto.dev actually requires."""
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i", "M")
        assert isinstance(ev["name"], str) and ev["name"]
        assert ev["pid"] == 1 and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and ev["ts"] >= 0
        elif ev["ph"] == "i":
            assert ev["s"] == "t"
        else:
            assert ev["name"] == "thread_name"
    assert json.loads(json.dumps(doc)) == doc  # round-trip stable


def test_chrome_export_schema_and_thread_names(tmp_path):
    tr = Tracer(enabled=True)
    tr.name_thread("serve-loop")
    with tr.span("decode.step", step=0, arr=np.int64(7)):
        tr.instant("preempt", request=np.int32(1))
    doc = tr.to_chrome()
    _validate_chrome(doc)
    # numpy args were coerced to plain JSON scalars
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["decode.step"]["args"]["arr"] == 7
    assert by_name["preempt"]["args"]["request"] == 1
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert meta and meta[0]["args"]["name"] == "serve-loop"
    path = tmp_path / "trace.json"
    tr.save(str(path))
    assert json.load(open(path)) == doc


# ------------------------------------------------- engine span balance
@pytest.fixture(scope="module")
def glm4():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.quant.qat import policy_for
    from repro.train.serve import (
        make_chunked_prefill,
        make_decode_step,
        make_verify_chunk,
        quantize_for_serving,
    )

    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(RNG),
                                   policy_for(model, default_bits=4))
    fns = {"prefill_fn": make_chunked_prefill(model, donate=False),
           "decode_fn": make_decode_step(model, donate=False),
           "verify_fn": make_verify_chunk(model, donate=False)}
    return cfg, model, sparams, fns


def _prompt(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def test_engine_spans_balance_under_preemption_and_spec(glm4):
    """A scarce-pool speculative run — forced preemption, near-zero
    acceptance (2-bit draft on random weights), replay — must leave the
    tracer balanced, with every lifecycle span present and a schema-valid
    Chrome export.  This is the adversarial regime for span leaks."""
    from repro.serve import ServeEngine
    from repro.spec import SpecConfig

    cfg, model, sparams, fns = glm4
    tr = Tracer(enabled=True)
    tr.name_thread("serve-loop")
    eng = ServeEngine(model, sparams, num_slots=4, max_len=16, cache="paged",
                      block_size=4, num_blocks=9, prefill_chunk=4,
                      spec=SpecConfig(k=3, draft_bits=2), tracer=tr,
                      **fns)
    rids = [eng.submit(_prompt(cfg, 4, seed=s), max_new_tokens=8)
            for s in range(4)]
    eng.run_until_drained()
    assert all(len(eng.output(r)) == 8 for r in rids)

    m = eng.metrics()
    assert m["preemptions"] > 0                   # pressure was real
    assert m["spec"]["windows"] > 0
    assert m["spec"]["proposed"] > m["spec"]["accepted"]  # rejections hit
    assert tr.depth() == 0                        # balanced by construction
    names = {e["name"] for e in tr.events()}
    for want in ("queue.wait", "admit", "prefill.chunk", "decode.step",
                 "decode.device", "decode.host", "spec.draft",
                 "spec.verify", "spec.resolve", "preempt"):
        assert want in names, want
    assert all(e["dur_s"] >= 0 for e in tr.events())
    # preempted requests re-queue: their second wait is its own sample
    requeued = [e for e in tr.events("queue.wait")
                if e["args"].get("requeued")]
    assert requeued
    _validate_chrome(tr.to_chrome())


def test_engine_spans_balance_on_admission_failure(glm4):
    """A prompt whose first chunk cannot fit keeps failing admission;
    blocked-admission attempts are counted and no span leaks."""
    from repro.serve import ServeEngine

    cfg, model, sparams, fns = glm4
    fns = {k: fns[k] for k in ("prefill_fn", "decode_fn")}
    tr = Tracer(enabled=True)
    eng = ServeEngine(model, sparams, num_slots=2, max_len=16, cache="paged",
                      block_size=4, num_blocks=9, prefill_chunk=4,
                      tracer=tr, **fns)
    big = eng.submit(_prompt(cfg, 12, seed=0), max_new_tokens=3)
    small = eng.submit(_prompt(cfg, 4, seed=1), max_new_tokens=8)
    eng.run_until_drained()
    assert len(eng.output(big)) == 3 and len(eng.output(small)) == 8
    assert eng.obs.get("sched.admitted").value >= 2
    assert tr.depth() == 0
    assert eng.pool.num_free_blocks == eng.pool.num_blocks - 1  # no leak
    _validate_chrome(tr.to_chrome())


def test_engine_metrics_keys_unchanged(glm4):
    """The registry rebuild of ``metrics()`` is key-compatible with the
    pre-registry dict (downstream benchmarks parse these), plus the new
    observability keys."""
    from repro.serve import ServeEngine

    cfg, model, sparams, fns = glm4
    fns = {k: fns[k] for k in ("prefill_fn", "decode_fn")}
    eng = ServeEngine(model, sparams, num_slots=2, max_len=16, cache="paged",
                      block_size=4, prefill_chunk=4, **fns)
    rid = eng.submit(_prompt(cfg, 4, seed=0), max_new_tokens=4)
    eng.run_until_drained()
    assert len(eng.output(rid)) == 4
    m = eng.metrics()
    legacy = {"steps", "decode_steps", "tokens_total", "tokens_per_s",
              "mean_occupancy", "num_slots", "cache", "preemptions",
              "requests", "mean_block_occupancy", "block_size",
              "num_blocks", "prefill_launches", "prefix_hit_rate",
              "blocks_shared", "prefix_cache", "decode_step_p50_ms",
              "decode_step_p99_ms", "decode_tok_p50_ms"}
    assert legacy <= set(m), legacy - set(m)
    # new: raw prefix counters (satellite: hit-RATE ambiguity fix),
    # recompile count, device/host split, queue wait
    for key in ("prefix_hits", "prefix_lookups", "recompiles",
                "decode_device_p50_ms", "decode_host_p50_ms",
                "queue_wait_p50_ms"):
        assert key in m, key
    assert m["recompiles"] == 0          # shared pre-warmed executables
    assert m["prefix_lookups"] >= m["prefix_hits"] >= 0
    json.dumps(m)                        # the whole dict is JSON-safe


# ----------------------------------------------------------------- logging
def test_structured_log_rate_limit_and_suppressed_count():
    out = io.StringIO()
    lg = StructuredLogger("t", min_interval_s=60.0, stream=out)
    assert lg.event("episode", reward=1.0)           # first always lands
    assert not lg.event("episode", reward=2.0)       # inside the interval
    assert not lg.event("episode", reward=3.0)
    assert lg.event("other", x=1)                    # per-event budgets
    assert lg.event("episode", reward=4.0, force=True)
    lines = out.getvalue().strip().splitlines()
    assert len(lines) == 3
    assert "suppressed=2" in lines[-1]               # drops are reported
    assert lg.emitted == 3


def test_structured_log_json_mode_round_trips():
    out = io.StringIO()
    lg = StructuredLogger("search", stream=out)
    configure(json_mode=True)
    try:
        assert json_mode()
        lg.event("episode", episode=3, reward=0.75, quant=np.float64(0.5))
        rec = json.loads(out.getvalue())
        assert rec["logger"] == "search" and rec["event"] == "episode"
        assert rec["episode"] == 3 and rec["reward"] == 0.75
    finally:
        configure(json_mode=False)
    lg.event("episode", episode=4, reward=0.8125)
    text = out.getvalue().strip().splitlines()[-1]
    assert text.startswith("[search] episode ")
    assert "episode=4" in text and "reward=0.8125" in text
