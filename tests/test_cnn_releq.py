"""Paper-faithful CNN substrate + a short end-to-end ReLeQ search."""
import numpy as np
import pytest

from repro.cnn import CNNTask
from repro.core.admm_baseline import admm_select
from repro.core.pareto import distance_to_frontier, enumerate_space, pareto_frontier


@pytest.fixture(scope="module")
def lenet_task():
    task = CNNTask("lenet", seed=0)
    task.pretrain(250)
    return task


def test_pretrain_reaches_accuracy(lenet_task):
    assert lenet_task.fp_acc > 0.8


def test_quantization_sensitivity_monotone(lenet_task):
    rels = [lenet_task.evaluate_bits({n: b for n in lenet_task.names},
                                     retrain_steps=2) for b in (8, 4, 2)]
    assert rels[0] > rels[1] > rels[2]
    assert rels[0] > 0.9


def test_finetune_recovers_accuracy(lenet_task):
    """Longer retrain must recover more accuracy at 3 bits — the dynamics
    ReLeQ's short-retrain proxy relies on."""
    bits = {n: 3 for n in lenet_task.names}
    short = lenet_task.evaluate_bits(bits, retrain_steps=1)
    long = lenet_task.long_retrain(bits, steps=60)
    assert long >= short - 0.02


@pytest.mark.slow
def test_releq_search_end_to_end(lenet_task):
    """A short ReLeQ run must (a) quantize below 8 bits on average and
    (b) keep relative accuracy high — Table 2's qualitative claim."""
    from repro.core.search import ReLeQSearch

    factory = lenet_task.make_env_factory(retrain_steps=2)
    search = ReLeQSearch(factory, num_envs=1, seed=0)
    res = search.run(episodes=25)
    assert res.best_bits
    avg = np.mean([res.best_bits[n] for n in lenet_task.names])
    assert avg < 8.0
    rel = lenet_task.long_retrain(res.best_bits, steps=80)
    assert rel > 0.9


def test_admm_respects_budget(lenet_task):
    bits = admm_select(lenet_task.groups, lenet_task.weights_by_name(),
                       budget_avg_bits=4.0)
    w = {g.name: g.n_weights for g in lenet_task.groups}
    avg = sum(w[n] * b for n, b in bits.items()) / sum(w.values())
    assert avg <= 4.0 + 1e-6
    assert set(bits) == set(lenet_task.names)


def test_pareto_enumeration_and_frontier(lenet_task):
    """Enumerate a coarse LeNet space; ReLeQ-style uniform points must lie
    near the frontier."""
    pts = enumerate_space(lenet_task.groups,
                          lambda b: lenet_task.evaluate_bits(b, retrain_steps=0),
                          bitset=(2, 4, 8))
    assert len(pts) == 3 ** 4
    front = pareto_frontier(pts)
    assert 1 <= len(front) <= len(pts)
    accs = [p["acc"] for p in front]
    quants = [p["quant"] for p in front]
    assert accs == sorted(accs)      # frontier sorted by construction
    assert quants == sorted(quants)
    best = max(pts, key=lambda p: p["acc"] - p["quant"])
    assert distance_to_frontier(best, front) < 0.2
