"""ReLeQ core: reward shaping, env mechanics, GAE, PPO convergence."""
import numpy as np
import pytest

from repro.core.costmodel import (
    energy_reduction_vs_8bit, speedup_vs_8bit, state_of_quantization,
    stripes_time, tpu_decode_time, tvm_cpu_time,
)
from repro.core.env import QuantEnv
from repro.core.ppo import PPOConfig, gae_advantages
from repro.core.reward import reward_difference, reward_proposed, reward_ratio
from repro.core.search import ReLeQSearch
from repro.models.model import QuantGroup

GROUPS = [QuantGroup(f"L{i}", ("blocks",), i, (64, 64), 64 * 64, 64 * 64 * 50)
          for i in range(4)]


class TestReward:
    def test_threshold_penalty(self):
        assert reward_proposed(0.39, 0.5) == -1.0
        assert reward_proposed(0.41, 0.5) > -1.0

    def test_asymmetry_equal_trade_is_net_negative(self):
        """The paper's asymmetry: trading ε of relative accuracy for the
        same ε of quantization benefit never pays — accuracy has priority.
        (Pointwise gradient dominance is intentionally NOT required: a=0.2
        makes the quant gradient steepen toward the optimum, §2.6.)"""
        eps = 0.05
        for acc in (0.92, 0.97, 1.0):
            for q in (0.35, 0.5, 0.8):
                keep = reward_proposed(acc, q)
                trade = reward_proposed(acc - eps, q - eps)
                assert trade < keep, (acc, q, trade, keep)

    def test_monotone(self):
        assert reward_proposed(1.0, 0.3) > reward_proposed(0.9, 0.3)
        assert reward_proposed(1.0, 0.3) > reward_proposed(1.0, 0.6)

    def test_alternatives(self):
        assert reward_ratio(0.9, 0.45) == pytest.approx(2.0)
        assert reward_difference(0.9, 0.4) == pytest.approx(0.5)


class TestCostModel:
    def test_sq_formula_hand_computed(self):
        g = [QuantGroup("a", ("a",), None, (2, 2), 4, 40),
             QuantGroup("b", ("b",), None, (2, 2), 4, 40)]
        # cost_l = n_w*120 + n_mac = 4*120 + 40 = 520 each
        sq = state_of_quantization([4, 8], g)
        assert sq == pytest.approx((520 * 4 + 520 * 8) / (520 * 8 * 2))

    def test_sq_bounds(self):
        assert state_of_quantization([8] * 4, GROUPS) == pytest.approx(1.0)
        assert 0 < state_of_quantization([2] * 4, GROUPS) < 1

    def test_speedups(self):
        bits = [4] * 4
        assert speedup_vs_8bit(stripes_time, bits, GROUPS) == pytest.approx(2.0)
        assert speedup_vs_8bit(tvm_cpu_time, bits, GROUPS) == pytest.approx(2.0)
        # decode at batch 1 is HBM-bound: time ∝ bits -> 2×
        assert speedup_vs_8bit(tpu_decode_time, bits, GROUPS) == pytest.approx(
            2.0, rel=0.05)
        assert energy_reduction_vs_8bit(bits, GROUPS) == pytest.approx(2.0)


class TestEnv:
    def test_episode_walk_and_reward(self):
        env = QuantEnv(groups=GROUPS, evaluate=lambda bits: 0.9,
                       weight_std={g.name: 0.5 for g in GROUPS})
        obs = env.reset()
        assert obs.shape == (6,)
        total_done = False
        for t in range(env.T):
            obs, r, done, info = env.step(0)  # pick 2 bits everywhere
            total_done = done
        assert total_done
        assert info["bits"] == {g.name: 2 for g in GROUPS}
        assert info["quant"] == pytest.approx(2 / 8)

    def test_frozen_groups_not_stepped(self):
        env = QuantEnv(groups=GROUPS, evaluate=lambda b: 1.0,
                       weight_std={g.name: 0.1 for g in GROUPS},
                       frozen={"L0": 8})
        assert env.T == 3
        for t in range(env.T):
            _, _, done, info = env.step(0)
        assert info["bits"]["L0"] == 8


class TestSearchResult:
    def test_average_bits_none_vs_empty(self):
        """Regression: an explicit empty selection used to silently mean
        "all groups" (`searchable_only or list(...)`); None and [] are
        distinct now."""
        from repro.core.search import SearchResult

        res = SearchResult(best_bits={"L0": 2, "L1": 4, "L2": 6}, best_reward=0.0)
        assert res.average_bits() == pytest.approx(4.0)
        assert res.average_bits(None) == pytest.approx(4.0)
        assert res.average_bits(["L0"]) == pytest.approx(2.0)
        assert res.average_bits(("L1", "L2")) == pytest.approx(5.0)
        with pytest.raises(ValueError):
            res.average_bits([])


class TestGAE:
    def test_matches_bruteforce(self):
        rng = np.random.default_rng(0)
        r = rng.normal(size=(2, 5)).astype(np.float32)
        v = rng.normal(size=(2, 5)).astype(np.float32)
        gamma, lam = 0.9, 0.8
        adv, ret = gae_advantages(r, v, gamma, lam)
        # brute force for batch 0
        for b in range(2):
            for t in range(5):
                acc, coef = 0.0, 1.0
                for i in range(t, 5):
                    nv = v[b, i + 1] if i + 1 < 5 else 0.0
                    delta = r[b, i] + gamma * nv - v[b, i]
                    acc += coef * delta
                    coef *= gamma * lam
                assert adv[b, t] == pytest.approx(acc, rel=1e-4, abs=1e-5)
        np.testing.assert_allclose(ret, adv + v, rtol=1e-5)


@pytest.mark.slow
def test_ppo_learns_layer_sensitivity():
    """The agent must learn that layer 2 needs high bits, others don't."""
    sens = [2.0, 2.0, 6.0, 2.5]

    def evaluate(bits):
        acc = 1.0
        for i, g in enumerate(GROUPS):
            acc *= 1.0 / (1.0 + np.exp(-(bits[g.name] - sens[i]) * 2.2))
        return acc

    def factory(i):
        return QuantEnv(groups=GROUPS, evaluate=evaluate,
                        weight_std={g.name: 0.5 for g in GROUPS})

    search = ReLeQSearch(factory, num_envs=1, seed=0)
    res = search.run(episodes=120)
    bb = res.best_bits
    assert bb["L2"] >= 6
    assert np.mean([bb["L0"], bb["L1"], bb["L3"]]) <= 5.5


def test_lm_env_evaluate_memoized():
    """Repeated bit-vectors skip the short retrain (search.py memo-cache):
    the second evaluate of the same policy consumes no training data."""
    import jax

    from repro.configs import get_config
    from repro.core.search import make_lm_env_factory
    from repro.data import SyntheticLMData
    from repro.models import build_model

    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLMData(seed=0, global_batch=2, seq_len=16,
                           vocab=cfg.vocab_size)
    factory = make_lm_env_factory(model, params, data, finetune_steps=1)
    env = factory(0)
    bits = {g.name: 8 for g in model.quant_groups()}
    first = env.evaluate(dict(bits))
    cursor = data.state_dict()["index"]          # consumed by the retrain
    assert env.evaluate(dict(bits)) == first     # memo hit
    assert data.state_dict()["index"] == cursor  # ...without retraining
    env.evaluate({**bits, "L00.attn.wq": 4})     # different vector
    assert data.state_dict()["index"] > cursor   # -> retrains again
    # the shared cache (autotune worker pools reuse it) reports hit-rate
    stats = factory.eval_cache.stats()
    assert stats == {"entries": 2, "hits": 1, "misses": 2,
                     "hit_rate": pytest.approx(1 / 3)}
