"""Cost-model helpers: SQ metric bounds, hardware-time monotonicity in
bits, and the speedup/energy ratios the serving benchmarks report."""
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.models.model import QuantGroup


def _groups():
    """Mixed profile: a big memory-bound matrix, small compute-heavy
    ones — exercises both sides of the decode-time max()."""
    mk = lambda name, nw, nm: QuantGroup(name, (name,), None, (nw,), nw, nm)
    return [
        mk("embed", 2_000_000, 0),
        mk("wq", 500_000, 500_000 * 4096),
        mk("mlp", 1_500_000, 1_500_000 * 4096),
        mk("head", 250_000, 250_000 * 4096),
    ]


def _uniform(groups, b):
    return np.full(len(groups), float(b))


def test_state_of_quantization_bounds_and_identity():
    g = _groups()
    assert cm.state_of_quantization(_uniform(g, 8), g) == pytest.approx(1.0)
    for b in (2, 3, 5):
        sq = cm.state_of_quantization(_uniform(g, b), g)
        assert 0.0 < sq < 1.0
        assert sq == pytest.approx(b / 8.0)  # uniform policy: exact ratio
    # clamping: "fp" groups above max_bits cost the same as max_bits
    assert cm.state_of_quantization(_uniform(g, 16), g) == pytest.approx(1.0)


@pytest.mark.parametrize("time_fn,kw", [
    (cm.stripes_time, {}),
    (cm.tvm_cpu_time, {}),
    (cm.tpu_decode_time, {}),
    (cm.tpu_decode_time, {"batch": 8}),
])
def test_hardware_times_monotone_in_bits(time_fn, kw):
    g = _groups()
    times = [time_fn(_uniform(g, b), g, **kw) for b in range(2, 9)]
    assert all(t > 0 for t in times)
    assert all(a <= b for a, b in zip(times, times[1:]))  # nondecreasing


def test_tpu_decode_time_memory_vs_compute_regimes():
    g = _groups()
    # batch=1 decode is weight-traffic bound: time strictly drops with bits
    assert cm.tpu_decode_time(_uniform(g, 2), g) < cm.tpu_decode_time(
        _uniform(g, 8), g)
    # at huge batch the compute term dominates -> bits stop mattering
    huge = {"batch": 10_000_000}
    assert cm.tpu_decode_time(_uniform(g, 2), g, **huge) == pytest.approx(
        cm.tpu_decode_time(_uniform(g, 8), g, **huge))


def test_speedup_vs_8bit_ordering():
    g = _groups()
    for fn in (cm.stripes_time, cm.tvm_cpu_time, cm.tpu_decode_time):
        s2 = cm.speedup_vs_8bit(fn, _uniform(g, 2), g)
        s4 = cm.speedup_vs_8bit(fn, _uniform(g, 4), g)
        s8 = cm.speedup_vs_8bit(fn, _uniform(g, 8), g)
        assert s2 >= s4 >= s8 == pytest.approx(1.0)
        assert s2 > 1.0
    # bit-serial laws are exactly linear in weight bits
    assert cm.speedup_vs_8bit(cm.stripes_time, _uniform(g, 2), g) == \
        pytest.approx(4.0)
    assert cm.speedup_vs_8bit(cm.tvm_cpu_time, _uniform(g, 4), g) == \
        pytest.approx(2.0)


def test_speedup_heterogeneous_policy():
    g = _groups()
    bits = np.array([8.0, 2.0, 4.0, 8.0])  # boundary groups kept at 8
    s = cm.speedup_vs_8bit(cm.tpu_decode_time, bits, g)
    assert 1.0 < s < cm.speedup_vs_8bit(cm.tpu_decode_time, _uniform(g, 2), g)


def test_energy_reduction_vs_8bit():
    g = _groups()
    assert cm.energy_reduction_vs_8bit(_uniform(g, 8), g) == pytest.approx(1.0)
    r4, r2 = (cm.energy_reduction_vs_8bit(_uniform(g, b), g) for b in (4, 2))
    assert r2 > r4 > 1.0
