"""Prefix caching over the paged pool: refcounted copy-on-write block
sharing, the trie admission path, and the parity gate that a cache-hit
sequence is token-identical to a cold-start run — across model families,
with quantized KV blocks (int8 + int4 nibble-packed), under preemption,
and with speculative decoding.  Plus the refcounted-allocator property
test and the eviction/flush lifecycle."""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: skip ONLY property tests
    import types

    st = types.SimpleNamespace(integers=lambda *a, **k: None,
                               lists=lambda *a, **k: None,
                               tuples=lambda *a, **k: None)

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import PagedCachePool, ServeEngine
from repro.spec import SpecConfig
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_verify_chunk,
    quantize_for_serving,
)

RNG = jax.random.PRNGKey(0)


def _served(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(RNG),
                                   policy_for(model, default_bits=4))
    return cfg, model, sparams


@pytest.fixture(scope="module")
def glm4():
    """Shared glm4 model + one chunked-prefill/decode jit cache for the
    whole module (compile budget)."""
    cfg, model, sparams = _served("glm4-9b")
    fns = {"prefill_fn": make_chunked_prefill(model, donate=False),
           "decode_fn": make_decode_step(model, donate=False)}
    return cfg, model, sparams, fns


def _prompt(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def _serve(model, sparams, prompts, gens, *, stagger=1, num_slots=3,
           max_len=24, block_size=4, prefill_chunk=4, **kw):
    """Staggered submission (one request per ``stagger`` steps) so later
    requests see earlier requests' *published* blocks — same-step
    admissions don't.  Returns (outputs, engine, peak concurrency)."""
    eng = ServeEngine(model, sparams, num_slots=num_slots, max_len=max_len,
                      cache="paged", block_size=block_size,
                      prefill_chunk=prefill_chunk, **kw)
    rids, sub, peak = [], 0, 0
    while sub < len(prompts) or eng.scheduler.has_work():
        while sub < len(prompts) and eng.steps >= sub * stagger:
            rids.append(eng.submit(prompts[sub], max_new_tokens=gens[sub]))
            sub += 1
        eng.step()
        peak = max(peak, eng.num_running)
    return [eng.output(r) for r in rids], eng, peak


class _FakeKV:
    """Minimal model stub: 1-layer paged KV, enough for pool-level tests."""

    class cfg:
        sliding_window = None

    def init_cache(self, batch, max_len, dtype=None):
        return {"k": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                "v": jnp.zeros((1, batch, max_len, 1, 2), jnp.float32),
                "length": jnp.zeros((batch,), jnp.int32)}


# ------------------------------------------------------------ parity gates
@pytest.mark.parametrize("arch", ["glm4-9b", "hymba-1.5b", "rwkv6-1.6b"])
def test_warm_identical_to_cold_all_families(arch):
    """THE prefix-cache contract: serving the same prompt again — now hit
    in the trie — emits exactly the cold-start token stream.  On glm4
    the hit is real (shared paged KV blocks); hymba (sliding-window ring)
    and rwkv (O(1) recurrent state) must AUTO-DISABLE sharing, because
    their per-token state depends on the full history — parity then holds
    trivially and the gate pins the auto-off."""
    cfg, model, sparams = _served(arch)
    P = _prompt(cfg, 10, seed=1)
    warm, weng, _ = _serve(model, sparams, [P, P, P], [5, 5, 5])
    cold, _, _ = _serve(model, sparams, [P], [5], prefix_cache=False)
    assert warm == [cold[0]] * 3
    if arch == "glm4-9b":
        assert weng.pool.prefix_cache
        assert weng.pool.prefix_hit_tokens > 0
        assert weng.metrics()["prefix_hit_rate"] > 0
    else:
        assert not weng.pool.prefix_cache
        assert weng.pool.prefix_hit_tokens == 0


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_warm_identical_to_cold_quantized_kv(glm4, kv_bits):
    """Same parity with int8 codes and int4 nibble-packed KV blocks: the
    trie maps quantized code blocks + their k_scale/v_scale leaves; a
    hit serves the stored codes bit-for-bit."""
    cfg, model, sparams, _ = glm4
    fns = {"prefill_fn": make_chunked_prefill(model, donate=False),
           "decode_fn": make_decode_step(model, donate=False)}
    P = _prompt(cfg, 12, seed=2)
    warm, weng, _ = _serve(model, sparams, [P, P], [6, 6], kv_bits=kv_bits,
                           **fns)
    cold, _, _ = _serve(model, sparams, [P], [6], kv_bits=kv_bits,
                        prefix_cache=False, **fns)
    assert warm == [cold[0]] * 2
    assert weng.pool.prefix_hit_tokens > 0
    assert weng.pool.kv_bits is not None


def test_warm_identical_to_cold_under_preemption(glm4):
    """Scarce blocks + shared prompts: preempt-and-requeue replays reuse
    the trie and the streams still match an ample no-sharing run exactly.
    Admission (prompt + watermark) passes all three, but decode growth
    (3 -> 5 blocks each) outruns the one-block-per-seq reserve: 13
    distinct blocks wanted (5 + 4 + 4 after sharing) against 10 usable,
    so preemption genuinely fires *with shared blocks live*."""
    cfg, model, sparams, fns = glm4
    P = _prompt(cfg, 8, seed=3)
    prompts = [P, P, P]
    want, _, _ = _serve(model, sparams, prompts, [12] * 3, num_slots=3,
                        max_len=20, prefix_cache=False, **fns)
    got, eng, _ = _serve(model, sparams, prompts, [12] * 3, num_slots=3,
                         max_len=20, num_blocks=11, **fns)
    assert got == want
    assert eng.scheduler.preemptions > 0  # the scarce pool exercised it
    pool = eng.pool  # drained pool conserves: free heap + trie == usable
    assert (len(pool._free_blocks) + len(pool._cached)
            == pool.num_blocks - 1)
    assert not pool._refcount


def test_divergence_after_shared_prefix(glm4):
    """B's prompt extends A's: B maps A's full blocks then grows its own
    tail; C repeats A exactly (block-aligned full hit -> admission COW).
    All three must match their cold runs — divergence never leaks
    through a shared block."""
    cfg, model, sparams, fns = glm4
    A = _prompt(cfg, 8, seed=4)          # 2 full blocks at bs=4
    B = np.concatenate([A, _prompt(cfg, 6, seed=5)])
    warm, eng, _ = _serve(model, sparams, [A, B, A], [5, 5, 5], **fns)
    for i, p in enumerate([A, B, A]):
        cold, _, _ = _serve(model, sparams, [p], [5], prefix_cache=False,
                            **fns)
        assert warm[i] == cold[0], f"stream {i} diverged"
    assert eng.pool.cow_copies >= 1      # C's aligned full hit COW'd
    assert eng.pool.prefix_hit_tokens > 0


def test_decoded_blocks_publish_and_hit(glm4):
    """Blocks completed during DECODE (not just prefill) publish into the
    trie: B's prompt replays A's prompt + its first emitted tokens and
    must hit past A's prompt boundary."""
    cfg, model, sparams, fns = glm4
    A = _prompt(cfg, 8, seed=6)
    eng = ServeEngine(model, sparams, num_slots=3, max_len=24,
                      cache="paged", block_size=4, prefill_chunk=4, **fns)
    eng.submit(A, max_new_tokens=6)
    eng.run_until_drained()
    outs = eng.output(0)
    # A fed prompt(8) + outs[:5] (the last sampled token is never fed),
    # so blocks 1-3 (12 tokens) are published; B replays 13 of them
    B = np.concatenate([A, np.asarray(outs[:5])])
    before = eng.pool.prefix_hit_tokens
    eng.submit(B, max_new_tokens=3)
    eng.run_until_drained()
    assert eng.pool.prefix_hit_tokens - before >= 12  # hit beyond prompt
    cold, _, _ = _serve(model, sparams, [B], [3], prefix_cache=False, **fns)
    assert eng.output(1) == cold[0]


def test_spec_draft_with_prefix_sharing(glm4):
    """Speculative decoding over shared prefixes: drafts write through
    block tables holding trie-mapped blocks; reserve_for_spec COWs
    anything still shared under the window, and greedy spec output stays
    token-identical to plain decode."""
    cfg, model, sparams, fns = glm4
    P = _prompt(cfg, 9, seed=7)
    want, _, _ = _serve(model, sparams, [P, P], [6, 6], **fns)
    ver = make_verify_chunk(model, donate=False)
    got, eng, _ = _serve(model, sparams, [P, P], [6, 6], verify_fn=ver,
                         spec=SpecConfig(k=3, draft_bits=2), **fns)
    assert got == want
    assert eng.pool.prefix_hit_tokens > 0


# ------------------------------------------------ admission & concurrency
def test_admission_gate_counts_new_blocks_only(glm4):
    """A request whose prompt is trie-resident admits into a pool that
    cannot hold it cold: 7 usable blocks, A holds 4 (12-token prompt +
    first decode write), so B cold needs 4 + 1 watermark > the 3 free —
    but trie-shared it needs only 2 new blocks (admission COW + one
    fresh) + 1 watermark = exactly 3.  With sharing A and B run
    concurrently; without it B waits for A to finish."""
    cfg, model, sparams, fns = glm4
    P = _prompt(cfg, 12, seed=8)
    kw = dict(num_slots=2, max_len=16, num_blocks=8)  # 7 usable blocks
    warm, weng, peak_shared = _serve(model, sparams, [P, P], [4, 3], **kw,
                                     **fns)
    cold, _, peak_cold = _serve(model, sparams, [P, P], [4, 3],
                                prefix_cache=False, **kw, **fns)
    assert warm == cold                      # parity even under pressure
    assert peak_shared == 2, peak_shared     # B admitted while A runs
    assert peak_cold == 1, peak_cold         # cold pool can't fit both
    assert weng.scheduler.preemptions == 0   # fits, no thrash


def test_executable_pins_hold_with_sharing(glm4):
    """Prefix sharing must not mint executables: the tail prefill starts
    mid-prompt but ``start`` is data, so the ONE chunked-prefill and ONE
    decode executables hold (the COW copy compiles separately)."""
    cfg, model, sparams, _ = glm4
    prefill = make_chunked_prefill(model, donate=False)
    decode = make_decode_step(model, donate=False)
    fns = {"prefill_fn": prefill, "decode_fn": decode}
    P = _prompt(cfg, 8, seed=9)
    Q = np.concatenate([P, _prompt(cfg, 5, seed=10)])
    _, eng, _ = _serve(model, sparams, [P, Q, P], [4, 4, 4], **fns)
    assert eng.pool.prefix_hit_tokens > 0 and eng.pool.cow_copies >= 1
    assert prefill._cache_size() == 1, "prefix tails recompiled prefill"
    assert decode._cache_size() == 1, "sharing recompiled decode"


# --------------------------------------------------- pool-level lifecycle
def test_eviction_lru_leaf_first():
    """Allocation under pressure evicts refcount-0 trie blocks LRU-first
    and leaf-first; owned blocks never leave."""
    pool = PagedCachePool(_FakeKV(), 3, max_len=16, block_size=4,
                          num_blocks=7)  # 6 usable
    tok_a, tok_b = list(range(8)), list(range(100, 108))
    sa = pool.alloc_seq()
    assert pool.ensure(sa, 8)
    pool.record_tokens(sa, tok_a)
    pool.free_seq(sa)                       # chain A cached (older)
    sb = pool.alloc_seq()
    assert pool.ensure(sb, 8)
    pool.record_tokens(sb, tok_b)
    pool.free_seq(sb)                       # chain B cached (newer)
    assert pool.prefix_cached_blocks == 4 and len(pool._free_blocks) == 2
    sc = pool.alloc_seq()
    assert pool.ensure(sc, 12)              # 3 blocks: 2 free + 1 evicted
    assert pool.prefix_evictions == 1
    # the victim is chain A's LEAF (LRU chain; its root must survive so
    # the longest-prefix match still finds A's first block)
    assert len(pool._match_nodes(tok_a)) == 1
    assert len(pool._match_nodes(tok_b)) == 2
    pool.free_seq(sc)
    assert (len(pool._free_blocks) + len(pool._cached)
            == pool.num_blocks - 1)


def test_flush_prefix_cache_empties_trie():
    """flush_prefix_cache returns cached blocks to the heap, empties the
    trie, and later identical prompts are misses (stale-KV safety)."""
    pool = PagedCachePool(_FakeKV(), 2, max_len=16, block_size=4,
                          num_blocks=7)
    s0 = pool.alloc_seq()
    assert pool.ensure(s0, 8)
    pool.record_tokens(s0, list(range(8)))
    pool.free_seq(s0)
    assert pool.prefix_cached_blocks == 2
    pool.flush_prefix_cache()
    assert pool.prefix_cached_blocks == 0
    assert not pool._root.children and not pool._node_of
    assert len(pool._free_blocks) == pool.num_blocks - 1
    assert pool.map_shared(pool.alloc_seq(), list(range(8))) == 0  # miss


def test_hot_swap_flushes_engine_trie(glm4):
    """autotune.deploy.hot_swap drops the trie: post-swap requests must
    never hit KV blocks computed under the old weight policy."""
    from repro.autotune.deploy import hot_swap

    cfg, model, sparams, fns = glm4
    P = _prompt(cfg, 8, seed=11)
    eng = ServeEngine(model, sparams, num_slots=2, max_len=16,
                      cache="paged", block_size=4, prefill_chunk=4, **fns)
    eng.submit(P, max_new_tokens=3)
    eng.run_until_drained()
    assert eng.pool.prefix_cached_blocks > 0
    report = hot_swap(eng, sparams)
    assert report["prefix_cache_flushed"]
    assert eng.pool.prefix_cached_blocks == 0
    assert not eng.pool._root.children


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_cow_preserves_block_contents_bitwise(kv_bits):
    """COW must copy codes AND scale leaves bit-for-bit — int8 codes,
    int4 nibble-packed uint8, and the f32 k_scale/v_scale riders."""
    pool = PagedCachePool(_FakeKV(), 2, max_len=8, block_size=4,
                          num_blocks=4, kv_bits=kv_bits)
    s0 = pool.alloc_seq()
    assert pool.ensure(s0, 8)
    toks = list(range(8))
    pool.record_tokens(s0, toks)            # publish both blocks
    rng = np.random.default_rng(0)
    for key in pool.paged_keys:             # k, v, k_scale, v_scale
        leaf = pool.cache[key]
        pat = rng.integers(1, 100, leaf.shape).astype(leaf.dtype)
        pool.cache[key] = jnp.asarray(pat)
    s1 = pool.alloc_seq()
    old = list(pool._seq_blocks[s0])
    cached = pool.map_shared(s1, toks)      # aligned full hit -> COW
    assert cached == 7 and pool.cow_copies == 1
    new = pool._seq_blocks[s1]
    assert new[0] == old[0] and new[1] != old[1]
    for key in pool.paged_keys:
        got = np.asarray(pool.cache[key][:, new[1]])
        want = np.asarray(pool.cache[key][:, old[1]])
        np.testing.assert_array_equal(got, want, err_msg=key)
    # both copies now privately owned: the write path touches only s1's
    assert pool._refcount[old[1]] == 1 and pool._refcount[new[1]] == 1


# ------------------------------------------- refcounted allocator property
@settings(max_examples=30, deadline=None)
@given(
    ops=st.lists(st.tuples(st.integers(0, 9), st.integers(0, 5)),
                 min_size=1, max_size=50),
)
def test_refcounted_allocator_invariants(ops):
    """Arbitrary share/fork/grow/free traffic: refcounts exactly mirror
    ownership (one count per owning sequence, never negative), shared
    blocks never reach the free heap, conservation holds after every op,
    and the pool drains back to its initial state."""
    pool = PagedCachePool(_FakeKV(), 3, max_len=16, block_size=4,
                          num_blocks=9)  # 8 usable
    # three token streams with shared prefixes -> real trie collisions
    streams = [list(range(16)), list(range(8)) + list(range(50, 58)),
               list(range(200, 216))]
    live: dict[int, list[int]] = {}  # seq -> its recorded tokens
    for op, arg in ops:
        if op <= 3:                            # admit (map + ensure)
            toks = streams[arg % 3][:4 + 4 * (arg % 3)]
            if not (pool.num_free and pool.can_admit(len(toks), 0, toks)):
                continue
            seq = pool.alloc_seq()
            pool.map_shared(seq, toks)
            if pool.ensure(seq, len(toks) + 1):
                pool.record_tokens(seq, toks)
                live[seq] = list(toks)
            else:                               # exhausted: roll back
                pool.free_seq(seq)
        elif op <= 5 and live:                 # grow + record one token
            seq = sorted(live)[arg % len(live)]
            if (len(live[seq]) < 16
                    and pool.ensure(seq, len(live[seq]) + 1)):
                tok = 300 + (arg * 7 + len(live[seq])) % 5  # forks streams
                pool.record_token(seq, tok)
                live[seq].append(tok)
        elif op <= 7 and live:                 # divergent write -> COW
            seq = sorted(live)[arg % len(live)]
            pool.cow_for_write(seq, max(len(live[seq]) - 1, 0))
        elif live:                             # retire
            seq = sorted(live)[arg % len(live)]
            pool.free_seq(seq)
            del live[seq]
        # ---- invariants after every op
        owned = Counter(b for s in pool._seq_blocks.values() for b in s)
        assert dict(owned) == pool._refcount   # counts mirror ownership
        assert all(c >= 1 for c in pool._refcount.values())
        assert 0 not in owned                  # garbage block never owned
        heap, cached = set(pool._free_blocks), set(pool._cached)
        assert not (heap & set(owned)) and not (cached & set(owned))
        assert not (heap & cached)
        assert (len(set(owned)) + len(heap) + len(cached)
                == pool.num_blocks - 1)        # conservation
    for seq in list(live):
        pool.free_seq(seq)
    assert not pool._refcount
    assert (len(pool._free_blocks) + len(pool._cached)
            == pool.num_blocks - 1)
    pool.flush_prefix_cache()                  # full drain: initial state
    assert len(pool._free_blocks) == pool.num_blocks - 1
    assert pool.num_free == pool.num_seqs and not pool._node_of
