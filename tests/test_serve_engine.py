"""Continuous-batching engine: slot invariants, mid-decode admission,
token-for-token parity with the legacy static greedy loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import SamplingParams, ServeEngine, SlotCachePool
from repro.train.serve import make_decode_step, make_prefill, quantize_for_serving

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    """(model, sparams, shared jit fns) at a 4-bit policy — one compile
    budget for the whole module."""
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    sparams = quantize_for_serving(model, params,
                                   policy_for(model, default_bits=4))
    fns = {"cache": "slot",  # legacy engine under test; paged: test_serve_paged.py
           "prefill_fn": make_prefill(model),
           "decode_fn": make_decode_step(model, donate=False)}
    return cfg, model, sparams, fns


def _prompt(cfg, n=8, seed=1):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def _static_loop(model, sparams, prompt, gen, max_len):
    """The legacy launch/serve.py greedy loop at batch=1."""
    logits, cache = model.prefill(sparams, tokens=jnp.asarray(prompt)[None],
                                  max_len=max_len)
    dec = make_decode_step(model, donate=False)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(gen):
        logits, cache = dec(sparams, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(int(tok[0, 0]))
    return out


# --------------------------------------------------------------- slot pool
def test_slot_pool_alloc_free_invariants(served):
    _, model, _, _ = served
    pool = SlotCachePool(model, num_slots=3, max_len=16)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2] and pool.num_free == 0
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.free(1)
    assert pool.num_free == 1 and pool.alloc() == 1  # lowest free reused
    with pytest.raises(ValueError):
        pool.free(7)          # never allocated
    pool.free(0)
    with pytest.raises(ValueError):
        pool.free(0)          # double free
    assert pool.active_slots == frozenset({1, 2})
    assert pool.occupancy() == pytest.approx(2 / 3)


def test_slot_pool_write_validates(served):
    _, model, _, _ = served
    pool = SlotCachePool(model, num_slots=2, max_len=16)
    good = model.init_cache(1, 16)
    with pytest.raises(ValueError):
        pool.write(0, good)   # slot not allocated
    slot = pool.alloc()
    with pytest.raises(ValueError):
        pool.write(slot, model.init_cache(1, 32))  # wrong cache length
    with pytest.raises(ValueError):
        pool.write(slot, model.init_cache(2, 16))  # wrong batch
    pool.write(slot, good)    # correct shapes accepted


# ------------------------------------------------------------------ parity
def test_single_request_matches_static_loop(served):
    cfg, model, sparams, fns = served
    prompt, gen = _prompt(cfg), 6
    want = _static_loop(model, sparams, prompt, gen, max_len=len(prompt) + gen + 1)
    eng = ServeEngine(model, sparams, num_slots=3,
                      max_len=len(prompt) + gen + 1, **fns)
    rid = eng.submit(prompt, max_new_tokens=gen + 1)
    eng.run_until_drained()
    assert eng.output(rid) == want


def test_single_request_parity_rwkv():
    """The slot pool is family-generic: same parity for the O(1)-state
    RWKV cache (no k/v leaves, no length bound)."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(RNG),
                                   policy_for(model, default_bits=4))
    prompt, gen = _prompt(cfg, 6), 4
    want = _static_loop(model, sparams, prompt, gen, max_len=16)
    eng = ServeEngine(model, sparams, num_slots=2, max_len=16, cache="slot")
    rid = eng.submit(prompt, max_new_tokens=gen + 1)
    eng.run_until_drained()
    assert eng.output(rid) == want


# ------------------------------------------------------- continuous batching
def test_admission_mid_decode_preserves_running(served):
    cfg, model, sparams, fns = served
    p1, p2, p3 = _prompt(cfg, 8, 1), _prompt(cfg, 8, 2), _prompt(cfg, 8, 3)

    def solo(prompt, n):
        eng = ServeEngine(model, sparams, num_slots=2, max_len=32, **fns)
        rid = eng.submit(prompt, max_new_tokens=n)
        eng.run_until_drained()
        return eng.output(rid)

    eng = ServeEngine(model, sparams, num_slots=2, max_len=32, **fns)
    r1 = eng.submit(p1, max_new_tokens=12)
    for _ in range(3):
        eng.step()
    # both slots get traffic while r1 is mid-decode; r3 must queue
    r2 = eng.submit(p2, max_new_tokens=4)
    r3 = eng.submit(p3, max_new_tokens=5)
    assert eng.num_running == 1 and eng.num_queued == 2
    eng.step()  # r2 takes the free slot, r3 keeps waiting
    assert eng.num_running == 2 and eng.num_queued == 1
    eng.run_until_drained()

    assert eng.output(r1) == solo(p1, 12)   # running seq not corrupted
    assert eng.output(r2) == solo(p2, 4)    # admitted seq clean slot
    assert eng.output(r3) == solo(p3, 5)    # queued seq reuses r2's slot
    m = {r["id"]: r for r in eng.metrics()["requests"]}
    assert m[r2]["ttft_steps"] == 0         # free slot -> admitted same step
    assert m[r3]["ttft_steps"] > 0          # had to wait for a slot


def test_queue_backpressure_and_length_bound(served):
    cfg, model, sparams, fns = served
    eng = ServeEngine(model, sparams, num_slots=1, max_len=16,
                      max_pending=2, **fns)
    with pytest.raises(ValueError):
        eng.submit(_prompt(cfg, 10), max_new_tokens=10)  # 20 > max_len 16
    eng.submit(_prompt(cfg, 4), max_new_tokens=3)
    eng.submit(_prompt(cfg, 4), max_new_tokens=3)
    with pytest.raises(RuntimeError):
        eng.submit(_prompt(cfg, 4), max_new_tokens=3)    # queue full
    eng.run_until_drained()
    assert all(r["state"] == "finished" for r in eng.metrics()["requests"])


def test_eos_frees_slot_early(served):
    cfg, model, sparams, fns = served
    prompt = _prompt(cfg)
    ref = _static_loop(model, sparams, prompt, 7, max_len=len(prompt) + 8)
    eos = ref[3]
    stop = ref.index(eos)  # ref may repeat tokens; EOS cuts at FIRST hit
    eng = ServeEngine(model, sparams, num_slots=2, max_len=32, **fns)
    rid = eng.submit(prompt, max_new_tokens=8, eos_id=eos)
    eng.run_until_drained()
    out = eng.output(rid)
    assert out == ref[:stop + 1] and out[-1] == eos
    assert eng.pool.num_free == 2             # slot released


def test_sampling_deterministic_per_seed(served):
    cfg, model, sparams, fns = served
    prompt = _prompt(cfg)

    def run(seed):
        eng = ServeEngine(model, sparams, num_slots=2, max_len=32, **fns)
        rid = eng.submit(prompt, max_new_tokens=6,
                         sampling=SamplingParams(temperature=1.0, seed=seed))
        eng.run_until_drained()
        return eng.output(rid)

    assert run(5) == run(5)


def test_metrics_aggregate(served):
    cfg, model, sparams, fns = served
    eng = ServeEngine(model, sparams, num_slots=2, max_len=32, **fns)
    for s in (1, 2, 3):
        eng.submit(_prompt(cfg, 8, s), max_new_tokens=4)
    m = eng.run_until_drained()
    assert m["tokens_total"] == 12 and m["tokens_per_s"] > 0
    assert 0.0 < m["mean_occupancy"] <= 1.0
    assert m["decode_steps"] <= m["steps"]
