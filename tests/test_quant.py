"""Quantization substrate: WRPN quantizer, bitplane packing, policy, fp8 state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: skip ONLY property tests
    import types

    st = types.SimpleNamespace(integers=lambda *a, **k: None,
                               sampled_from=lambda *a, **k: None)

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.quant.int8_opt import dequantize_state, quantize_state, QTensor
from repro.quant.pack import (
    dequant_packed, pack_bitplanes, pack_weight, packed_nbytes, unpack_bitplanes,
)
from repro.quant.policy import QuantPolicy
from repro.quant.wrpn import (
    fake_quant, fake_quant_ste, quantize_to_int, tensor_scale,
)

RNG = np.random.default_rng(0)


class TestWRPN:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 6, 7, 8])
    def test_level_count(self, bits):
        """Mid-tread: at most 2^(k-1)-1 magnitude levels each side + zero."""
        w = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
        wq = fake_quant(w, bits)
        n = max(2 ** (bits - 1) - 1, 1)
        levels = np.unique(np.round(np.asarray(wq) / float(tensor_scale(w)) * n))
        assert len(levels) <= 2 * n + 1
        assert 0.0 in np.round(levels)  # mid-tread: zero representable

    def test_idempotent(self):
        w = jnp.asarray(RNG.normal(size=(32, 32)), jnp.float32)
        q1 = fake_quant(w, 4)
        q2 = fake_quant(q1, 4)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)

    def test_error_monotone_in_bits(self):
        w = jnp.asarray(RNG.normal(size=(128, 64)), jnp.float32)
        errs = [float(jnp.mean((w - fake_quant(w, b)) ** 2)) for b in (2, 3, 4, 6, 8)]
        assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:])), errs

    def test_fp_passthrough(self):
        w = jnp.asarray(RNG.normal(size=(8, 8)), jnp.float32)
        np.testing.assert_array_equal(np.asarray(fake_quant(w, 32)), np.asarray(w))

    def test_ste_gradient_inside_clip(self):
        w = jnp.asarray(RNG.normal(size=(64,)), jnp.float32)
        g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, jnp.int32(3))))(w)
        # per-tensor scale = max|w|: all |w| <= scale -> gradient all ones
        np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(bits=st.integers(2, 8), seed=st.integers(0, 2 ** 16))
    def test_quantized_values_on_grid(self, bits, seed):
        """Property: every QDQ output is scale·i/n for integer |i| <= n."""
        w = jnp.asarray(np.random.default_rng(seed).normal(size=(41,)), jnp.float32)
        s = float(tensor_scale(w))
        n = 2 ** (bits - 1) - 1
        wq = np.asarray(fake_quant(w, bits))
        grid = np.round(wq / s * n)
        np.testing.assert_allclose(wq, grid / n * s, atol=1e-5)
        assert np.all(np.abs(grid) <= n)


class TestPack:
    @pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 7, 8])
    def test_roundtrip(self, bits):
        w = jnp.asarray(RNG.normal(size=(64, 24)), jnp.float32)
        codes, scale = quantize_to_int(w, bits, axis=0)
        packed = pack_bitplanes(codes, bits)
        assert packed.shape == (bits, 8, 24)
        back = unpack_bitplanes(packed, bits)
        np.testing.assert_array_equal(np.asarray(codes, np.int32), np.asarray(back))

    def test_bytes_scale_linearly_with_bits(self):
        for b in range(2, 9):
            assert packed_nbytes(512, 128, b) == b * 64 * 128

    @settings(max_examples=15, deadline=None)
    @given(bits=st.integers(2, 8), seed=st.integers(0, 999))
    def test_dequant_matches_fake_quant(self, bits, seed):
        """pack→dequant == per-column WRPN QDQ (no train/serve gap)."""
        w = jnp.asarray(np.random.default_rng(seed).normal(size=(16, 10)),
                        jnp.float32)
        planes, scale = pack_weight(w, bits)
        rec = dequant_packed(planes, scale, bits)
        ref = fake_quant(w, bits, scale=tensor_scale(w, axis=0), axis=0)
        np.testing.assert_allclose(np.asarray(rec), np.asarray(ref), atol=1e-5)

    def test_k_not_multiple_of_8_raises(self):
        codes, _ = quantize_to_int(jnp.ones((12, 4)), 4)
        with pytest.raises(ValueError):
            pack_bitplanes(codes, 4)


class TestPackProperties:
    """Property-based pack→unpack round-trips (the serving path's one
    lossless stage: whatever codes go onto the wire must come back
    bit-exact for every bitwidth, shape and source dtype)."""

    @settings(max_examples=60, deadline=None)
    @given(bits=st.sampled_from([2, 3, 4, 5, 8]), rows8=st.integers(1, 9),
           cols=st.integers(1, 37), seed=st.integers(0, 2 ** 16))
    def test_codes_roundtrip_bit_exact(self, bits, rows8, cols, seed):
        """Any in-range signed code tensor survives pack→unpack exactly
        (odd column counts exercise the non-tiled minor dim)."""
        n = 2 ** (bits - 1) - 1
        codes = np.random.default_rng(seed).integers(
            -n, n + 1, (rows8 * 8, cols), dtype=np.int32)
        back = unpack_bitplanes(pack_bitplanes(jnp.asarray(codes), bits), bits)
        np.testing.assert_array_equal(codes, np.asarray(back))

    @settings(max_examples=40, deadline=None)
    @given(bits=st.sampled_from([2, 3, 4, 5, 8]),
           rows=st.integers(1, 41), cols=st.integers(1, 19),
           dtype=st.sampled_from(["float32", "bfloat16", "float16"]),
           seed=st.integers(0, 999))
    def test_quantize_pack_roundtrip_odd_shapes(self, bits, rows, cols,
                                                dtype, seed):
        """Float weights at odd shapes/dtypes: pad→quantize→pack→unpack
        reproduces the quantized codes exactly, padding rows stay zero."""
        from repro.quant.pack import pad_contraction_to_8

        w = np.random.default_rng(seed).normal(size=(rows, cols))
        wp = jnp.asarray(pad_contraction_to_8(w.astype(np.float32)),
                         jnp.dtype(dtype))
        codes, _ = quantize_to_int(wp, bits, axis=0)
        back = unpack_bitplanes(pack_bitplanes(codes, bits), bits)
        np.testing.assert_array_equal(np.asarray(codes, np.int32),
                                      np.asarray(back))
        assert np.all(np.asarray(back)[rows:] == 0)  # pad rows quantize to 0


class TestPolicy:
    def test_json_roundtrip_and_frozen(self):
        pol = QuantPolicy(("a", "b", "c"), {"a": 4, "b": 2}, frozen={"c": 8})
        pol2 = QuantPolicy.from_json(pol.to_json())
        assert pol2.get("a") == 4 and pol2.get("c") == 8
        with pytest.raises(ValueError):
            pol.with_bits("c", 2)
        assert pol.searchable == ("a", "b")

    def test_as_array_order(self):
        pol = QuantPolicy(("x", "y"), {"x": 3, "y": 5})
        assert pol.as_array().tolist() == [3, 5]
        assert pol.average_bits() == 4.0


class TestFp8State:
    def test_roundtrip_small_values(self):
        """Second-moment-like tiny values must not collapse to zero."""
        v = jnp.asarray(np.abs(RNG.normal(size=(1024,))) ** 2 * 1e-6 + 1e-12,
                        jnp.float32)
        from repro.quant.int8_opt import dequantize_state_sq, quantize_state_sq

        d = dequantize_state_sq(quantize_state_sq(v))
        rel = np.asarray(jnp.abs(d - v) / (v + 1e-30))
        assert np.median(rel) < 0.15

    def test_sharding_friendly_shape(self):
        x = jnp.asarray(RNG.normal(size=(4, 8, 512)), jnp.float32)
        q = quantize_state(x)
        assert isinstance(q, QTensor)
        assert q.codes.shape == (4, 8, 2, 256)  # leading dims preserved
        d = dequantize_state(q)
        assert d.shape == x.shape
        assert float(jnp.max(jnp.abs(d - x))) / float(jnp.max(jnp.abs(x))) < 0.1
