"""repro.spec unit tests: SpecConfig validation, repack_weight low-bit
views, low_bit_view group walking (frozen groups shared by reference),
snap_params_to_grid losslessness, DraftSelector archive picks, the
rejection-sampler window resolution, per-request PRNG streams, and
engine-level EOS-mid-window emission.  (Engine parity + distribution
exactness gates live in tests/test_serve_paged.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune.archive import ParetoArchive
from repro.configs import get_config
from repro.models import build_model
from repro.quant.pack import Packed, dequant_packed, pack_weight, repack_weight
from repro.quant.qat import get_by_path, policy_for
from repro.serve import ServeEngine
from repro.serve.request import Request, SamplingParams
from repro.spec import (
    DraftSelector,
    SpecConfig,
    low_bit_view,
    snap_params_to_grid,
    spec_window,
)
from repro.train.serve import quantize_for_serving

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def glm4():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    sparams = quantize_for_serving(model, params, policy_for(model, 4))
    return cfg, model, params, sparams


# ---------------------------------------------------------------- config
def test_spec_config_validation():
    assert SpecConfig(k=2, draft_bits=2).k == 2
    with pytest.raises(ValueError):
        SpecConfig(k=0, draft_bits=2)
    with pytest.raises(ValueError):
        SpecConfig(k=4)  # no draft source at all
    with pytest.raises(ValueError):
        SpecConfig(k=4, draft_bits=9)
    with pytest.raises(ValueError):
        SpecConfig(k=4, draft_bits=1)


def test_spec_requires_paged_cache(glm4):
    cfg, model, params, sparams = glm4
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, sparams, num_slots=2, max_len=16, cache="slot",
                    spec=SpecConfig(k=2, draft_bits=2))


# ---------------------------------------------------------------- repack
def test_repack_weight_matches_direct_pack():
    """Re-packing an 8-bit Packed at 2 bits must equal packing the 8-bit
    DEQUANTIZED weights at 2 bits directly — the draft sees exactly the
    low-bit projection of what the target serves."""
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 24), jnp.float32)
    planes8, scale8 = pack_weight(w, 8)
    p8 = Packed(planes8, scale8, 8)
    p2 = repack_weight(p8, 2)
    assert p2.bits == 2 and p2.planes.shape[0] == 2
    w8 = dequant_packed(planes8, scale8, 8)
    planes2, scale2 = pack_weight(w8, 2)
    np.testing.assert_allclose(
        np.asarray(dequant_packed(p2.planes, p2.scale, 2)),
        np.asarray(dequant_packed(planes2, scale2, 2)), rtol=0, atol=0)


def test_repack_weight_noop_at_equal_or_wider():
    """Never "up-quantize": bits >= current returns the input unchanged."""
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 8), jnp.float32)
    planes, scale = pack_weight(w, 4)
    p4 = Packed(planes, scale, 4)
    assert repack_weight(p4, 4) is p4
    assert repack_weight(p4, 8) is p4


def test_repack_weight_expert_bank():
    """Expert banks (leading E axis on the planes) re-pack per expert."""
    bank = jax.random.normal(jax.random.PRNGKey(3), (3, 16, 8), jnp.float32)
    planes = jnp.stack([pack_weight(bank[e], 8)[0] for e in range(3)])
    scale = jnp.stack([pack_weight(bank[e], 8)[1] for e in range(3)])
    p2 = repack_weight(Packed(planes, scale, 8), 2)
    assert p2.planes.shape == (3, 2, 2, 8)  # (E, bits, K//8, N)
    for e in range(3):
        w8 = dequant_packed(planes[e], scale[e], 8)
        pl, sc = pack_weight(w8, 2)
        np.testing.assert_allclose(
            np.asarray(dequant_packed(p2.planes[e], p2.scale[e], 2)),
            np.asarray(dequant_packed(pl, sc, 2)))


# ----------------------------------------------------------- low_bit_view
def test_low_bit_view_repacks_searchable_keeps_frozen(glm4):
    """The draft view re-packs every searchable Packed leaf at the draft
    bits but shares frozen-at-8 groups (lm_head) BY REFERENCE — those are
    bit-identical between draft and target, which is what lets them agree
    on the readout.  The target's sparams are never mutated."""
    cfg, model, params, sparams = glm4
    frozen = model.frozen_bits()
    draft = low_bit_view(model, sparams, bits=2)
    checked_searchable = checked_frozen = 0
    for g in model.quant_groups():
        if g.path == ("lm_head",):
            assert draft["lm_head"] is sparams["lm_head"]
            checked_frozen += 1
            continue
        if g.path[0] != "blocks":
            continue
        blocks_d, blocks_t = draft["blocks"], sparams["blocks"]
        if isinstance(blocks_t[0], list):
            leaf_d = get_by_path(blocks_d[g.path[1]][g.layer], g.path[2:])
            leaf_t = get_by_path(blocks_t[g.path[1]][g.layer], g.path[2:])
        else:
            leaf_d = get_by_path(blocks_d[g.layer], g.path[1:])
            leaf_t = get_by_path(blocks_t[g.layer], g.path[1:])
        if not isinstance(leaf_t, Packed):
            continue
        if g.name in frozen:
            assert leaf_d is leaf_t
            checked_frozen += 1
        else:
            assert leaf_d.bits == 2
            assert leaf_t.bits == 4  # target untouched
            checked_searchable += 1
    assert checked_searchable > 0 and checked_frozen > 0


def test_low_bit_view_needs_a_policy(glm4):
    cfg, model, params, sparams = glm4
    with pytest.raises(ValueError):
        low_bit_view(model, sparams)


# ------------------------------------------------------------- grid snap
def test_snap_params_to_grid_makes_low_bit_pack_lossless(glm4):
    """After snapping to the 2-bit grid, pack->dequant at 2 bits
    reconstructs searchable weights exactly — so an 8-bit target and its
    2-bit re-pack agree everywhere (acceptance ~ 1, the regime the spec
    benchmark measures its mechanical speedup ceiling in)."""
    cfg, model, params, _ = glm4
    snapped = snap_params_to_grid(model, params, 2)
    frozen = model.frozen_bits()
    checked = 0
    for g in model.quant_groups():
        if g.name in frozen:
            continue
        w = np.asarray(get_by_path(snapped, g.path), np.float32)
        # stacked layouts snap each trailing-2D slice with its own scales
        for mat in w.reshape(-1, *w.shape[-2:]):
            pl, sc = pack_weight(jnp.asarray(mat), 2)
            np.testing.assert_allclose(
                np.asarray(dequant_packed(pl, sc, 2)), mat, atol=1e-6)
        checked += 1
        if checked >= 3:  # a few groups suffice; the property is per-leaf
            break
    assert checked > 0


# ---------------------------------------------------------- DraftSelector
def _archive():
    arc = ParetoArchive(objectives=("acc", "sq"))
    assert arc.add({"a": 8, "b": 8}, acc=0.99, sq=0.5)
    assert arc.add({"a": 2, "b": 4}, acc=0.97, sq=0.2)
    assert arc.add({"a": 2, "b": 2}, acc=0.90, sq=0.1)
    return arc


def test_draft_selector_picks_cheapest_above_floor():
    arc = _archive()
    sel = DraftSelector(acc_floor=0.95)
    assert {tuple(sorted(e.bits_dict().items()))
            for e in sel.candidates(arc)} == {
        (("a", 8), ("b", 8)), (("a", 2), ("b", 4))}
    assert sel.select(arc).bits_dict() == {"a": 2, "b": 4}  # cheapest


def test_draft_selector_max_avg_bits_and_empty():
    arc = _archive()
    assert DraftSelector(acc_floor=0.95, max_avg_bits=4.0).select(
        arc).bits_dict() == {"a": 2, "b": 4}
    assert DraftSelector(acc_floor=0.999).select(arc) is None
    assert DraftSelector(acc_floor=0.95, max_avg_bits=2.5).select(arc) is None


def test_draft_selector_policy_roundtrip(glm4):
    """Archive entry -> QuantPolicy aligned with the model's groups, fed
    straight into SpecConfig(draft_policy=...)."""
    cfg, model, params, sparams = glm4
    base = policy_for(model, 3)
    bits = {g.name: base.get(g.name) for g in model.quant_groups()}
    arc = ParetoArchive(objectives=("acc", "sq"))
    arc.add(bits, acc=0.99, sq=0.1)
    pol = DraftSelector(acc_floor=0.5).policy(model, arc)
    assert pol is not None
    frozen = model.frozen_bits()
    for g in model.quant_groups():
        assert pol.get(g.name) == (frozen.get(g.name, 3))
    # and it actually drives low_bit_view
    draft = low_bit_view(model, sparams, policy=pol)
    assert draft["lm_head"] is sparams["lm_head"]


# ----------------------------------------------------------- spec_window
def _rng_for(pos, kind):
    return np.random.default_rng((5, pos, kind))


def test_spec_window_greedy_identity():
    """Greedy resolution: accept while the draft matches the target
    argmax, emit the argmax at the first disagreement — never more."""
    V = 8
    rows = np.zeros((4, V))
    rows[0, 3] = rows[1, 5] = rows[2, 1] = rows[3, 6] = 10.0
    sp = SamplingParams()  # temperature 0 -> greedy
    emitted, acc = spec_window([3, 5, 2], rows, sp, _rng_for, base_pos=0)
    assert emitted == [3, 5, 1] and acc == 2  # mismatch at j=2 -> argmax
    emitted, acc = spec_window([3, 5, 1], rows, sp, _rng_for, base_pos=0)
    assert emitted == [3, 5, 1, 6] and acc == 3  # full accept -> bonus row


def test_spec_window_k0_degenerates_to_plain_decode():
    logits = np.zeros((1, 5))
    logits[0, 2] = 4.0
    emitted, acc = spec_window([], logits, SamplingParams(), _rng_for,
                               base_pos=0)
    assert emitted == [2] and acc == 0


def test_spec_window_bonus_uses_plain_decode_stream():
    """On full acceptance the bonus draw must come from the SAME stream
    plain decode would use at that position (KIND_TOKEN at base_pos + k)
    — this is what makes speculative sampling invariant to windowing."""
    V = 6
    rows = np.zeros((2, V))
    rows[0, 1] = 10.0  # near-deterministic acceptance of draft token 1
    rows[1] = np.asarray([0.5, -0.2, 1.0, 0.1, -1.0, 0.3])
    sp = SamplingParams(temperature=1.0, seed=0)
    q = np.zeros(V)
    q[1] = 1.0
    emitted, acc = spec_window([1], rows, sp, _rng_for, base_pos=4,
                               q_probs=[q])
    assert acc == 1
    from repro.serve.request import warp_probs
    from repro.spec import KIND_TOKEN

    p = warp_probs(rows[1], sp)
    want = int(_rng_for(5, KIND_TOKEN).choice(V, p=p))
    assert emitted == [1, want]


# ------------------------------------------------------------ PRNG streams
def test_request_rng_streams_deterministic_and_distinct():
    req = Request(3, np.arange(4), 8,
                  SamplingParams(temperature=1.0, seed=42))
    a = req.rng_for(2, 1).random(4)
    b = req.rng_for(2, 1).random(4)
    np.testing.assert_array_equal(a, b)          # reproducible stream
    assert not np.allclose(a, req.rng_for(2, 2).random(4))  # kind-keyed
    assert not np.allclose(a, req.rng_for(3, 1).random(4))  # position-keyed
    other = Request(4, np.arange(4), 8,
                    SamplingParams(temperature=1.0, seed=42))
    assert not np.allclose(a, other.rng_for(2, 1).random(4))  # id-keyed


# ---------------------------------------------------------- engine window
def test_spec_eos_and_budget_mid_window(glm4):
    """EOS landing INSIDE a speculative window truncates the stream at
    exactly the non-spec point; tokens past it in the same window are
    dropped, the row retires, and the pool fully drains."""
    cfg, model, params, sparams = glm4
    base = ServeEngine(model, sparams, num_slots=1, max_len=24,
                       cache="paged", block_size=4, prefill_chunk=4)
    rid = base.submit(_prompt_of(cfg, 4, 1), max_new_tokens=10)
    base.run_until_drained()
    stream = base.output(rid)
    eos = stream[2]  # make the third emitted token the EOS
    eng = ServeEngine(model, sparams, num_slots=1, max_len=24,
                      cache="paged", block_size=4, prefill_chunk=4,
                      spec=SpecConfig(k=4, draft_bits=2))
    r2 = eng.submit(_prompt_of(cfg, 4, 1), max_new_tokens=10, eos_id=eos)
    eng.run_until_drained()
    assert eng.output(r2) == stream[:3]
    assert eng.pool.num_free == eng.pool.num_slots
    assert eng.pool.num_free_blocks == eng.pool.num_blocks - 1


# ---------------------------------------------------------- draftability
def test_draftability_evaluator_measures_and_memoizes(glm4):
    """DraftabilityEvaluator times real spec engine steps (candidate as
    draft, fixed 8-bit target) and memoizes per distinct candidate."""
    from repro.autotune.workers import DraftabilityEvaluator

    cfg, model, params, _ = glm4
    ev = DraftabilityEvaluator(model, params, k=2, num_slots=2,
                               decode_steps=2, warmup_steps=1)
    bits = {n: 2 for n in ev.group_names}
    lat, ref = ev(bits)
    assert lat > 0.0 and ref > 0.0
    calls = []
    orig, ev._measure = ev._measure, lambda b: calls.append(1) or orig(b)
    assert ev(bits) == (lat, ref)
    assert not calls  # both the candidate and the 8-bit ref were cached


def _prompt_of(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))
