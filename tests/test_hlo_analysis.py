"""Loop-aware HLO analysis: flops through (nested) scans, collectives."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, roofline_from_costs

W = jnp.zeros((128, 128), jnp.float32)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_plain_matmul():
    c = _compile(lambda x, w: x @ w, W, W)
    assert analyze_hlo(c.as_text()).flops == 2 * 128 ** 3


def test_scan_multiplies_body():
    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)[0]
    c = _compile(f, W, W)
    assert analyze_hlo(c.as_text()).flops == 10 * 2 * 128 ** 3


def test_nested_scan():
    def f(x, w):
        def inner(c, _):
            return c @ w, None
        def outer(c, _):
            return jax.lax.scan(inner, c, None, length=10)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]
    c = _compile(f, W, W)
    assert analyze_hlo(c.as_text()).flops == 50 * 2 * 128 ** 3


def test_roofline_terms_and_bottleneck():
    from repro.launch.hlo_analysis import HLOCosts

    costs = HLOCosts(flops=197e12, traffic_bytes=819e9 / 2)
    rl = roofline_from_costs(costs, chips=1, model_flops=197e12 / 2)
    assert rl.bottleneck == "compute"
    assert rl.t_compute == 1.0
    assert rl.roofline_fraction == 0.5
    assert rl.useful_ratio == 0.5
