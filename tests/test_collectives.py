"""Compressed all-reduce (fp8 AG phase + error feedback) vs exact psum."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_compressed_allreduce_matches_psum():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import compressed_allreduce

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 1000)),
                    jnp.float32)

    def f(x):
        def local(xs):
            out, fb = compressed_allreduce(xs[0], "data")
            return out[None], fb[None]
        return jax.shard_map(local, mesh=mesh, in_specs=P("data", None),
                             out_specs=(P("data", None), P("data", None)))(x)

    with jax.set_mesh(mesh):
        out, fb = jax.jit(f)(x)
    want = np.mean(np.asarray(x), axis=0)
    got = np.asarray(out[0])
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 0.05, rel          # fp8 AG-phase error, error-fed-back
    # feedback holds the local residual (bounded by fp8 step size)
    assert float(jnp.max(jnp.abs(fb))) < 0.1
    print("OK", rel)
    """
    _run(code)


@pytest.mark.slow
def test_error_feedback_residual_converges():
    """Threading the residual back in (EF) makes the *time-average* of
    repeated 1-plane compressed reductions approach the exact mean — the
    property that keeps compressed-gradient SGD unbiased."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import compressed_allreduce

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 512)),
                    jnp.float32)

    def f(x, fb):
        def local(xs, fbs):
            out, fb2 = compressed_allreduce(xs[0], "data",
                                            residual=fbs[0], planes=1)
            return out[None], fb2[None]
        return jax.shard_map(local, mesh=mesh,
                             in_specs=(P("data", None), P("data", None)),
                             out_specs=(P("data", None), P("data", None)))(x, fb)

    jf = jax.jit(f)
    want = np.mean(np.asarray(x), axis=0)
    with jax.set_mesh(mesh):
        fb = jnp.zeros_like(x)
        outs = []
        for _ in range(8):
            out, fb = jf(x, fb)
            outs.append(np.asarray(out[0]))
    first = np.abs(outs[0] - want).max() / np.abs(want).max()
    avg = np.abs(np.mean(outs, axis=0) - want).max() / np.abs(want).max()
    assert avg < first / 2, (first, avg)   # EF averages the bias away
    assert avg < 0.02, avg
    print("OK", first, avg)
    """
    _run(code)


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
