"""Scheduler/pool invariants the continuous-batching engine must keep
under any traffic: no slot leaks, FIFO admission, bounded occupancy —
plus the dist hook that places the slot pool on a (1-device) mesh.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import ServeEngine
from repro.train.serve import make_decode_step, make_prefill, quantize_for_serving


@pytest.fixture(scope="module")
def served():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(jax.random.PRNGKey(0)),
                                   policy_for(model, default_bits=4))
    fns = {"cache": "slot",  # legacy engine; paged invariants: test_serve_paged.py
           "prefill_fn": make_prefill(model),
           "decode_fn": make_decode_step(model, donate=False)}
    return cfg, model, sparams, fns


def _prompt(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def test_no_slot_leak_after_drain(served):
    """Every slot returns to the pool no matter how requests interleave
    (mixed budgets force admissions into recycled slots)."""
    cfg, model, sparams, fns = served
    eng = ServeEngine(model, sparams, num_slots=3, max_len=24, **fns)
    for i in range(7):
        eng.submit(_prompt(cfg, 4 + (i % 3), seed=i), max_new_tokens=1 + i % 4)
    eng.run_until_drained()
    assert eng.pool.num_free == eng.pool.num_slots
    assert eng.pool.active_slots == frozenset()
    assert eng.scheduler.running == {} and len(eng.queue) == 0
    assert all(r["state"] == "finished" for r in eng.metrics()["requests"])


def test_fifo_admission_order_mixed_lengths(served):
    """Admission order == submit order even when prompt lengths differ
    (a short prompt must not overtake a long one in the queue)."""
    cfg, model, sparams, fns = served
    eng = ServeEngine(model, sparams, num_slots=2, max_len=32, **fns)
    rids = [eng.submit(_prompt(cfg, n, seed=n), max_new_tokens=2)
            for n in (9, 3, 12, 5, 7)]
    admitted = []
    while eng.scheduler.has_work():
        admitted += eng.step()["admitted"]
    assert admitted == rids


def test_occupancy_never_exceeds_pool(served):
    """occupancy() stays in [0, 1] at every step and the aggregate mean
    can never exceed the pool size."""
    cfg, model, sparams, fns = served
    eng = ServeEngine(model, sparams, num_slots=2, max_len=24, **fns)
    for i in range(5):
        eng.submit(_prompt(cfg, 4, seed=i), max_new_tokens=1 + i)
    while eng.scheduler.has_work():
        eng.step()
        occ = eng.pool.occupancy()
        assert 0.0 <= occ <= 1.0
        assert len(eng.scheduler.running) <= eng.pool.num_slots
    assert 0.0 < eng.metrics()["mean_occupancy"] <= 1.0


def test_mesh_hook_single_device_parity(served):
    """The dist sharding hook: a pool placed on a 1-device mesh serves
    token-identical outputs (the 8-device case runs in
    test_distributed.py's subprocess tier)."""
    cfg, model, sparams, fns = served
    prompts = [_prompt(cfg, 5, seed=s) for s in (1, 2)]

    def run(mesh):
        eng = ServeEngine(model, sparams, num_slots=2, max_len=16, mesh=mesh,
                          **fns)
        rids = [eng.submit(p, max_new_tokens=3) for p in prompts]
        eng.run_until_drained()
        return [eng.output(r) for r in rids]

    want = run(None)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        got = run(mesh)
    assert got == want
