"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the brief: sweep shapes/dtypes per kernel and assert_allclose against
the ref.py oracle.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.qmm import qmm_pallas
from repro.quant.pack import pack_weight
from repro.quant.wrpn import tensor_scale

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(8, 128), (256, 256), (64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [2, 4, 8, 32])
def test_fake_quant_kernel(shape, dtype, bits):
    w = jnp.asarray(RNG.normal(size=shape), dtype)
    scale = tensor_scale(w)
    got = fake_quant_pallas(w, jnp.int32(bits), scale,
                            block=(min(128, shape[0]), min(128, shape[1])),
                            interpret=True)
    want = kref.fake_quant_ref(w, jnp.int32(bits), scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("mkn", [(8, 64, 128), (32, 128, 128), (128, 256, 128)])
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("path", ["dequant", "bitserial"])
def test_qmm_kernel(mkn, bits, path):
    M, K, N = mkn
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    planes, scale = pack_weight(w, bits)
    want = kref.qmm_ref(x, planes, scale, bits)
    got = qmm_pallas(x, planes, scale.reshape(1, N), bits=bits, path=path,
                     block=(min(128, M), min(128, N), min(128, K)),
                     interpret=True)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 2e-2, rel  # bf16 MXU accumulation tolerance


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_qmm_dtypes(xdtype):
    M, K, N = 16, 64, 128
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(M, K)), xdtype)
    planes, scale = pack_weight(w, 4)
    want = kref.qmm_ref(x.astype(jnp.float32), planes, scale, 4)
    got = qmm_pallas(x, planes, scale.reshape(1, N), bits=4,
                     block=(16, 128, 64), interpret=True)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 3e-2


@pytest.mark.parametrize("shape", [(3, 7, 4, 2, 1, 8),    # B,NB,bs,KV,G,hd
                                   (2, 9, 8, 1, 4, 16),
                                   (4, 5, 16, 2, 2, 16)])
@pytest.mark.parametrize("kvdtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel(shape, kvdtype):
    """Pallas paged decode attention (block-table index maps + online
    softmax) vs the gather-then-decode_attention oracle."""
    from repro.kernels.paged_attention import paged_attention_pallas

    B, NB, bs, KV, G, hd = shape
    nb = NB - 1  # logical blocks per sequence (block 0 = garbage sink)
    q = jnp.asarray(RNG.normal(size=(B, 1, KV * G, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), kvdtype)
    v = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), kvdtype)
    # each sequence gets a distinct permutation of physical blocks
    bt = jnp.stack([1 + (jnp.arange(nb) + b) % (NB - 1) for b in range(B)])
    lengths = jnp.asarray([(7 * b + 3) % (nb * bs) + 1 for b in range(B)],
                          jnp.int32)
    want = kref.paged_attention_ref(q, k, v, bt, lengths)
    got = paged_attention_pallas(q.reshape(B, KV, G, hd), k, v, bt, lengths,
                                 interpret=True).reshape(B, 1, KV * G, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_paged_attention_ops_dispatch():
    from repro.kernels import ops

    os.environ["REPRO_PALLAS"] = "interpret"
    try:
        B, NB, bs, KV, G, hd = 2, 5, 4, 2, 2, 8
        q = jnp.asarray(RNG.normal(size=(B, 1, KV * G, hd)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), jnp.float32)
        bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
        lengths = jnp.asarray([5, 8], jnp.int32)
        got = ops.paged_attention(q, k, v, bt, lengths)
        want = kref.paged_attention_ref(q, k, v, bt, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
    finally:
        os.environ["REPRO_PALLAS"] = "ref"


def test_ops_wrapper_pads_and_dispatches():
    from repro.kernels import ops

    os.environ["REPRO_PALLAS"] = "interpret"
    try:
        w = jnp.asarray(RNG.normal(size=(64, 96)), jnp.float32)
        x = jnp.asarray(RNG.normal(size=(3, 5, 64)), jnp.float32)  # odd batch
        planes, scale = pack_weight(w, 3)
        got = ops.qmm(x, planes, scale, bits=3)
        want = kref.qmm_ref(x.reshape(15, 64), planes, scale, 3).reshape(3, 5, 96)
        rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
        assert rel < 2e-2
    finally:
        os.environ["REPRO_PALLAS"] = "ref"


# ------------------------------------------------------- quantized KV blocks
def _quant_pools(NB, bs, KV, hd, kv_bits, seed=11):
    """Random fp pool -> (codes, scales) in the requested block container."""
    from repro.quant.pack import kv_pack_int4, kv_quantize

    rng = np.random.default_rng(seed)
    qmax = float(2 ** (kv_bits - 1) - 1)
    kf = jnp.asarray(rng.normal(size=(NB, bs, KV, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(NB, bs, KV, hd)), jnp.float32)
    kc, ks = kv_quantize(kf, qmax)
    vc, vs = kv_quantize(vf, qmax)
    if kv_bits == 4:  # nibble-packed uint8 container
        kc, vc = kv_pack_int4(kc), kv_pack_int4(vc)
    return kc, vc, ks, vs, qmax


@pytest.mark.parametrize("kv_bits", [8, 4])  # int8 codes / packed-int4 codes
@pytest.mark.parametrize("case", ["block_boundary", "length_zero", "one_block"])
def test_paged_attention_quant_edge_cases(kv_bits, case):
    """Quantized-KV paged decode in interpret mode at the edges: length
    exactly on a block boundary, all-masked length-0 garbage rows (zero
    output, no NaN from the denominator guard), and an nb == 1 table."""
    from repro.kernels.paged_attention import paged_attention_quant_pallas

    B, bs, KV, G, hd = 3, 4, 2, 2, 8
    nb = 1 if case == "one_block" else 3
    NB = 1 + B * nb
    q = jnp.asarray(RNG.normal(size=(B, 1, KV * G, hd)), jnp.float32)
    kc, vc, ks, vs, _ = _quant_pools(NB, bs, KV, hd, kv_bits)
    bt = jnp.asarray(1 + np.arange(B * nb).reshape(B, nb), jnp.int32)
    if case == "block_boundary":
        lengths = jnp.asarray([bs, 2 * bs, nb * bs], jnp.int32)
    elif case == "length_zero":
        lengths = jnp.asarray([0, 0, bs + 1], jnp.int32)
        bt = bt.at[0].set(0).at[1].set(0)  # dead rows sit on the garbage sink
    else:
        lengths = jnp.asarray([1, bs // 2, bs], jnp.int32)
    got = paged_attention_quant_pallas(
        q.reshape(B, KV, G, hd), kc, vc, ks, vs, bt, lengths,
        interpret=True).reshape(B, 1, KV * G, hd)
    want = kref.quant_paged_attention_ref(q, kc, vc, ks, vs, bt, lengths)
    assert not np.any(np.isnan(np.asarray(got)))
    live = np.asarray(lengths) > 0
    if case == "length_zero":
        # all-masked rows: the kernel's l == 0 guard yields exact zeros
        # (the jnp oracle's masked softmax degenerates to a uniform
        # average there — dead rows are never consumed, so only the
        # no-NaN/zero contract matters, not oracle agreement)
        np.testing.assert_array_equal(np.asarray(got[~live]), 0.0)
    np.testing.assert_allclose(np.asarray(got[live]),
                               np.asarray(want, np.float32)[live],
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kv_bits", [8, 4])
@pytest.mark.parametrize("lengths_case", ["mid", "boundary", "zero"])
def test_fused_decode_kernel_vs_ref(kv_bits, lengths_case):
    """The fused QKV+RoPE+quantize+attend kernel vs its composed oracle:
    identical codes/scales bitwise, attention output equal at activation
    (bf16) resolution."""
    from repro.kernels.fused_decode import fused_qkv_paged_decode_pallas
    from repro.models.common import rope_freqs
    from repro.quant.pack import Packed

    B, nb, bs, KV, G, hd, D = 3, 3, 4, 2, 2, 8, 32
    H = KV * G
    NB = 1 + B * nb
    Tc = nb * bs
    kc, vc, ks, vs, qmax = _quant_pools(NB, bs, KV, hd, kv_bits)
    bt = jnp.asarray(1 + np.arange(B * nb).reshape(B, nb), jnp.int32)
    lengths = {"mid": [1, 5, Tc - 1],
               "boundary": [bs - 1, bs, 2 * bs - 1],
               "zero": [0, 0, 3]}[lengths_case]
    lengths = jnp.asarray(lengths, jnp.int32)
    x = jnp.asarray(RNG.normal(size=(B, D)), jnp.bfloat16)
    packs = {}
    for name, n_out, bits in (("wq", H * hd, 4), ("wk", KV * hd, 3),
                              ("wv", KV * hd, 8)):
        p, s = pack_weight(jnp.asarray(RNG.normal(size=(D, n_out)),
                                       jnp.float32), bits)
        packs[name] = Packed(p, s, bits)
    wq, wk, wv = packs["wq"], packs["wk"], packs["wv"]
    ro, rkc, rvc, rks, rvs = kref.fused_qkv_paged_decode_ref(
        x, wq, wk, wv, kc, vc, ks, vs, bt, lengths, jnp.float32(qmax),
        1e4, H, KV)
    inv = rope_freqs(hd, 1e4)
    ang = lengths.astype(jnp.float32)[:, None] * inv
    po, pkc, pvc, pks, pvs = fused_qkv_paged_decode_pallas(
        x, wq.planes, wq.scale, wk.planes, wk.scale, wv.planes, wv.scale,
        kc, vc, ks, vs, bt, lengths, jnp.cos(ang), jnp.sin(ang),
        jnp.float32(qmax), bits_q=wq.bits, bits_k=wk.bits, bits_v=wv.bits,
        num_heads=H, interpret=True)
    po = po.reshape(B, 1, H, hd)
    assert not np.any(np.isnan(np.asarray(po)))
    np.testing.assert_array_equal(np.asarray(pkc), np.asarray(rkc))
    np.testing.assert_array_equal(np.asarray(pvc), np.asarray(rvc))
    np.testing.assert_array_equal(np.asarray(pks), np.asarray(rks))
    np.testing.assert_array_equal(np.asarray(pvs), np.asarray(rvs))
    # output contract is the activation dtype (bf16): exact there
    np.testing.assert_array_equal(
        np.asarray(po.astype(jnp.bfloat16), np.float32),
        np.asarray(ro.astype(jnp.bfloat16), np.float32))
