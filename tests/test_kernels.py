"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the brief: sweep shapes/dtypes per kernel and assert_allclose against
the ref.py oracle.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.qmm import qmm_pallas
from repro.quant.pack import pack_weight
from repro.quant.wrpn import tensor_scale

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(8, 128), (256, 256), (64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [2, 4, 8, 32])
def test_fake_quant_kernel(shape, dtype, bits):
    w = jnp.asarray(RNG.normal(size=shape), dtype)
    scale = tensor_scale(w)
    got = fake_quant_pallas(w, jnp.int32(bits), scale,
                            block=(min(128, shape[0]), min(128, shape[1])),
                            interpret=True)
    want = kref.fake_quant_ref(w, jnp.int32(bits), scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("mkn", [(8, 64, 128), (32, 128, 128), (128, 256, 128)])
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("path", ["dequant", "bitserial"])
def test_qmm_kernel(mkn, bits, path):
    M, K, N = mkn
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    planes, scale = pack_weight(w, bits)
    want = kref.qmm_ref(x, planes, scale, bits)
    got = qmm_pallas(x, planes, scale.reshape(1, N), bits=bits, path=path,
                     block=(min(128, M), min(128, N), min(128, K)),
                     interpret=True)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 2e-2, rel  # bf16 MXU accumulation tolerance


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_qmm_dtypes(xdtype):
    M, K, N = 16, 64, 128
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(M, K)), xdtype)
    planes, scale = pack_weight(w, 4)
    want = kref.qmm_ref(x.astype(jnp.float32), planes, scale, 4)
    got = qmm_pallas(x, planes, scale.reshape(1, N), bits=4,
                     block=(16, 128, 64), interpret=True)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 3e-2


@pytest.mark.parametrize("shape", [(3, 7, 4, 2, 1, 8),    # B,NB,bs,KV,G,hd
                                   (2, 9, 8, 1, 4, 16),
                                   (4, 5, 16, 2, 2, 16)])
@pytest.mark.parametrize("kvdtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel(shape, kvdtype):
    """Pallas paged decode attention (block-table index maps + online
    softmax) vs the gather-then-decode_attention oracle."""
    from repro.kernels.paged_attention import paged_attention_pallas

    B, NB, bs, KV, G, hd = shape
    nb = NB - 1  # logical blocks per sequence (block 0 = garbage sink)
    q = jnp.asarray(RNG.normal(size=(B, 1, KV * G, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), kvdtype)
    v = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), kvdtype)
    # each sequence gets a distinct permutation of physical blocks
    bt = jnp.stack([1 + (jnp.arange(nb) + b) % (NB - 1) for b in range(B)])
    lengths = jnp.asarray([(7 * b + 3) % (nb * bs) + 1 for b in range(B)],
                          jnp.int32)
    want = kref.paged_attention_ref(q, k, v, bt, lengths)
    got = paged_attention_pallas(q.reshape(B, KV, G, hd), k, v, bt, lengths,
                                 interpret=True).reshape(B, 1, KV * G, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_paged_attention_ops_dispatch():
    from repro.kernels import ops

    os.environ["REPRO_PALLAS"] = "interpret"
    try:
        B, NB, bs, KV, G, hd = 2, 5, 4, 2, 2, 8
        q = jnp.asarray(RNG.normal(size=(B, 1, KV * G, hd)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(NB, bs, KV, hd)), jnp.float32)
        bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
        lengths = jnp.asarray([5, 8], jnp.int32)
        got = ops.paged_attention(q, k, v, bt, lengths)
        want = kref.paged_attention_ref(q, k, v, bt, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
    finally:
        os.environ["REPRO_PALLAS"] = "ref"


def test_ops_wrapper_pads_and_dispatches():
    from repro.kernels import ops

    os.environ["REPRO_PALLAS"] = "interpret"
    try:
        w = jnp.asarray(RNG.normal(size=(64, 96)), jnp.float32)
        x = jnp.asarray(RNG.normal(size=(3, 5, 64)), jnp.float32)  # odd batch
        planes, scale = pack_weight(w, 3)
        got = ops.qmm(x, planes, scale, bits=3)
        want = kref.qmm_ref(x.reshape(15, 64), planes, scale, 3).reshape(3, 5, 96)
        rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
        assert rel < 2e-2
    finally:
        os.environ["REPRO_PALLAS"] = "ref"
