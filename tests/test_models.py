"""Per-arch smoke tests (reduced configs, 1 CPU device) + decode parity.

The brief requires: per assigned architecture, instantiate a REDUCED config
of the same family and run one forward/train step on CPU asserting output
shapes + no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 24
    batch = {"labels": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    logits, aux = model.forward(params, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one train step (loss + grads finite)
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: model.loss(p, batch, remat="full")[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ["glm4-9b", "h2o-danube-3-4b", "rwkv6-1.6b",
                                  "hymba-1.5b", "musicgen-large"])
def test_decode_matches_teacher_forced(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 12
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, tokens=toks)
    lp, cache = model.prefill(params, tokens=toks[:, :S - 1], max_len=S + 2)
    ld, _ = model.decode_step(params, cache, toks[:, S - 1:])
    assert float(jnp.max(jnp.abs(lp[:, 0] - full[:, S - 2]))) < 0.15
    assert float(jnp.max(jnp.abs(ld[:, 0] - full[:, S - 1]))) < 0.15


@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b", "llama4-maverick-400b-a17b"])
def test_moe_decode_matches_dropfree_forward(arch):
    """MoE teacher-forced training drops tokens; compare at high capacity."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 12
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, tokens=toks)
    lp, cache = model.prefill(params, tokens=toks[:, :S - 1], max_len=S + 2)
    ld, _ = model.decode_step(params, cache, toks[:, S - 1:])
    assert float(jnp.max(jnp.abs(ld[:, 0] - full[:, S - 1]))) < 0.15


def test_sliding_window_ring_cache():
    """Danube SWA: decode far past the window; ring cache must match."""
    cfg = dataclasses.replace(get_config("h2o-danube-3-4b", smoke=True),
                              sliding_window=6)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 20
    toks = jax.random.randint(RNG, (B, S + 1), 0, cfg.vocab_size)
    full, _ = model.forward(params, tokens=toks)
    _, cache = model.prefill(params, tokens=toks[:, :S], max_len=S + 4)
    ld, _ = model.decode_step(params, cache, toks[:, S:S + 1])
    assert float(jnp.max(jnp.abs(ld[:, 0] - full[:, S]))) < 0.15


def test_quant_groups_cover_all_big_matrices():
    for arch in all_archs():
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        groups = model.quant_groups()
        names = [g.name for g in groups]
        assert names[0] == "embed"
        assert len(names) == len(set(names))
        assert all(g.n_weights > 0 for g in groups)
        frozen = model.frozen_bits()
        assert "embed" in frozen  # paper's boundary rule
