"""repro.autotune: Pareto-archive invariants (hypothesis), async service
vs lockstep parity, evaluator workers, cache concurrency, hot-swap deploy."""
import json
import random
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: skip ONLY property tests
    import types

    st = types.SimpleNamespace(
        integers=lambda *a, **k: None, sampled_from=lambda *a, **k: None,
        lists=lambda *a, **k: None, tuples=lambda *a, **k: None,
        floats=lambda *a, **k: None)

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

from repro.autotune import (
    AnalyticLatencyEvaluator,
    AutotuneService,
    EvaluatorPool,
    AccuracyEvaluator,
    ParetoArchive,
    ServiceConfig,
    dominates,
)
from repro.core import EvalCache
from repro.core.env import QuantEnv
from repro.core.pareto import as_archive, enumerate_space, pareto_frontier
from repro.core.search import ReLeQSearch
from repro.models.model import QuantGroup

GROUPS = [QuantGroup(f"L{i}", ("blocks",), i, (64, 64), 64 * 64, 64 * 64 * 50)
          for i in range(4)]
SENS = [2.0, 2.0, 6.0, 2.5]


def sensitivity_evaluate(bits):
    """The LeNet-scale oracle from test_core_rl: layer 2 needs high bits."""
    acc = 1.0
    for i, g in enumerate(GROUPS):
        acc *= 1.0 / (1.0 + np.exp(-(bits[g.name] - SENS[i]) * 2.2))
    return acc


def make_factory(eval_mode="episode_end", evaluate=sensitivity_evaluate):
    def factory(i):
        return QuantEnv(groups=GROUPS, evaluate=evaluate,
                        weight_std={g.name: 0.5 for g in GROUPS},
                        eval_mode=eval_mode)
    return factory


# ===================================================================== archive
def _bits(vals):
    return {f"L{i}": b for i, b in enumerate(vals)}


class TestArchive:
    def test_dominated_point_rejected_and_pruned(self):
        arch = ParetoArchive()
        assert arch.add(_bits([4, 4]), acc=0.9, sq=0.5, latency=1.0)
        # dominated on every axis -> rejected
        assert not arch.add(_bits([8, 8]), acc=0.8, sq=0.6, latency=2.0)
        # dominates the incumbent -> replaces it
        assert arch.add(_bits([2, 2]), acc=0.95, sq=0.4, latency=0.5)
        assert len(arch) == 1
        assert arch.entries()[0].acc == 0.95

    def test_incomparable_points_coexist(self):
        arch = ParetoArchive(objectives=("acc", "sq"))
        arch.add(_bits([8, 8]), acc=1.0, sq=0.9)
        arch.add(_bits([2, 2]), acc=0.5, sq=0.3)
        assert len(arch) == 2

    def test_duplicate_offer_idempotent(self):
        arch = ParetoArchive()
        assert arch.add(_bits([4, 4]), acc=0.9, sq=0.5, latency=1.0)
        assert not arch.add(_bits([4, 4]), acc=0.9, sq=0.5, latency=1.0)
        assert len(arch) == 1 and arch.offered == 2 and arch.accepted == 1

    def test_latency_objective_requires_latency(self):
        arch = ParetoArchive()  # default ranks latency
        with pytest.raises(ValueError):
            arch.add(_bits([4, 4]), acc=0.9, sq=0.5)
        ParetoArchive(objectives=("acc", "sq")).add(
            _bits([4, 4]), acc=0.9, sq=0.5)  # fine without

    def test_select_modes(self):
        arch = ParetoArchive()
        arch.add(_bits([8, 8]), acc=1.00, sq=0.9, latency=3.0, reward=0.1)
        arch.add(_bits([4, 4]), acc=0.97, sq=0.5, latency=2.0, reward=0.5)
        arch.add(_bits([2, 2]), acc=0.60, sq=0.2, latency=1.0, reward=0.2)
        assert arch.select("accuracy").acc == 1.00
        assert arch.select("efficiency", acc_floor=0.95).sq == 0.5
        assert arch.select("latency", acc_floor=0.95).latency == 2.0
        assert arch.select("reward").reward == 0.5
        knee = arch.select("knee")
        assert knee.acc - knee.sq == max(e.acc - e.sq for e in arch.entries())

    def test_warm_start_roundtrip_and_merge(self, tmp_path):
        path = str(tmp_path / "archive.json")
        a = ParetoArchive()
        a.add(_bits([4, 4]), acc=0.9, sq=0.5, latency=1.25,
              reward=0.3, meta={"episode": 7})
        a.add(_bits([8, 2]), acc=0.95, sq=0.6, latency=1.5)
        a.save(path)
        b = ParetoArchive.warm_start(path)
        assert {e.key() for e in b.entries()} == {e.key() for e in a.entries()}
        assert b.entries()[0].meta == a.entries()[0].meta
        # composing runs: a later search merges new points in
        b.add(_bits([2, 2]), acc=0.99, sq=0.4, latency=1.0)
        c = ParetoArchive()
        c.merge(b)
        assert len(c) == len(b)
        # missing file -> fresh archive
        fresh = ParetoArchive.warm_start(str(tmp_path / "none.json"))
        assert len(fresh) == 0

    def test_warm_start_reranks_on_objective_mismatch(self, tmp_path):
        """A latency-ranked checkpoint resumed without a latency evaluator
        re-ranks on (acc, sq) instead of crashing the search mid-run."""
        path = str(tmp_path / "lat.json")
        a = ParetoArchive()
        a.add(_bits([4, 4]), acc=0.9, sq=0.5, latency=2.0)
        # same acc, worse sq — only its better latency keeps it on the
        # 3-objective frontier
        a.add(_bits([8, 2]), acc=0.9, sq=0.6, latency=1.0)
        assert len(a) == 2
        a.save(path)
        b = ParetoArchive.warm_start(path, objectives=("acc", "sq"))
        assert b.objectives == ("acc", "sq")
        assert len(b) == 1 and b.entries()[0].sq == 0.5
        # reverse direction: unmeasured entries cannot join a
        # latency-ranked archive and are dropped, not crashed on
        path2 = str(tmp_path / "nolat.json")
        c = ParetoArchive(objectives=("acc", "sq"))
        c.add(_bits([4, 4]), acc=0.9, sq=0.5)
        c.save(path2)
        d = ParetoArchive.warm_start(path2)  # default ranks latency
        assert d.objectives == ("acc", "sq", "latency") and len(d) == 0

    def test_oracle_matches_pareto_frontier(self):
        """On an enumerable space the 2-objective archive IS the paper's
        frontier (core/pareto.py subsumed as the small-network oracle)."""
        pts = enumerate_space(GROUPS, sensitivity_evaluate, bitset=(2, 4, 8))
        assert len(pts) == 3 ** 4
        front = pareto_frontier(pts)
        arch = as_archive(pts)
        assert arch.objectives == ("acc", "sq")
        assert arch.objective_set() == {(p["acc"], p["quant"]) for p in front}

    # ------------------------------------------------------- hypothesis
    @given(points=st.lists(
        st.tuples(st.lists(st.sampled_from([2, 4, 8]), min_size=2,
                           max_size=2),
                  st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]),
                  st.sampled_from([0.25, 0.5, 1.0]),
                  st.floats(1e-9, 10.0, allow_nan=False,
                            allow_infinity=False)),
        max_size=14),
        seed=st.integers(0, 7))
    @settings(max_examples=120, deadline=None)
    def test_archive_invariants(self, points, seed):
        def build(pts):
            arch = ParetoArchive()
            for bits, acc, sq, lat in pts:
                arch.add(_bits(bits), acc=acc, sq=sq, latency=lat)
            return arch

        arch = build(points)
        entries = arch.entries()
        # 1) no archived point dominates another
        for a in entries:
            for b in entries:
                if a is not b:
                    assert not dominates(a, b, arch.objectives), (a, b)
        # 2) insertion is order-independent
        shuffled = list(points)
        random.Random(seed).shuffle(shuffled)
        assert {e.key() for e in build(shuffled).entries()} == \
               {e.key() for e in entries}
        # 3) JSON warm-start round-trips losslessly
        back = ParetoArchive.from_dict(json.loads(json.dumps(arch.to_dict())))
        assert back.objectives == arch.objectives
        assert {e.key() for e in back.entries()} == {e.key() for e in entries}


# ==================================================================== cache
class TestEvalCacheConcurrency:
    def test_concurrent_same_key_computes_once(self):
        cache = EvalCache()
        calls, gate = [], threading.Event()

        def slow():
            gate.wait(2.0)
            calls.append(1)
            return 42.0

        with ThreadPoolExecutor(8) as ex:
            futs = [ex.submit(cache.get_or_compute, {"a": 4}, slow)
                    for _ in range(8)]
            gate.set()
            results = [f.result() for f in futs]
        assert len(calls) == 1                      # coalesced
        assert all(v == 42.0 for v, _ in results)
        assert sum(1 for _, hit in results if not hit) == 1
        stats = cache.stats()
        assert stats["misses"] == 1 and stats["hits"] == 7
        assert stats["hit_rate"] == pytest.approx(7 / 8)

    def test_distinct_keys_run_concurrently(self):
        cache = EvalCache()
        started = threading.Barrier(4, timeout=5.0)

        def fn():
            started.wait()  # deadlocks unless 4 computes overlap
            return 1.0

        with ThreadPoolExecutor(4) as ex:
            futs = [ex.submit(cache.get_or_compute, {"a": b}, fn)
                    for b in (2, 3, 4, 5)]
            assert all(f.result()[0] == 1.0 for f in futs)
        assert len(cache) == 4

    def test_canonical_key_order_insensitive(self):
        assert EvalCache.key({"a": 2, "b": 4}) == EvalCache.key({"b": 4, "a": 2})

    def test_hit_rate_in_search_record(self):
        """The lockstep search surfaces the shared memo's hit rate."""
        cache = EvalCache()

        def evaluate(bits):
            v, _ = cache.get_or_compute(bits, lambda: sensitivity_evaluate(bits))
            return v

        factory = make_factory(evaluate=evaluate)
        factory.eval_cache = cache
        res = ReLeQSearch(factory, seed=0).run(episodes=4)
        assert res.cache_stats["misses"] >= 1
        assert res.cache_stats["hits"] + res.cache_stats["misses"] > 0
        assert 0.0 <= res.cache_stats["hit_rate"] <= 1.0


# ================================================================== workers
class TestWorkers:
    def test_analytic_latency_monotone_and_normalized(self):
        ev = AnalyticLatencyEvaluator(GROUPS)
        lo, ref = ev({g.name: 2 for g in GROUPS})
        hi, ref2 = ev({g.name: 8 for g in GROUPS})
        assert ref == ref2 == hi                 # 8-bit IS the reference
        assert 0 < lo < hi
        mid, _ = ev({g.name: 4 for g in GROUPS})
        assert lo < mid < hi

    def test_pool_without_latency(self):
        with EvaluatorPool(AccuracyEvaluator(sensitivity_evaluate,
                                             thread_safe=True),
                           num_workers=2) as pool:
            res = pool.submit({g.name: 8 for g in GROUPS}).result()
        assert res.latency is None and res.ref_latency is None
        assert res.acc == pytest.approx(
            sensitivity_evaluate({g.name: 8 for g in GROUPS}))
        assert res.latency_ratio() is None

    def test_pool_with_latency_and_shared_cache(self):
        pool = EvaluatorPool(
            AccuracyEvaluator(sensitivity_evaluate, thread_safe=True),
            AnalyticLatencyEvaluator(GROUPS), num_workers=2)
        bits = {g.name: 4 for g in GROUPS}
        r1 = pool.submit(bits).result()
        r2 = pool.submit(bits).result()
        pool.shutdown()
        assert not r1.acc_cache_hit and r2.acc_cache_hit
        assert 0 < r1.latency_ratio() < 1.0
        assert pool.stats()["acc_cache"]["hits"] >= 1
        assert pool.stats()["latency_cache"]["entries"] >= 1


# ================================================================== service
class TestService:
    def test_deferred_episode_matches_episode_end(self):
        """Deferred rollout + reward_for patch == lockstep episode_end."""
        calls = []

        def spy(bits):
            calls.append(1)
            return sensitivity_evaluate(bits)

        env_d = make_factory("deferred", evaluate=spy)(0)
        env_e = make_factory("episode_end")(0)
        actions = [0, 3, 6, 2]
        env_d.reset(), env_e.reset()
        for a in actions:
            _, r_d, done, info_d = env_d.step(a)
            _, r_e, done_e, info_e = env_e.step(a)
            if not done:
                assert r_d == r_e          # provisional rewards identical
        assert not calls                   # deferred never evaluated
        acc = sensitivity_evaluate(info_d["bits"])
        assert env_d.reward_for(acc, info_d["quant"]) == pytest.approx(r_e)
        assert info_d["bits"] == info_e["bits"]

    def test_async_reaches_lockstep_best_reward(self):
        """Acceptance pin: seeded async service >= lockstep best reward on
        the LeNet-scale env (deterministic: in-order, one worker)."""
        lockstep = ReLeQSearch(make_factory(), num_envs=1, seed=0)
        res_lock = lockstep.run(episodes=25)

        service = AutotuneService(
            make_factory(), config=ServiceConfig(
                num_workers=1, in_order=True, max_inflight=4,
                batch_episodes=1, seed=0))
        res_async = service.run(episodes=25)
        service.shutdown()
        assert res_async.best_reward >= res_lock.best_reward - 1e-6
        assert len(res_async.episodes) == 25
        assert res_async.service_stats["updates"] == 25
        assert len(service.archive) >= 1
        # the archive's best-reward entry IS the search's best policy
        top = service.archive.select("reward")
        assert top.bits_dict() == res_async.best_bits

    def test_out_of_order_consumption_and_staleness_bound(self):
        service = AutotuneService(
            make_factory(), accuracy_thread_safe=True,
            config=ServiceConfig(num_workers=4, max_inflight=8,
                                 batch_episodes=3, max_staleness=1, seed=2))
        res = service.run(episodes=18)
        service.shutdown()
        assert len(res.episodes) == 18
        assert res.service_stats["updates"] >= 1
        # staleness-bounded: anything older than max_staleness versions
        # was dropped from update batches, never silently trained on
        assert res.service_stats["stale_dropped"] >= 0
        assert res.service_stats["pool"]["completed"] == 18
        assert np.isfinite(res.best_reward)

    def test_hw_weight_blends_latency_into_reward(self):
        service = AutotuneService(
            make_factory(), latency_eval=AnalyticLatencyEvaluator(GROUPS),
            config=ServiceConfig(num_workers=1, in_order=True,
                                 batch_episodes=2, hw_weight=1.0, seed=0))
        res = service.run(episodes=4)
        service.shutdown()
        for ep in res.episodes:
            assert ep["latency"] is not None
            assert 0 < ep["latency_ratio"] <= 1.0
            # hw_weight=1: the terminal quant state IS the latency ratio
            assert ep["q_eff"] == pytest.approx(min(ep["latency_ratio"], 1.0))
        assert service.archive.objectives == ("acc", "sq", "latency")
        assert len(service.archive) >= 1

    def test_latency_archive_without_evaluator_rejected_early(self):
        with pytest.raises(ValueError, match="latency"):
            AutotuneService(make_factory(),
                            archive=ParetoArchive())  # ranks latency


# ------------------------------------------------- hardware-in-the-loop
@pytest.mark.slow
def test_engine_latency_evaluator_measures_and_caches(served_lm):
    """Real-decode-step measurement: positive wall time, 8-bit reference
    shared, repeats served from the memo (no second engine build)."""
    from repro.autotune import EngineLatencyEvaluator

    _, model, params = served_lm
    ev = EngineLatencyEvaluator(model, params, num_slots=2, prompt_len=4,
                                decode_steps=3, warmup_steps=1)
    bits = {n: ev.frozen.get(n, 4) for n in ev.group_names}
    lat, ref = ev(bits)
    assert lat > 0 and ref > 0
    misses = ev.cache.stats()["misses"]
    lat2, ref2 = ev(bits)
    assert (lat2, ref2) == (lat, ref)
    assert ev.cache.stats()["misses"] == misses  # memo hit, no rebuild


@pytest.mark.slow
def test_hlo_latency_evaluator_bits_monotone(served_lm):
    """Compiled-HLO roofline of the packed decode step: fewer weight bits
    -> fewer HBM bytes -> lower estimated decode latency."""
    from repro.autotune import HLOLatencyEvaluator

    _, model, _ = served_lm
    ev = HLOLatencyEvaluator(model, max_len=16)
    low, ref = ev({n: ev.frozen.get(n, 2) for n in ev.group_names})
    high, ref2 = ev({n: ev.frozen.get(n, 8) for n in ev.group_names})
    assert ref == ref2 == high        # all-8-bit IS the reference
    assert 0 < low < high


# =================================================================== deploy
@pytest.fixture(scope="module")
def served_lm():
    """Smoke LM + an archive holding a real searched-style entry."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestDeploy:
    def _archive_for(self, model):
        from repro.core.costmodel import state_of_quantization

        groups = model.quant_groups()
        arch = ParetoArchive(objectives=("acc", "sq"))
        four = {g.name: 4 for g in groups}
        eight = {g.name: 8 for g in groups}
        arch.add(four, acc=0.97,
                 sq=state_of_quantization([4] * len(groups), groups))
        arch.add(eight, acc=1.0,
                 sq=state_of_quantization([8] * len(groups), groups))
        return arch, four

    def test_policy_from_entry(self, served_lm):
        from repro.autotune import policy_from_entry
        from repro.autotune.archive import ArchiveEntry

        _, model, _ = served_lm
        arch, four = self._archive_for(model)
        entry = arch.select("efficiency", acc_floor=0.9)
        policy = policy_from_entry(model, entry)
        frozen = model.frozen_bits()
        # searchable groups take the entry's 4 bits; frozen stay pinned
        for name in policy.searchable:
            assert policy.get(name) == 4
        for name, b in frozen.items():
            assert policy.get(name) == b
        bad = ArchiveEntry(bits=(("nope", 4),), acc=1.0, sq=0.5)
        with pytest.raises(KeyError):
            policy_from_entry(model, bad)

    def test_hot_swap_ab_parity_on_running_engine(self, served_lm):
        """Acceptance pin: a policy pulled from the archive, hot-swapped
        into a running engine, serves token-identical greedy output to a
        fresh engine built directly with that policy."""
        from repro.autotune import deploy as deploy_fn
        from repro.quant.qat import policy_for
        from repro.serve import ServeEngine

        cfg, model, params = served_lm
        arch, _ = self._archive_for(model)
        engine = ServeEngine.from_params(
            model, params, policy_for(model, default_bits=8),
            num_slots=2, max_len=24, block_size=8, prefill_chunk=8)
        # the engine is live: serve traffic at the old 8-bit policy first
        rng = np.random.default_rng(0)
        pre = engine.submit(rng.integers(0, cfg.vocab_size, 6), 4)
        engine.run_until_drained()
        served_before = engine.output(pre)
        assert len(served_before) == 4

        prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(2)]
        policy, report = deploy_fn(arch, model, params, engine,
                                   select="efficiency", acc_floor=0.9,
                                   parity_prompts=prompts, max_new_tokens=5)
        assert all(policy.get(n) == 4 for n in policy.searchable)
        assert report["parity"]["match"]
        outs = report["parity"]["outputs"]
        assert outs["live"] == outs["fresh"]
        assert all(len(o) == 5 for o in outs["live"])
        # pre-swap traffic untouched; engine now serves the new policy
        assert engine.output(pre) == served_before

    def test_hot_swap_holds_queued_requests_for_new_policy(self, served_lm):
        """Mid-decode rows finish under the OLD weights (their KV was
        prefilled by them); a request still queued at swap time prefills
        and decodes entirely under the NEW policy."""
        from repro.autotune import compile_policy, hot_swap
        from repro.quant.qat import policy_for
        from repro.serve import ServeEngine

        cfg, model, params = served_lm
        kw = dict(num_slots=1, max_len=24, block_size=8, prefill_chunk=8)
        engine = ServeEngine.from_params(
            model, params, policy_for(model, default_bits=8), **kw)
        rng = np.random.default_rng(3)
        queued_prompt = rng.integers(0, cfg.vocab_size, 6)
        engine.submit(rng.integers(0, cfg.vocab_size, 6), 6)
        engine.step()                    # admitted into the only row
        rid_q = engine.submit(queued_prompt, 5)   # no free row -> queued
        assert engine.num_running == 1 and engine.num_queued == 1

        sp4 = compile_policy(model, params,
                             policy_for(model, default_bits=4))
        report = hot_swap(engine, sp4)
        assert report["drained_steps"] >= 1
        assert engine.num_running == 0   # mid-decode row finished...
        assert engine.num_queued == 1    # ...queued request held back
        assert engine.sparams is sp4
        engine.run_until_drained()

        fresh = ServeEngine(model, sp4, **kw)
        fid = fresh.submit(queued_prompt, 5)
        fresh.run_until_drained()
        assert engine.output(rid_q) == fresh.output(fid)
