"""Distribution: sharding specs, small-mesh lower/compile, EP MoE, elastic.

Multi-device cases run in SUBPROCESSES (XLA_FLAGS must be set before jax
initializes; the main test process keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_param_specs_cover_all_archs():
    from jax.sharding import PartitionSpec

    code = """
    import jax
    from repro.configs import all_archs, get_config
    from repro.models import build_model
    from repro.launch import specs as S
    from repro.dist import sharding as shd
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    for arch in all_archs():
        model = build_model(get_config(arch, smoke=True))
        ps = S.params_struct(model)
        specs = shd.param_specs(ps, mesh)
        n_leaves = len(jax.tree.leaves(ps))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        assert n_specs == n_leaves, (arch, n_specs, n_leaves)
    print("OK")
    """
    assert "OK" in run_py(code)


@pytest.mark.slow
def test_small_mesh_train_step_runs():
    """Lower + compile + EXECUTE a sharded QAT train step on 8 fake devices."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.dist import sharding as shd
    from repro.quant.qat import bits_assignment, policy_for, quantize_params
    from repro.data import SyntheticLMData

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    groups = model.quant_groups()
    bm = {k: jnp.asarray(v) for k, v in bits_assignment(
        groups, policy_for(model, 8)).items()}

    def step(state, batch, bmm):
        def loss_fn(p):
            return model.loss(quantize_params(p, bmm, groups), batch,
                              remat="full")
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(state["params"])
        p2, o2 = opt.update(state["params"], g, state["opt"])
        return {"params": p2, "opt": o2}, l

    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": opt.init(params)}
        st_specs = shd.to_named(shd.state_specs(state, mesh), mesh)
        state = jax.device_put(state, st_specs)
        data = SyntheticLMData(seed=0, global_batch=4, seq_len=16,
                               vocab=cfg.vocab_size)
        batch = {k: jnp.asarray(v) for k, v in data.next().items()}
        jstep = jax.jit(step, in_shardings=(st_specs, None, None))
        losses = []
        for _ in range(3):
            state, l = jstep(state, batch, bm)
            losses.append(float(l))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    print("OK", losses)
    """
    assert "OK" in run_py(code)


@pytest.mark.slow
def test_moe_ep_matches_meshless():
    code = """
    import jax, jax.numpy as jnp
    from repro.models.moe import init_moe, moe_ffn
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = jax.random.PRNGKey(1)
    B, S, D, F, E, k = 4, 8, 16, 24, 8, 2
    p = init_moe(rng, E, D, F, jnp.float32)
    x = jax.random.normal(rng, (B, S, D), jnp.float32)
    y_ref, _ = moe_ffn(x, p, k=k, no_drop=True)
    with jax.set_mesh(mesh):
        y_ep, _ = jax.jit(lambda x, p: moe_ffn(x, p, k=k, no_drop=True))(x, p)
    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    assert err < 1e-5, err
    print("OK", err)
    """
    assert "OK" in run_py(code)


@pytest.mark.slow
def test_sharded_pool_parity():
    """ServeEngine with its KV pool placed over an 8-device data mesh
    (the dist sharding hook) emits token-identical outputs — slot pool
    (slot axis sharded) AND paged pool (block axis sharded)."""
    code = """
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.quant.qat import policy_for
    from repro.serve import ServeEngine
    from repro.train.serve import quantize_for_serving

    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(jax.random.PRNGKey(0)),
                                   policy_for(model, default_bits=4))
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(s), (5,), 0,
                                             cfg.vocab_size))
               for s in (1, 2, 3)]
    kw = {"slot": {"cache": "slot"},
          # num_blocks=40: 8 seqs x 4 blocks of 4 + garbage, NB % 8 == 0
          "paged": {"cache": "paged", "block_size": 4, "num_blocks": 40}}

    def run(kind, mesh):
        eng = ServeEngine(model, sparams, num_slots=8, max_len=16, mesh=mesh,
                          **kw[kind])
        rids = [eng.submit(p, max_new_tokens=2 + i)
                for i, p in enumerate(prompts)]
        eng.run_until_drained()
        assert eng.pool.num_free == 8          # no row leak, sharded or not
        return [eng.output(r) for r in rids]

    mesh = jax.make_mesh((8, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    for kind in ("slot", "paged"):
        want = run(kind, None)
        with jax.set_mesh(mesh):
            sharded = ServeEngine(model, sparams, num_slots=8, max_len=16,
                                  mesh=mesh, **kw[kind])
            leaf = sharded.pool.cache["k"]
            # slot/block axis spread over the data mesh
            assert len(leaf.sharding.device_set) == 8, (kind, leaf.sharding)
            got = run(kind, mesh)
        assert got == want, (kind, got, want)
    print("OK")
    """
    assert "OK" in run_py(code)


@pytest.mark.slow
def test_dp_compressed_grad_train_step():
    """Pure-DP train step with the fp8-plane compressed gradient
    all-reduce (EF residuals carried in the train state): loss decreases,
    tracks the exact-psum step closely, and the residual is nonzero
    (compression actually happened)."""
    code = """
    import os
    os.environ["REPRO_SHARD_PROFILE"] = "dp"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.quant.qat import bits_assignment, policy_for
    from repro.train.train_step import init_dp_state, make_dp_train_step
    from repro.data import SyntheticLMData

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    bm = {k: jnp.asarray(v) for k, v in bits_assignment(
        model.quant_groups(), policy_for(model, 8)).items()}

    def fit(planes, steps=4):
        with jax.set_mesh(mesh):
            state = init_dp_state(model, opt, jax.random.PRNGKey(0), mesh)
            step = make_dp_train_step(model, opt, mesh, planes=planes,
                                      donate=False)
            data = SyntheticLMData(seed=0, global_batch=8, seq_len=16,
                                   vocab=cfg.vocab_size)
            losses = []
            for _ in range(steps):
                batch = {k: jnp.asarray(v) for k, v in data.next().items()}
                state, m = step(state, batch, bm)
                losses.append(float(m["loss"]))
            ef = max(float(jnp.max(jnp.abs(l)))
                     for l in jax.tree.leaves(state["ef"]))
        return losses, ef

    comp, ef = fit(planes=2)
    exact, ef0 = fit(planes=0)
    assert all(np.isfinite(comp)), comp
    assert comp[-1] < comp[0], comp
    assert ef > 0 and ef0 == 0.0, (ef, ef0)
    # 2-plane fp8 + EF stays within a tight band of the exact-psum path
    assert abs(comp[-1] - exact[-1]) < 0.05, (comp, exact)
    print("OK", comp, exact)
    """
    assert "OK" in run_py(code)


@pytest.mark.slow
def test_elastic_reshard_checkpoint():
    """Save on a 4-device mesh, restore onto 8 devices — loss continues."""
    code = """
    import jax, jax.numpy as jnp, numpy as np, tempfile, os
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.dist import sharding as shd
    from repro import ckpt as ckpt_lib
    from repro.data import SyntheticLMData

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    tmp = tempfile.mkdtemp()

    def fit(mesh_shape, restore, steps):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            state = {"params": params, "opt": opt.init(params)}
            specs = shd.to_named(shd.state_specs(state, mesh), mesh)
            if restore:
                tree, meta, step = ckpt_lib.restore(tmp)
                state = jax.device_put(
                    jax.tree.map(lambda r, a: jnp.asarray(a, r.dtype),
                                 state, tree), specs)
            else:
                state = jax.device_put(state, specs)
            data = SyntheticLMData(seed=0, global_batch=4, seq_len=16,
                                   vocab=cfg.vocab_size)
            def step_fn(state, batch):
                def loss_fn(p):
                    return model.loss(p, batch)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(state["params"])
                p2, o2 = opt.update(state["params"], g, state["opt"])
                return {"params": p2, "opt": o2}, l
            js = jax.jit(step_fn, in_shardings=(specs, None))
            l = None
            for _ in range(steps):
                state, l = js(state, {k: jnp.asarray(v) for k, v in data.next().items()})
            ckpt_lib.save(tmp, steps, state)
            return float(l)

    l1 = fit((2, 2), restore=False, steps=3)   # 4 chips
    l2 = fit((2, 4), restore=True, steps=2)    # elastic: 8 chips
    assert np.isfinite(l2) and l2 < l1 + 0.5, (l1, l2)
    print("OK", l1, l2)
    """
    assert "OK" in run_py(code)
