"""Quantized KV-cache blocks: pack helpers, pool layout, engine token
parity against the fp-KV oracle (EXACT match — the dequantized product the
quantized path computes is bitwise what the oracle stores), spec-mode
parity, the HAQ-style kv-bits action plumbing (env groups + latency
evaluator), sharding specs, and the three serving-loop regression fixes
that rode along (spec-window re-grant after preemption, bounded metrics
buffers, length-aware admission)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.quant.pack import (
    kv_dequantize,
    kv_pack_int4,
    kv_qdq,
    kv_quantize,
    kv_unpack_int4,
)
from repro.quant.qat import policy_for
from repro.serve import PagedCachePool, ServeEngine, SlotCachePool
from repro.spec import SpecConfig
from repro.train.serve import quantize_for_serving

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def glm4():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(RNG),
                                   policy_for(model, default_bits=4))
    return cfg, model, sparams


def _prompt(cfg, n, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab_size))


def _run(model, sparams, cfg, *, num_slots=3, max_len=24, gens=(6, 6, 6),
         **kw):
    eng = ServeEngine(model, sparams, num_slots=num_slots, max_len=max_len,
                      **kw)
    rids = [eng.submit(_prompt(cfg, 3 + 2 * s, s), max_new_tokens=g)
            for s, g in enumerate(gens, start=1)]
    eng.run_until_drained()
    return [eng.output(r) for r in rids], eng


# --------------------------------------------------------------- kv helpers
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_kv_quantize_roundtrip(bits):
    qmax = float(2 ** (bits - 1) - 1)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 3, 16)),
                    jnp.float32)
    codes, scale = kv_quantize(x, qmax)
    assert codes.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(codes))) <= qmax
    # QDQ == dequantize(quantize): the oracle-storage identity
    np.testing.assert_array_equal(np.asarray(kv_dequantize(codes, scale)),
                                  np.asarray(kv_qdq(x, qmax)))
    # reconstruction error bounded by half a step per head row
    step = np.asarray(scale)[..., None]
    err = np.abs(np.asarray(kv_dequantize(codes, scale)) - np.asarray(x))
    assert np.all(err <= 0.5 * step + 1e-6)


def test_kv_quantize_zero_row_yields_zero_codes():
    codes, scale = kv_quantize(jnp.zeros((2, 3, 8)), 7.0)
    np.testing.assert_array_equal(np.asarray(codes), 0)
    np.testing.assert_array_equal(np.asarray(scale), 0.0)
    np.testing.assert_array_equal(np.asarray(kv_dequantize(codes, scale)), 0.0)


def test_kv_int4_nibble_roundtrip():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(-7, 8, size=(4, 2, 16)), jnp.int8)
    packed = kv_pack_int4(codes)
    assert packed.dtype == jnp.uint8 and packed.shape == (4, 2, 8)
    np.testing.assert_array_equal(np.asarray(kv_unpack_int4(packed)),
                                  np.asarray(codes))


# --------------------------------------------------------------- pool layout
def test_paged_pool_quantized_layout(glm4):
    cfg, model, _ = glm4
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    pool = PagedCachePool(model, 2, 32, block_size=8, kv_bits=8)
    NB = pool.num_blocks
    assert pool.cache["k"].dtype == jnp.int8
    assert pool.cache["k"].shape == (L, NB, 8, KV, hd)
    assert pool.cache["k_scale"].shape == (L, NB, 8, KV)
    assert pool.cache["k_scale"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(pool.cache["kv_qmax"]), 127.0)
    assert set(pool.paged_keys) == {"k", "v", "k_scale", "v_scale"}
    # uniform 4-bit: nibble-packed container at half the code bytes
    p4 = PagedCachePool(model, 2, 32, block_size=8, kv_bits=4)
    assert p4.cache["k"].dtype == jnp.uint8
    assert p4.cache["k"].shape == (L, p4.num_blocks, 8, KV, hd // 2)
    # mixed grid including 4 stays int8 (bits are data, not shape)
    pm = PagedCachePool(model, 2, 32, block_size=8, kv_bits=[8, 4][:L])
    assert pm.cache["k"].dtype == jnp.int8
    # oracle: fp32 value storage, no scale leaves
    po = PagedCachePool(model, 2, 32, block_size=8, kv_bits=4, kv_oracle=True)
    assert po.cache["k"].dtype == jnp.float32
    assert "k_scale" not in po.cache and "kv_qmax" in po.cache


def test_paged_pool_quantized_cache_bytes_ratio(glm4):
    """int4 KV blocks must cost well under half the fp16 bytes per block
    (codes at hd/2 bytes + one f32 scale per token-head)."""
    cfg, model, _ = glm4
    fp = PagedCachePool(model, 2, 32, block_size=8)
    q4 = PagedCachePool(model, 2, 32, block_size=8, kv_bits=4)
    per_block_fp = fp.cache_bytes() / fp.num_blocks
    per_block_q4 = q4.cache_bytes() / q4.num_blocks
    assert per_block_q4 < 0.5 * per_block_fp


def test_paged_pool_kv_validation(glm4):
    cfg, model, _ = glm4
    with pytest.raises(ValueError, match="kv_oracle requires"):
        PagedCachePool(model, 2, 32, kv_oracle=True)
    with pytest.raises(ValueError, match="2..8"):
        PagedCachePool(model, 2, 32, kv_bits=9)
    with pytest.raises(ValueError, match="entries for"):
        PagedCachePool(model, 2, 32, kv_bits=[8, 8, 8, 8, 8])
    rw = build_model(get_config("rwkv6-1.6b", smoke=True))
    with pytest.raises(ValueError, match="O\\(1\\) recurrent"):
        PagedCachePool(rw, 2, 32, kv_bits=8)


def test_engine_rejects_kv_bits_on_slot_pool(glm4):
    cfg, model, sparams = glm4
    with pytest.raises(ValueError, match="cache='paged'"):
        ServeEngine(model, sparams, cache="slot", kv_bits=8)


# ------------------------------------------------------- engine token parity
@pytest.mark.parametrize("kv_bits", [8, 4])
def test_engine_quantized_matches_oracle_exact(glm4, kv_bits):
    """The tentpole parity gate: a quantized-KV engine and an fp-KV oracle
    engine (same qmax, values stored as exact QDQ floats) emit IDENTICAL
    tokens — the dequantized codes·scale product is bitwise the stored
    oracle value, so this is equality, not allclose."""
    cfg, model, sparams = glm4
    got, _ = _run(model, sparams, cfg, cache="paged", kv_bits=kv_bits)
    want, _ = _run(model, sparams, cfg, cache="paged", kv_bits=kv_bits,
                   kv_oracle=True)
    assert got == want


def test_engine_mixed_kv_grid_matches_oracle(glm4):
    cfg, model, sparams = glm4
    bits = [8, 3][:cfg.num_layers]
    got, eng = _run(model, sparams, cfg, cache="paged", kv_bits=bits)
    want, _ = _run(model, sparams, cfg, cache="paged", kv_bits=bits,
                   kv_oracle=True)
    assert got == want
    assert eng.metrics()["kv_bits"] == bits


def test_engine_quantized_spec_matches_plain_decode(glm4):
    """Greedy speculative decoding over quantized blocks is token-identical
    to plain quantized decode (drafts read/write the same quantized blocks
    through the same tables; the recurrent snapshot skips scale leaves)."""
    cfg, model, sparams = glm4
    plain, _ = _run(model, sparams, cfg, cache="paged", kv_bits=4)
    spec, eng = _run(model, sparams, cfg, cache="paged", kv_bits=4,
                     spec=SpecConfig(k=2, draft_bits=3))
    assert spec == plain
    assert eng.metrics()["spec"]["windows"] > 0


def test_engine_quantized_preemption_parity(glm4):
    """Block exhaustion under quantized KV preempts-and-replays without
    changing any client-visible stream."""
    cfg, model, sparams = glm4
    roomy, _ = _run(model, sparams, cfg, cache="paged", kv_bits=4,
                    block_size=4, gens=(10, 10, 10))
    tight, eng = _run(model, sparams, cfg, cache="paged", kv_bits=4,
                      block_size=4, num_blocks=7, gens=(10, 10, 10))
    assert tight == roomy
    assert eng.metrics()["preemptions"] > 0


# ------------------------------------------------------------ action plumbing
def test_kv_quant_groups(glm4):
    cfg, model, _ = glm4
    groups = model.kv_quant_groups(seq_len=128)
    assert [g.name for g in groups] == [f"kv.L{l:02d}"
                                        for l in range(cfg.num_layers)]
    g = groups[0]
    assert g.n_macs == 0
    assert g.n_weights == 2 * 128 * cfg.num_kv_heads * cfg.hd
    assert g.path == ("kv", 0)


def test_quant_env_kv_groups_extend_episode(glm4):
    from repro.core import costmodel
    from repro.core.env import QuantEnv

    cfg, model, _ = glm4
    wg = model.quant_groups(seq_len=64)
    kvg = model.kv_quant_groups(seq_len=64)
    env = QuantEnv(groups=list(wg), evaluate=lambda bits: 1.0,
                   weight_std={}, kv_groups=list(kvg))
    assert env.T == len(wg) + len(kvg)
    # walk the whole episode; the kv steps land at the tail
    obs = env.reset()
    done = False
    while not done:
        obs, r, done, info = env.step(0)  # always pick the lowest bitwidth
    assert info["group"] == kvg[-1].name
    assert all(info["bits"][g.name] == 2 for g in kvg)
    # SQ prices the kv groups (memory-only: n_macs = 0 still contributes)
    sq_all8 = costmodel.state_of_quantization(
        [8] * env.T, env.groups)
    assert info["quant"] < sq_all8


def test_engine_latency_evaluator_parses_kv_bits(glm4, monkeypatch):
    from repro.autotune.workers import EngineLatencyEvaluator

    cfg, model, sparams = glm4
    ev = EngineLatencyEvaluator(model, model.init(RNG), num_slots=2,
                                decode_steps=2, warmup_steps=1,
                                kv_quant=True)
    assert ev.kv_group_names == tuple(
        g.name for g in model.kv_quant_groups())
    seen = {}
    real_from_params = ServeEngine.from_params.__func__

    def spy(cls, mdl, params, policy, **kw):
        seen["kv_bits"] = kw.get("kv_bits")
        return real_from_params(cls, mdl, params, policy, **kw)

    monkeypatch.setattr(ServeEngine, "from_params", classmethod(spy))
    bits = {n: 4 for n in ev.weight_group_names}
    bits.update({n: 3 for n in ev.kv_group_names})
    lat, ref = ev(bits)
    assert seen["kv_bits"] == [3] * cfg.num_layers
    assert lat > 0 and ref > 0


def test_cache_specs_for_quantized_pool(glm4):
    from repro.dist.sharding import cache_specs
    from jax.sharding import Mesh, PartitionSpec as P

    cfg, model, _ = glm4
    pool = PagedCachePool(model, 2, 32, block_size=8, kv_bits=8)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    specs = cache_specs(pool.step_cache(), mesh)
    # scale leaves shard on the block axis (axis 1) like the code leaves;
    # the per-layer qmax vector has no per-sequence axis -> replicated
    assert specs["k_scale"][1] == specs["k"][1]
    assert all(s is None for i, s in enumerate(specs["k_scale"]) if i != 1)
    assert specs["kv_qmax"] == P()
    assert specs["block_tables"] == P()


# ------------------------------------------------- serving-loop regressions
def test_reserve_for_spec_regrants_after_preemption(glm4):
    """Regression: a preemption frees blocks mid-reservation, so the
    surviving (older) row's spec window must be retried at full size —
    previously the shrunk (possibly 0) window was kept, silently losing
    speculation for the step."""
    from repro.serve.queue import AdmissionQueue
    from repro.serve.request import Request, SamplingParams
    from repro.serve.scheduler import ContinuousScheduler

    cfg, model, _ = glm4
    # 2 rows; pool with 4 usable blocks of 4 tokens each
    pool = PagedCachePool(model, 2, 16, block_size=4, num_blocks=5)
    sched = ContinuousScheduler(pool, AdmissionQueue())
    for rid in (0, 1):
        req = Request(rid, np.asarray([1, 2, 3]), 8, SamplingParams(), None)
        slot = pool.alloc_seq()
        assert pool.ensure(slot, 8)  # two blocks each -> pool exhausted
        sched.start(req, slot, first_token=1, cached_len=8)
    assert pool.num_free_blocks == 0
    want = {s: 4 for s in sched.running}
    granted, preempted = sched.reserve_for_spec(want)
    # the youngest was preempted; its 2 freed blocks must re-enable the
    # oldest's FULL window (8 cached + 4 + 1 = 13 tokens -> 4 blocks)
    assert len(preempted) == 1
    assert preempted[0].request_id == 1
    assert granted == {0: 4}


def test_decode_metrics_buffers_are_bounded(glm4):
    cfg, model, sparams = glm4
    _, eng = _run(model, sparams, cfg, cache="paged", metrics_window=4,
                  gens=(8, 8, 8))
    assert eng._c_decode_steps.value > 4  # ran longer than the window
    assert len(eng._h_decode.samples()) == 4
    assert len(eng._h_decode_tok.samples()) == 4
    m = eng.metrics()
    assert m["decode_step_p50_ms"] > 0


def test_decode_metrics_parity_on_short_runs(glm4):
    """A run shorter than the window sees every sample — the percentile
    metrics are computed over the identical full history."""
    cfg, model, sparams = glm4
    _, eng = _run(model, sparams, cfg, cache="paged", gens=(4, 4, 4))
    steps = int(eng._c_decode_steps.value)
    assert steps < 512  # default window
    assert len(eng._h_decode.samples()) == steps
    assert len(eng._h_decode_tok.samples()) == steps


def test_overlength_prompt_rejected_engine_keeps_serving(glm4):
    cfg, model, sparams = glm4
    for kind in ("paged", "slot"):
        eng = ServeEngine(model, sparams, num_slots=2, max_len=16,
                          cache=kind)
        with pytest.raises(ValueError, match="cache tokens"):
            eng.submit(_prompt(cfg, 20, 0), max_new_tokens=4)
        rid = eng.submit(_prompt(cfg, 4, 1), max_new_tokens=3)
        eng.run_until_drained()
        assert len(eng.output(rid)) == 3


def test_pools_can_admit_honors_length(glm4):
    """Regression: both pools must refuse sequences beyond per-row
    capacity at ADMISSION time (blocks_needed used to clamp, silently
    truncating an over-length sequence)."""
    cfg, model, _ = glm4
    slot = SlotCachePool(model, 2, 16)
    assert slot.can_admit(16) and not slot.can_admit(17)
    paged = PagedCachePool(model, 2, 16, block_size=4)
    assert paged.can_admit(16) and not paged.can_admit(17)


def test_block_table_upload_cached_across_steady_steps(glm4):
    cfg, model, _ = glm4
    pool = PagedCachePool(model, 2, 16, block_size=4)
    seq = pool.alloc_seq()
    assert pool.ensure(seq, 8)
    bt1 = pool.step_cache()["block_tables"]
    bt2 = pool.step_cache()["block_tables"]
    assert bt1 is bt2  # steady state: same device buffer, no re-upload
    assert pool.ensure(seq, 13)  # growth dirties the table
    bt3 = pool.step_cache()["block_tables"]
    assert bt3 is not bt2
    pool.free_seq(seq)
    bt4 = pool.step_cache()["block_tables"]
    assert bt4 is not bt3
