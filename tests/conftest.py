import os
import sys

# Tests run the pure-jnp reference path by default (fast on 1 CPU core);
# kernel tests opt into Pallas interpret mode explicitly.
os.environ.setdefault("REPRO_PALLAS", "ref")
# NEVER set xla_force_host_platform_device_count here — smoke tests must
# see exactly 1 device (the dry-run owns the 512-device override).

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (deselect with -m 'not slow')")
