"""Serving path: packed-bitplane weights vs QAT QDQ, byte scaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.quant.pack import Packed
from repro.quant.qat import bits_assignment, policy_for, quantize_params
from repro.train.serve import make_decode_step, quantize_for_serving

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["glm4-9b", "moonshot-v1-16b-a3b",
                                  "rwkv6-1.6b", "hymba-1.5b"])
def test_serve_matches_qdq(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    pol = policy_for(model, default_bits=4)
    sparams = quantize_for_serving(model, params, pol)
    cache = model.init_cache(batch=2, max_len=16)
    toks = jax.random.randint(RNG, (2, 1), 0, cfg.vocab_size)
    logits, _ = make_decode_step(model, donate=False)(sparams, cache, toks)
    bm = {k: jnp.asarray(v) for k, v in bits_assignment(
        model.quant_groups(), pol).items()}
    qp = quantize_params(params, bm, model.quant_groups())
    ref, _ = model.decode_step(qp, model.init_cache(2, 16), toks)
    assert float(jnp.max(jnp.abs(logits - ref))) < 0.1


def test_weight_bytes_scale_with_policy_bits():
    """The paper's entire serving claim: stored bytes ∝ chosen bitwidths."""
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)

    def packed_bytes(bits):
        sp = quantize_for_serving(model, params, policy_for(model, bits))
        # blocks only: boundary groups (embed/lm_head) are frozen at 8 bits
        return sum(l.planes.size for l in jax.tree.leaves(
            sp["blocks"], is_leaf=lambda x: isinstance(x, Packed))
            if isinstance(l, Packed))

    b2, b4, b8 = packed_bytes(2), packed_bytes(4), packed_bytes(8)
    assert b4 == 2 * b2 and b8 == 2 * b4


def test_heterogeneous_policy_respected():
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    pol = policy_for(model, default_bits=8)
    target = [g for g in model.quant_groups() if g.name == "L00.attn.wq"][0]
    pol = pol.with_bits(target.name, 3)
    sp = quantize_for_serving(model, params, pol)
    wq = sp["blocks"][0][0]["attn"]["wq"]
    assert isinstance(wq, Packed) and wq.bits == 3
    wk = sp["blocks"][0][0]["attn"]["wk"]
    assert wk.bits == 8
