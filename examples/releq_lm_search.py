"""ReLeQ on a language model: search per-matrix bitwidths for a reduced
glm4-family decoder, driving the QAT train/eval steps as the environment.

    PYTHONPATH=src python examples/releq_lm_search.py [--episodes 12]

This is the scale-out configuration of DESIGN.md §4 running on one host:
the environment evaluator = short QAT finetune + likelihood-ratio proxy;
bitwidths enter the jit'd step as data so every candidate shares one
executable.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.search import ReLeQSearch, make_lm_env_factory
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim import AdamW
from repro.train.train_step import init_state, make_train_step
from repro.quant.qat import bits_assignment, policy_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--episodes", type=int, default=12)
    ap.add_argument("--pretrain-steps", type=int, default=120)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    data = SyntheticLMData(seed=0, global_batch=8, seq_len=32,
                           vocab=cfg.vocab_size)

    print(f"== pretraining reduced {args.arch} ==")
    opt = AdamW(lr=3e-3)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = make_train_step(model, opt)
    bm = {k: jax.numpy.asarray(v) for k, v in bits_assignment(
        model.quant_groups(), policy_for(model, 8)).items()}
    for i in range(args.pretrain_steps):
        state, m = step(state, data.next(), bm)
    print(f"pretrain loss: {float(m['loss']):.3f}")

    print("\n== ReLeQ search over per-matrix bitwidths ==")
    factory = make_lm_env_factory(model, state["params"], data,
                                  finetune_steps=2)
    search = ReLeQSearch(factory, seed=0)
    result = search.run(episodes=args.episodes, log_every=4)
    bits = result.best_bits
    print(f"\nbest policy (avg {np.mean(list(bits.values())):.2f} bits):")
    for name, b in list(bits.items())[:12]:
        print(f"  {name:20s} {b}")
    if len(bits) > 12:
        print(f"  ... (+{len(bits) - 12} groups)")


if __name__ == "__main__":
    main()
