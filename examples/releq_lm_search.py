"""ReLeQ on a language model, served by the asynchronous autotune stack:
search per-matrix bitwidths for a reduced glm4-family decoder with the
QAT train/eval steps as the accuracy evaluator and the analytic TPU
decode roofline as the hardware signal.

    PYTHONPATH=src python examples/releq_lm_search.py [--episodes 12]

This drives ``repro.autotune.AutotuneService`` (the scale-out successor
to the lockstep loop of DESIGN.md §4) on one host: episode rollouts are
decoupled from the short-retrain evaluations, which run on a worker
pool and complete out of order; every evaluated candidate lands in a
Pareto archive over (rel-accuracy, SQ, latency).  ``--lockstep`` runs
the faithful single-env ``ReLeQSearch`` loop instead for comparison —
and ``python -m repro.launch.autotune --deploy`` takes the archive all
the way into a live ServeEngine.
"""
import argparse

import jax
import numpy as np

from repro.autotune import (
    AnalyticLatencyEvaluator,
    AutotuneService,
    ServiceConfig,
)
from repro.configs import get_config
from repro.core.search import ReLeQSearch, make_lm_env_factory
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim import AdamW
from repro.train.train_step import init_state, make_train_step
from repro.quant.qat import bits_assignment, policy_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--episodes", type=int, default=12)
    ap.add_argument("--pretrain-steps", type=int, default=120)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--lockstep", action="store_true",
                    help="run the paper-faithful synchronous loop instead")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    data = SyntheticLMData(seed=0, global_batch=8, seq_len=32,
                           vocab=cfg.vocab_size)

    print(f"== pretraining reduced {args.arch} ==")
    opt = AdamW(lr=3e-3)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = make_train_step(model, opt)
    bm = {k: jax.numpy.asarray(v) for k, v in bits_assignment(
        model.quant_groups(), policy_for(model, 8)).items()}
    for i in range(args.pretrain_steps):
        state, m = step(state, data.next(), bm)
    print(f"pretrain loss: {float(m['loss']):.3f}")

    factory = make_lm_env_factory(model, state["params"], data,
                                  finetune_steps=2)
    if args.lockstep:
        print("\n== lockstep ReLeQ search ==")
        result = ReLeQSearch(factory, seed=0).run(
            episodes=args.episodes, log_every=4)
    else:
        print(f"\n== async ReLeQ search ({args.workers} workers) ==")
        service = AutotuneService(
            factory,
            latency_eval=AnalyticLatencyEvaluator(model.quant_groups(),
                                                  model.frozen_bits()),
            config=ServiceConfig(num_workers=args.workers,
                                 batch_episodes=2, seed=0))
        result = service.run(episodes=args.episodes, log_every=4)
        service.shutdown()
        s = result.service_stats
        print(f"throughput {s['episodes_per_s']:.2f} episodes/s, "
              f"{s['updates']} PPO updates, "
              f"retrain cache hit-rate {result.cache_stats['hit_rate']:.2f}")
        print(f"Pareto archive: {s['archive_size']} non-dominated policies")
        for e in service.archive.entries():
            print(f"  acc={e.acc:.3f} sq={e.sq:.3f} "
                  f"lat={e.latency:.2e}s "
                  f"avg_bits={np.mean([b for _, b in e.bits]):.2f}")

    bits = result.best_bits
    print(f"\nbest policy (avg {np.mean(list(bits.values())):.2f} bits):")
    for name, b in list(bits.items())[:12]:
        print(f"  {name:20s} {b}")
    if len(bits) > 12:
        print(f"  ... (+{len(bits) - 12} groups)")


if __name__ == "__main__":
    main()
