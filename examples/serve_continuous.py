"""Continuous-batching serving of a ReLeQ-quantized LM.

    PYTHONPATH=src python examples/serve_continuous.py [--bits 4]

Demonstrates the ``repro.serve`` engine end-to-end: requests with
different prompt and output lengths arrive *while others are mid-decode*,
get admitted into freed KV-cache slots, and each step packs every running
sequence into one jit'd decode over the bit-packed weights.  Contrast
with ``examples/serve_quantized.py`` (the one-shot fixed-batch loop).
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import SamplingParams, ServeEngine
from repro.train.serve import quantize_for_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--num-slots", type=int, default=3)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = policy_for(model, default_bits=args.bits)
    sparams = quantize_for_serving(model, params, policy)
    engine = ServeEngine(model, sparams, num_slots=args.num_slots,
                         max_len=48)
    print(f"{cfg.name}: {args.num_slots} slots, policy avg "
          f"{policy.average_bits():.1f} bits")

    rng = np.random.default_rng(7)
    sampling = SamplingParams(temperature=args.temperature, seed=3)
    # wave 1: fill every slot plus one queued request
    for i in range(args.num_slots + 1):
        engine.submit(rng.integers(0, cfg.vocab_size, 6 + i),
                      max_new_tokens=6 + 2 * i, sampling=sampling)
    for _ in range(4):
        engine.step()
    # wave 2 arrives mid-decode and takes slots as they free up
    for i in range(2):
        engine.submit(rng.integers(0, cfg.vocab_size, 5),
                      max_new_tokens=5, sampling=sampling)
    engine.run_until_drained()

    m = engine.metrics()
    print(f"tokens/s={m['tokens_per_s']:.1f} "
          f"occupancy={m['mean_occupancy']:.2f} over "
          f"{m['decode_steps']} decode steps")
    for r in m["requests"]:
        print(f"  req {r['id']}: prompt={r['prompt_len']} "
              f"tokens={r['new_tokens']} ttft={r['ttft_steps']} steps")
    print("req 0 tokens:", engine.output(0))


if __name__ == "__main__":
    main()
