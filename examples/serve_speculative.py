"""Speculative decoding with a quantized self-draft off the Pareto archive.

    PYTHONPATH=src python examples/serve_speculative.py [--spec-k 6]

The ReLeQ search leaves behind a Pareto archive of (accuracy, SQ) per-
layer bitwidth policies.  ``repro.spec`` turns the cheap end of that
frontier into a *draft model for free*: the same bit-packed weights the
target serves are re-read at fewer bitplanes (no second copy, no second
KV cache — draft and target share the paged block tables), the low-bit
view proposes ``k`` tokens per window, and one batched verify call
through the chunked-prefill executable scores all k+1 positions at the
full-precision policy.  Exact rejection sampling keeps the output
distribution identical to serving without speculation — greedy output
is token-identical, which this script checks.

Walkthrough: archive -> DraftSelector -> SpecConfig -> ServeEngine,
with a side-by-side non-speculative run for the parity + speed story.
"""
import argparse

import jax
import numpy as np

from repro.autotune.archive import ParetoArchive
from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import ServeEngine
from repro.spec import DraftSelector, SpecConfig, snap_params_to_grid
from repro.train.serve import quantize_for_serving


def serve(model, sparams, prompts, gen, spec=None):
    engine = ServeEngine(model, sparams, num_slots=len(prompts),
                         max_len=prompts.shape[1] + gen + 1,
                         block_size=8, prefill_chunk=8, spec=spec)
    ids = [engine.submit(p, max_new_tokens=gen) for p in prompts]
    engine.run_until_drained()
    return [engine.output(i) for i in ids], engine.metrics()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--spec-k", type=int, default=6)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # snapping weights to the 2-bit grid stands in for QAT-trained
    # checkpoints, where low-bit views genuinely agree with the target
    params = snap_params_to_grid(model, params, 2)
    sparams = quantize_for_serving(model, params, policy_for(model, 8))

    # the archive a real search leaves behind: one frontier entry per
    # accuracy/cost trade-off.  DraftSelector picks the cheapest entry
    # above the accuracy floor — draft cost scales with plane count.
    arc = ParetoArchive(objectives=("acc", "sq"))
    groups = [g.name for g in model.quant_groups()]
    for bits, acc, sq in ((2, 0.97, 0.10), (4, 0.99, 0.30), (8, 1.0, 0.9)):
        pol = policy_for(model, bits)
        arc.add({n: pol.get(n) for n in groups}, acc=acc, sq=sq)
    draft_policy = DraftSelector(acc_floor=0.95).policy(model, arc)
    picked = DraftSelector(acc_floor=0.95).select(arc)
    print(f"archive has {len(arc.entries())} entries; selector picked "
          f"avg {np.mean([b for _, b in picked.bits]):.1f} bits "
          f"(acc {picked.acc:.2f})")

    rng = np.random.default_rng(11)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8))

    plain, m0 = serve(model, sparams, prompts, args.gen)
    spec = SpecConfig(k=args.spec_k, draft_policy=draft_policy)
    fast, m1 = serve(model, sparams, prompts, args.gen, spec=spec)

    assert fast == plain, "speculation must be distribution-exact"
    s = m1["spec"]
    print(f"greedy outputs token-identical across {sum(map(len, plain))} "
          f"tokens (exactness gate)")
    print(f"spec k={s['k']}: acceptance={s['acceptance_rate']:.3f} "
          f"({s['accepted']}/{s['proposed']}), "
          f"{m0['decode_steps']} -> {m1['decode_steps']} decode steps")
    if "decode_tok_p50_ms" in m0 and "decode_tok_p50_ms" in m1:
        print(f"p50 per emitted token: {m0['decode_tok_p50_ms']:.2f} ms "
              f"plain -> {m1['decode_tok_p50_ms']:.2f} ms speculative")
    print("req 0 tokens:", fast[0])


if __name__ == "__main__":
    main()
