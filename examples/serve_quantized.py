"""Serve a quantized LM: pack ReLeQ bitwidths into bitplanes and decode.

    PYTHONPATH=src python examples/serve_quantized.py [--bits 4]

Shows the serving path end-to-end: train params -> quantize_for_serving
(per-layer bitplane packing, DESIGN.md §3) -> batched prefill + decode
loop with the packed weights, reporting packed-vs-bf16 weight bytes (the
quantity that sets decode latency on TPU).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.quant.pack import Packed
from repro.quant.qat import policy_for
from repro.train.serve import make_decode_step, quantize_for_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = policy_for(model, default_bits=args.bits)
    sparams = quantize_for_serving(model, params, policy)

    bf16_bytes = sum(x.size * 2 for x in jax.tree.leaves(params))
    packed_bytes = sum(
        x.planes.size + x.scale.size * 4
        for x in jax.tree.leaves(sparams, is_leaf=lambda l: isinstance(l, Packed))
        if isinstance(x, Packed))
    print(f"weights: bf16 {bf16_bytes/1e6:.2f} MB -> packed "
          f"{packed_bytes/1e6:.2f} MB at {args.bits} bits "
          f"(matmul weights only)")

    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    logits, cache = model.prefill(sparams, tokens=prompt,
                                  max_len=8 + args.steps + 1)
    dec = make_decode_step(model, donate=False)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(args.steps):
        logits, cache = dec(sparams, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    dt = (time.time() - t0) / args.steps
    seqs = jnp.concatenate(out, axis=1)
    print(f"decoded {args.steps} steps × batch {B} "
          f"({dt*1e3:.1f} ms/step on CPU ref path)")
    print("sample token ids:", seqs[0].tolist())


if __name__ == "__main__":
    main()
