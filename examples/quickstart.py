"""Quickstart: ReLeQ end-to-end on the paper's LeNet in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. pretrain LeNet (synthetic-learnable MNIST stand-in, DESIGN.md §3),
2. run the PPO agent over per-layer bitwidths (paper Fig 4 loop),
3. long-retrain at the found policy and report accuracy loss + the
   hardware speedups the paper's cost models predict.
"""
import numpy as np

from repro.cnn import CNNTask
from repro.core import costmodel as cm
from repro.core.search import ReLeQSearch


def main():
    print("== pretraining LeNet (fp32) ==")
    task = CNNTask("lenet", seed=0)
    fp_acc = task.pretrain(300)
    print(f"full-precision accuracy: {fp_acc:.3f}")

    print("\n== ReLeQ search (PPO + LSTM agent, per-layer bitwidths) ==")
    search = ReLeQSearch(task.make_env_factory(retrain_steps=2), seed=0)
    result = search.run(episodes=30, log_every=10)
    bits = result.best_bits
    names = task.names
    print("bitwidths:", {n: bits[n] for n in names})
    print(f"average bits: {np.mean([bits[n] for n in names]):.2f}")

    print("\n== long retrain at the found policy (paper's final step) ==")
    rel = task.long_retrain(bits, steps=150)
    print(f"relative accuracy after retrain: {rel:.4f} "
          f"(acc loss {max(0.0, (1 - rel) * 100):.2f}%)")

    vec = [bits[n] for n in names]
    print("\n== hardware benefit (paper cost models) ==")
    print(f"Stripes speedup vs 8-bit : {cm.speedup_vs_8bit(cm.stripes_time, vec, task.groups):.2f}x")
    print(f"Stripes energy reduction : {cm.energy_reduction_vs_8bit(vec, task.groups):.2f}x")
    print(f"TVM-CPU speedup vs 8-bit : {cm.speedup_vs_8bit(cm.tvm_cpu_time, vec, task.groups):.2f}x")
    print(f"TPU-v5e decode speedup   : {cm.speedup_vs_8bit(cm.tpu_decode_time, vec, task.groups):.2f}x")


if __name__ == "__main__":
    main()
