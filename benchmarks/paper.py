"""One function per paper table/figure (DESIGN.md §7 maps them).

Each returns a list of CSV rows ``(name, us_per_call, derived)`` and dumps
richer JSON into benchmarks/results/.  RL-driven artifacts share one
pretrained task + one search run per network (quick mode budgets for a
single CPU core).
"""
from __future__ import annotations

import json
import os
import time
from functools import lru_cache

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(RESULTS, exist_ok=True)


def _dump(name: str, obj):
    from repro.obs import run_provenance

    if isinstance(obj, dict):
        obj = {"provenance": run_provenance(), **obj}
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=2, default=float)


# ---------------------------------------------------------------------------
# shared artifacts
# ---------------------------------------------------------------------------

QUICK_NETS = ("lenet", "simplenet")
FULL_NETS = ("lenet", "simplenet", "svhn10", "resnet20", "vgg11", "alexnet",
             "mobilenet")


@lru_cache(maxsize=None)
def get_task(net: str, pretrain_steps: int = 300):
    from repro.cnn import CNNTask

    t0 = time.time()
    task = CNNTask(net, seed=0)
    task.pretrain(pretrain_steps)
    task._pretrain_s = time.time() - t0
    return task


@lru_cache(maxsize=None)
def get_search(net: str, episodes: int = 30, reward_mode: str = "proposed",
               seed: int = 0, clip_eps: float = 0.1, use_lstm: bool = True,
               retrain_steps: int = 2):
    from repro.core.ppo import PPOConfig
    from repro.core.search import ReLeQSearch

    task = get_task(net)
    factory = task.make_env_factory(retrain_steps=retrain_steps,
                                    reward_mode=reward_mode)
    cfg = PPOConfig(clip_eps=clip_eps, use_lstm=use_lstm)
    search = ReLeQSearch(factory, num_envs=1, seed=seed, ppo_config=cfg)
    t0 = time.time()
    result = search.run(episodes=episodes)
    result.wall_s = time.time() - t0
    result.task = task
    return result


def _paper_bits(task):
    """Bits vector for the ReLeQ result, ordered like task.groups."""
    res = get_search(task.model.name)
    return {g.name: res.best_bits[g.name] for g in task.groups}, res


# ---------------------------------------------------------------------------
# Table 2: bitwidths found by ReLeQ + accuracy loss after long retrain
# ---------------------------------------------------------------------------

def table2_bitwidths(nets=QUICK_NETS):
    rows, table = [], []
    for net in nets:
        task = get_task(net)
        bits, res = _paper_bits(task)
        t0 = time.time()
        rel = task.long_retrain(bits, steps=120)
        rec = {
            "network": net, "dataset": task.data.name,
            "bitwidths": [bits[g.name] for g in task.groups],
            "average_bits": float(np.mean([bits[g.name] for g in task.groups])),
            "acc_loss_pct": max(0.0, (1 - rel) * 100),
            "fp_acc": task.fp_acc, "episodes": len(res.episodes),
            "search_wall_s": res.wall_s,
        }
        table.append(rec)
        rows.append((f"table2/{net}", res.wall_s * 1e6 / max(len(res.episodes), 1),
                     f"avg_bits={rec['average_bits']:.2f};acc_loss={rec['acc_loss_pct']:.2f}%"))
    _dump("table2_bitwidths", table)
    return rows


# ---------------------------------------------------------------------------
# Fig 5: action-probability evolution (policy confidence over episodes)
# ---------------------------------------------------------------------------

def fig5_policy_evolution():
    res = get_search("lenet")
    evo = np.stack(res.prob_evolution)       # (episodes, T, A)
    first, last = evo[0], evo[-1]
    conf_gain = float(np.mean(last.max(-1) - first.max(-1)))
    _dump("fig5_policy_evolution", {
        "episodes": evo.shape[0], "layers": evo.shape[1],
        "first_episode_max_prob": first.max(-1).tolist(),
        "last_episode_max_prob": last.max(-1).tolist(),
        "confidence_gain": conf_gain,
    })
    return [("fig5/lenet", 0.0, f"confidence_gain={conf_gain:.3f}")]


# ---------------------------------------------------------------------------
# Fig 6: Pareto frontier + where the ReLeQ point lands
# ---------------------------------------------------------------------------

def fig6_pareto():
    from repro.core.pareto import (distance_to_frontier, enumerate_space,
                                   pareto_frontier)

    task = get_task("lenet")
    t0 = time.time()
    pts = enumerate_space(task.groups,
                          lambda b: task.evaluate_bits(b, retrain_steps=0),
                          bitset=(2, 3, 4, 6, 8))
    wall = time.time() - t0
    front = pareto_frontier(pts)
    bits, _ = _paper_bits(task)
    releq_pt = {"bits": bits,
                "quant": __import__("repro.core.costmodel", fromlist=["x"])
                .state_of_quantization([bits[g.name] for g in task.groups],
                                       task.groups),
                "acc": task.evaluate_bits(bits, retrain_steps=0)}
    d = distance_to_frontier(releq_pt, front)
    _dump("fig6_pareto", {"points": len(pts), "frontier": len(front),
                          "releq_distance_to_frontier": d,
                          "frontier_pts": [(p["quant"], p["acc"]) for p in front]})
    return [("fig6/lenet", wall * 1e6 / len(pts),
             f"points={len(pts)};frontier={len(front)};releq_dist={d:.3f}")]


# ---------------------------------------------------------------------------
# Fig 7: learning curves (acc state / quant state / reward vs episodes)
# ---------------------------------------------------------------------------

def fig7_learning_curves():
    res = get_search("simplenet")
    eps = res.episodes
    accs = [e["acc"] for e in eps]
    quants = [e["quant"] for e in eps]
    rewards = [e["reward"] for e in eps]
    k = max(len(eps) // 4, 1)
    trend = {
        "acc_first_q": float(np.mean(accs[:k])), "acc_last_q": float(np.mean(accs[-k:])),
        "quant_first_q": float(np.mean(quants[:k])), "quant_last_q": float(np.mean(quants[-k:])),
        "reward_first_q": float(np.mean(rewards[:k])), "reward_last_q": float(np.mean(rewards[-k:])),
        "series": {"acc": accs, "quant": quants, "reward": rewards},
    }
    _dump("fig7_learning_curves", trend)
    return [("fig7/simplenet", 0.0,
             f"reward {trend['reward_first_q']:.3f}->{trend['reward_last_q']:.3f};"
             f"quant {trend['quant_first_q']:.3f}->{trend['quant_last_q']:.3f}")]


# ---------------------------------------------------------------------------
# Fig 8 / Fig 9: hardware speedups from the found bitwidths (cost models)
# ---------------------------------------------------------------------------

def fig8_tvm_speedup(nets=QUICK_NETS):
    from repro.core import costmodel as cm

    rows, table = [], []
    for net in nets:
        task = get_task(net)
        bits, _ = _paper_bits(task)
        vec = [bits[g.name] for g in task.groups]
        s = cm.speedup_vs_8bit(cm.tvm_cpu_time, vec, task.groups)
        table.append({"network": net, "tvm_speedup_vs_8bit": s})
        rows.append((f"fig8/{net}", 0.0, f"tvm_speedup={s:.2f}x"))
    _dump("fig8_tvm_speedup", table)
    return rows


def fig9_stripes(nets=QUICK_NETS):
    from repro.core import costmodel as cm

    rows, table = [], []
    for net in nets:
        task = get_task(net)
        bits, _ = _paper_bits(task)
        vec = [bits[g.name] for g in task.groups]
        s = cm.speedup_vs_8bit(cm.stripes_time, vec, task.groups)
        e = cm.energy_reduction_vs_8bit(vec, task.groups)
        t = cm.speedup_vs_8bit(cm.tpu_decode_time, vec, task.groups)
        table.append({"network": net, "stripes_speedup": s,
                      "stripes_energy_reduction": e, "tpu_decode_speedup": t})
        rows.append((f"fig9/{net}", 0.0,
                     f"stripes={s:.2f}x;energy={e:.2f}x;tpu_decode={t:.2f}x"))
    _dump("fig9_stripes", table)
    return rows


# ---------------------------------------------------------------------------
# Table 4: ReLeQ vs ADMM bitwidth selection
# ---------------------------------------------------------------------------

def table4_admm():
    from repro.core import costmodel as cm
    from repro.core.admm_baseline import admm_select

    rows, table = [], []
    for net in ("lenet",):
        task = get_task(net)
        bits, _ = _paper_bits(task)
        vec = [bits[g.name] for g in task.groups]
        avg = float(np.mean(vec))
        admm_bits = admm_select(task.groups, task.weights_by_name(),
                                budget_avg_bits=avg + 0.5)
        admm_vec = [admm_bits[g.name] for g in task.groups]
        rel_r = task.long_retrain(bits, steps=80)
        rel_a = task.long_retrain(admm_bits, steps=80)
        su_tvm = cm.tvm_cpu_time(admm_vec, task.groups) / cm.tvm_cpu_time(vec, task.groups)
        su_str = cm.stripes_time(admm_vec, task.groups) / cm.stripes_time(vec, task.groups)
        en = cm.stripes_energy(admm_vec, task.groups) / cm.stripes_energy(vec, task.groups)
        table.append({"network": net, "releq_bits": vec, "admm_bits": admm_vec,
                      "releq_rel_acc": rel_r, "admm_rel_acc": rel_a,
                      "speedup_tvm": su_tvm, "speedup_stripes": su_str,
                      "energy_improvement": en})
        rows.append((f"table4/{net}", 0.0,
                     f"tvm={su_tvm:.2f}x;stripes={su_str:.2f}x;energy={en:.2f}x"))
    _dump("table4_admm", table)
    return rows


# ---------------------------------------------------------------------------
# Table 5: PPO clipping-parameter sensitivity
# ---------------------------------------------------------------------------

def table5_ppo_clip(episodes: int = 20):
    rows, table = [], []
    for eps in (0.1, 0.2, 0.3):
        res = get_search("lenet", episodes=episodes, clip_eps=eps, seed=3)
        avg_r = float(np.mean([e["reward"] for e in res.episodes]))
        table.append({"clip": eps, "avg_reward": avg_r})
        rows.append((f"table5/eps{eps}", 0.0, f"avg_reward={avg_r:.3f}"))
    _dump("table5_ppo_clip", table)
    return rows


# ---------------------------------------------------------------------------
# Fig 10: reward-formulation ablation
# ---------------------------------------------------------------------------

def fig10_reward_ablation(episodes: int = 20):
    rows, table = [], []
    for mode in ("proposed", "ratio", "difference"):
        res = get_search("lenet", episodes=episodes, reward_mode=mode, seed=5)
        accs = [e["acc"] for e in res.episodes]
        k = max(len(accs) // 4, 1)
        table.append({"mode": mode, "acc_last_q": float(np.mean(accs[-k:])),
                      "acc_mean": float(np.mean(accs))})
        rows.append((f"fig10/{mode}", 0.0,
                     f"acc_last_q={float(np.mean(accs[-k:])):.3f}"))
    _dump("fig10_reward_ablation", table)
    return rows


# ---------------------------------------------------------------------------
# §2.7: LSTM-vs-MLP agent ablation (paper: LSTM ≈1.33× faster convergence)
# ---------------------------------------------------------------------------

def lstm_ablation(episodes: int = 24):
    rows, table = [], []
    for use_lstm in (True, False):
        res = get_search("lenet", episodes=episodes, use_lstm=use_lstm, seed=11)
        rs = [e["reward"] for e in res.episodes]
        k = max(len(rs) // 4, 1)
        table.append({"lstm": use_lstm, "reward_last_q": float(np.mean(rs[-k:]))})
        rows.append((f"lstm_ablation/{'lstm' if use_lstm else 'mlp'}", 0.0,
                     f"reward_last_q={float(np.mean(rs[-k:])):.3f}"))
    _dump("lstm_ablation", table)
    return rows


# ---------------------------------------------------------------------------
# kernels microbench (CPU wall-time of the ref path; TPU gain is the
# cost-model column — no TPU in this container)
# ---------------------------------------------------------------------------

def qmm_microbench():
    import jax
    import jax.numpy as jnp

    from repro.core import costmodel as cm
    from repro.kernels import ref as kref
    from repro.quant.pack import pack_weight

    rows = []
    rng = np.random.default_rng(0)
    K, N, M = 2048, 2048, 8
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    for bits in (2, 4, 8):
        planes, scale = pack_weight(w, bits)
        f = jax.jit(lambda x, p, s: kref.qmm_ref(x, p, s, bits))
        f(x, planes, scale).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            f(x, planes, scale).block_until_ready()
        us = (time.time() - t0) / 5 * 1e6
        # projected TPU decode gain vs bf16 weights: traffic ratio 16/bits
        rows.append((f"qmm_ref/{bits}b", us,
                     f"bytes_ratio_vs_bf16={16 / bits:.1f}x"))
    return rows
