"""§Roofline harness: aggregate the dry-run JSON records into the table.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
prints the per-(arch × shape × mesh) roofline terms, bottleneck, useful
ratio, and fit flag.  ``python -m benchmarks.roofline [--markdown]``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(path: str = DRYRUN_DIR):
    recs = []
    for fn in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def rows(recs):
    out = []
    for r in recs:
        if r.get("status") == "skipped":
            out.append({"cell": f"{r['arch']} × {r['shape']} × {r['mesh']}",
                        "status": "skipped", "why": r.get("reason", "")})
            continue
        rl = r["roofline"]
        out.append({
            "cell": f"{r['arch']} × {r['shape']} × {r['mesh']}",
            "status": "ok",
            "profile": r.get("profile", "?"),
            "t_compute_ms": rl["t_compute"] * 1e3,
            "t_memory_ms": rl["t_memory"] * 1e3,
            "t_collective_ms": rl["t_collective"] * 1e3,
            "bottleneck": rl["bottleneck"],
            "useful": rl["useful_ratio"],
            "roofline_frac": rl.get("roofline_fraction", 0.0),
            "peak_gb": r["memory"]["peak_bytes"] / 1e9,
            "fits_16g": r.get("fits_16g"),
            "collectives": rl.get("collectives", ""),
        })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--dir", default=DRYRUN_DIR)
    args = ap.parse_args()
    rs = rows(load_records(args.dir))
    if args.markdown:
        print("| cell | prof | compute ms | memory ms | coll ms | bottleneck "
              "| useful | roofline | peak GB | fits |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rs:
            if r["status"] == "skipped":
                print(f"| {r['cell']} | — | — | — | — | skipped: {r['why'][:40]}"
                      " | — | — | — | — |")
            else:
                print(f"| {r['cell']} | {r['profile']} | {r['t_compute_ms']:.0f} "
                      f"| {r['t_memory_ms']:.0f} | {r['t_collective_ms']:.0f} "
                      f"| {r['bottleneck']} | {r['useful']:.2f} "
                      f"| {r['roofline_frac']:.3f} | {r['peak_gb']:.2f} "
                      f"| {'Y' if r['fits_16g'] else 'N'} |")
    else:
        for r in rs:
            print(r)


if __name__ == "__main__":
    main()
