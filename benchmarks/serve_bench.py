# Static vs continuous (slot/paged) batching tokens/s at ReLeQ policies.
"""Serving benchmark: ``python -m benchmarks.serve_bench [--arch glm4-9b]``.

One workload of requests with heterogeneous output lengths, served three
ways at each ``--bits`` policy:

- **static**: the legacy fixed-batch loop — each batch decodes until its
  *longest* member finishes, early finishers idle their slot,
- **continuous**: :class:`repro.serve.ServeEngine` with the legacy slot
  pool — finished slots refilled from the queue on the very next step,
- **paged**: the block-granular engine with chunked prefill.

A separate *mixed-prompt-length* section pins the paged engine's two
structural wins and records them in ``BENCH_serve.json``:

- compile churn: the paged engine compiles exactly ONE prefill and ONE
  decode executable for any prompt-length mix (jit cache counters
  asserted), while the slot engine compiles a prefill per distinct
  length;
- memory: at EQUAL paged-leaf cache bytes the paged pool serves strictly
  more concurrent sequences than the slot pool.

A *multi-tenant* section pins the prefix cache's two wins on the
workload it exists for — N tenants sharing a long system prompt with
short unique user turns, arrivals staggered one request per step:

- prefill work: at a fixed total prompt length, prefill *launches*
  must be strictly decreasing as the share ratio rises (0 -> 0.5 -> 1
  of the system prompt reused across a tenant's requests) — shared
  full blocks are mapped by refcount, never re-prefilled;
- admitted concurrency: at EQUAL cache bytes (same block pool) the
  sharing engine must reach a strictly higher peak of concurrently
  running sequences than a ``prefix_cache=False`` baseline, because
  the admission gate charges only *new* blocks against the pool.

A *speculative* section benchmarks quantized self-draft decoding
(``repro.spec``) on a weight-traffic-bound cell: acceptance rate per
draft bitwidth, end-to-end tokens/s vs the non-spec paged engine, and
p50/p99 per-step decode latency, with a hard ``>= 1.3x`` speedup gate at
the cheapest draft (CI fails the build if speculation stops paying).

A *paged-vs-slot gate* section asserts the tentpole claim — the paged
path is the fast path — at every weight bitwidth: at an equal-or-smaller
KV-byte budget the paged engine (oversubscribed rows, the capacity
paging buys) must reach ``>=`` the slot engine's tokens/s.  Drives run
as time-adjacent order-rotated pairs and the gate takes the MEDIAN
per-pair ratio over ``--gate-trials`` pairs, so shared-machine noise
hits both modes alike and a single stalled drive cannot flip the verdict.

A *quantized-KV* section exercises the int8/int4 block pool end to end:
exact token parity against the fp-KV oracle (``kv_oracle=True`` stores
the QDQ values ``kernels/ref.py`` attends — equality, not allclose),
executable pins (still ONE prefill + ONE decode with quantized blocks),
per-bitwidth kv8/kv4 tokens/s, and the capacity gate: at equal cache
bytes the int4-KV pool must run ``>= 2x`` the slot pool's peak
concurrent sequences.

Prints ``name,tokens_per_s,derived`` CSV rows (useful tokens only — a
finished sequence's padding steps never count for any mode).  All modes
share one jit cache per policy; a warmup pass runs before timing.

``BENCH_serve.json`` schema (trajectory diffs key off these fields):

- ``tokens_per_s``: per weight-bitwidth ``{static, continuous, paged,
  continuous_vs_static, paged_vs_static}`` wall-clock tokens/s,
- ``paged_mixed_prompts``: per pool kind ``{prefill_executables,
  decode_executables, peak_concurrent, kv_bytes, tokens_per_s,
  preemptions}`` + ``distinct_prompt_lens``,
- ``paged_vs_slot_gate``: per weight-bitwidth ``{slot, paged}`` best-of
  tokens/s, ``ratio`` (median per-pair paged/slot, the ``>= 1``
  assertion) and ``pair_ratios`` at equal KV bytes, plus the gate's own
  workload size and the paged row/block budget,
- ``kv_quant``: ``parity`` (oracle exact-match), ``executables``
  (prefill/decode pins), ``tokens_per_s`` (kv8/kv4 paged rows) and
  ``concurrency_int4`` (peak sequences at equal bytes vs slot, the
  ``>= 2x`` assertion),
- ``multi_tenant``: prefix-cache section — per share-ratio
  ``{prefill_launches, prefix_hit_rate, hit_tokens, cow_copies,
  peak_concurrent, preemptions}`` (launches strictly decreasing with
  ratio, the assertion) plus ``concurrency`` ``{shared_peak,
  baseline_peak, num_blocks, kv_bytes}`` — shared peak strictly above
  the ``prefix_cache=False`` baseline at equal cache bytes — and the
  section's tenant/prompt geometry,
- ``speculative``: per draft-bitwidth acceptance/speedup medians,
- ``hotpath``: the one-token-hotpath gate — ``{baseline, hotpath}``
  best-of tokens/s, ``ratio`` (median per-pair hotpath/baseline, the
  ``>= 1.15`` assertion), ``pair_ratios``, the attribution split
  (``decode_host_p50_ms <= 0.25 * decode_step_p50_ms`` asserted on the
  hotpath engine), pipeline lookahead/bubble counts, and the executable
  pins (still ONE prefill + ONE decode),
- ``observability``: ``overhead`` (median enabled/disabled tokens-per-s
  ratio, the ``>= 0.97`` tracing-overhead gate) + ``smoke_trace``
  (event/drop counts, recompiles-after-warmup, span names, device/host
  p50 of the traced multi-tenant speculative run; the Chrome trace
  itself lands in ``results/trace_smoke.json``),
- ``provenance``: git sha / timestamp / jax version / device count
  (``repro.obs.run_provenance``) stamped on every record.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.obs import run_provenance
from repro.obs.trace import Tracer
from repro.quant.qat import policy_for
from repro.serve import SamplingParams, ServeEngine
from repro.spec import SpecConfig, snap_params_to_grid
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_prefill,
    make_verify_chunk,
    quantize_for_serving,
)


def make_workload(n: int, prompt_len: int, gen: int, vocab: int, seed: int = 0):
    """(prompts (n, prompt_len), gens (n,)) — gen lengths spread over
    [gen//4, gen] so static batches always carry stragglers."""
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, (n, prompt_len), dtype=np.int64)
    lo = max(1, gen // 4)
    gens = np.linspace(lo, gen, n).round().astype(int)
    return prompts, rng.permutation(gens)


def run_static(model, sparams, prompts, gens, batch, max_len,
               prefill_fn, decode_fn) -> tuple[float, int]:
    """Fixed-batch loop -> (seconds, useful tokens)."""
    n = len(prompts)
    total = 0
    t0 = time.perf_counter()
    for lo in range(0, n, batch):
        p = jnp.asarray(prompts[lo:lo + batch])
        g = gens[lo:lo + batch]
        logits, cache = prefill_fn(sparams, p, max_len)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        emitted = np.ones(len(g), np.int64)  # prefill token
        for _ in range(int(g.max())):
            logits, cache = decode_fn(sparams, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
            emitted += emitted < g + 1  # only unfinished sequences count
        total += int(emitted.sum())
    return time.perf_counter() - t0, total


def run_continuous(model, sparams, prompts, gens, num_slots, max_len,
                   prefill_fn, decode_fn, **kw) -> dict:
    engine = ServeEngine(model, sparams, num_slots=num_slots,
                         max_len=max_len, decode_fn=decode_fn,
                         prefill_fn=prefill_fn, **kw)
    for p, g in zip(prompts, gens):
        engine.submit(p, int(g) + 1)
    return engine.run_until_drained()


def run_paged_mixed(model, sparams, cfg, args) -> dict:
    """Mixed-prompt-length section: slot vs paged at equal KV bytes.

    Asserts the paged engine's acceptance contract — exactly one prefill
    and one decode executable for the whole length mix (jit cache
    counters), and strictly more concurrent sequences than the slot pool
    at an equal-or-smaller KV-byte budget — and returns the numbers for
    ``BENCH_serve.json``.
    """
    rng = np.random.default_rng(2)
    n = args.requests
    max_len = args.prompt_len + args.gen + 1
    bs = args.block_size
    plens = np.linspace(2, args.prompt_len, n).round().astype(int)
    prompts = [rng.integers(0, cfg.vocab_size, int(l)) for l in plens]
    gens = rng.permutation(
        np.linspace(max(1, args.gen // 4), args.gen, n).round().astype(int))
    # equal-bytes budget: paged pool (incl the garbage block) holds at most
    # floor(slot tokens / bs) blocks — never MORE KV bytes than the slot pool
    num_blocks = args.batch * max_len // bs
    setups = {
        "slot": dict(cache="slot", num_slots=args.batch),
        "paged": dict(cache="paged", num_slots=2 * args.batch,
                      block_size=bs, num_blocks=num_blocks,
                      prefill_chunk=args.prefill_chunk),
    }
    out = {}
    for kind, kw in setups.items():
        prefill_fn = (make_chunked_prefill(model, donate=False)
                      if kind == "paged" else make_prefill(model))
        decode_fn = make_decode_step(model, donate=False)

        def drive():
            eng = ServeEngine(model, sparams, max_len=max_len,
                              prefill_fn=prefill_fn, decode_fn=decode_fn,
                              **kw)
            for p, g in zip(prompts, gens):
                eng.submit(p, int(g) + 1)
            peak = 0
            t0 = time.perf_counter()
            while eng.scheduler.has_work():
                eng.step()
                peak = max(peak, eng.num_running)
            return eng, peak, time.perf_counter() - t0

        drive()  # warmup: all compiles land outside timing (same shapes,
        #          so the executable counters below are unchanged)
        eng, peak, dt = drive()
        m = eng.metrics()
        out[kind] = {
            "prefill_executables": prefill_fn._cache_size(),
            "decode_executables": decode_fn._cache_size(),
            "peak_concurrent": peak,
            "kv_bytes": eng.pool.cache_bytes(),
            "tokens_per_s": round(m["tokens_total"] / dt, 1),
            "preemptions": m.get("preemptions", 0),
        }
    assert out["paged"]["prefill_executables"] == 1, out
    assert out["paged"]["decode_executables"] == 1, out
    assert out["paged"]["kv_bytes"] <= out["slot"]["kv_bytes"], out
    assert out["paged"]["peak_concurrent"] > out["slot"]["peak_concurrent"], out
    out["distinct_prompt_lens"] = len(set(int(l) for l in plens))
    return out


def run_paged_gate(model, cfg, args, params) -> dict:
    """The tentpole gate: paged >= slot tokens/s at every weight bitwidth.

    Fair fight at equal KV bytes: the slot pool serves ``batch`` rows of
    ``max_len`` tokens; the paged pool gets the SAME byte budget
    (``batch * max_len // block_size`` usable blocks) but ``2 * batch``
    sequence rows — oversubscription is the capacity block granularity
    buys, and more concurrent rows per decode step is where the
    throughput comes from.  The gate runs its own workload size
    (>= 24 requests, >= 48 generated tokens) regardless of
    ``--requests``/``--gen``: short drives (~0.2 s) are dominated by OS
    scheduling noise on a shared box, and a shallow queue never exercises
    the oversubscribed slots.  Noise discipline: per bitwidth the slot
    and paged drives run as time-adjacent order-rotated PAIRS and the
    gate asserts the MEDIAN per-pair ratio >= 1 over ``--gate-trials``
    pairs (pairing cancels minutes-scale machine-load drift; the median
    rejects a single stalled drive).
    """
    n_gate = max(args.requests, 24)
    gen_gate = max(args.gen, 48)
    prompts, gens = make_workload(n_gate, args.prompt_len, gen_gate,
                                  cfg.vocab_size, seed=5)
    max_len = args.prompt_len + gen_gate + 1
    bs = args.block_size
    num_blocks = args.batch * max_len // bs  # equal-bytes budget
    out: dict = {"trials": args.gate_trials,
                 "requests": n_gate, "gen": gen_gate,
                 "paged_num_slots": 2 * args.batch,
                 "paged_num_blocks": num_blocks}
    for bits in args.bits:
        sparams = quantize_for_serving(model, params,
                                       policy_for(model, default_bits=bits))
        prefill_slot = make_prefill(model)
        prefill_paged = make_chunked_prefill(model, donate=False)
        decode_fn = make_decode_step(model, donate=False)

        def drive(kind):
            if kind == "slot":
                kw = dict(cache="slot", num_slots=args.batch,
                          prefill_fn=prefill_slot)
            else:
                kw = dict(cache="paged", num_slots=2 * args.batch,
                          block_size=bs, num_blocks=num_blocks,
                          prefill_chunk=args.prefill_chunk,
                          prefill_fn=prefill_paged)
            eng = ServeEngine(model, sparams, max_len=max_len,
                              decode_fn=decode_fn, **kw)
            for p, g in zip(prompts, gens):
                eng.submit(p, int(g) + 1)
            m = eng.run_until_drained()
            return m["tokens_per_s"], eng.pool.cache_bytes()

        best = {"slot": 0.0, "paged": 0.0}
        kv_bytes = {}
        for kind in ("slot", "paged"):  # warmup: compiles land outside
            _, kv_bytes[kind] = drive(kind)
        assert kv_bytes["paged"] <= kv_bytes["slot"], kv_bytes
        pair_ratios = []
        for t in range(args.gate_trials):
            order = (("slot", "paged") if t % 2 == 0
                     else ("paged", "slot"))
            pair = {}
            for kind in order:
                pair[kind], _ = drive(kind)
                best[kind] = max(best[kind], pair[kind])
            pair_ratios.append(pair["paged"] / pair["slot"])
        median = sorted(pair_ratios)[len(pair_ratios) // 2]
        out[str(bits)] = {
            "slot": round(best["slot"], 1),
            "paged": round(best["paged"], 1),
            "ratio": round(median, 3),
            "pair_ratios": [round(r, 3) for r in pair_ratios],
            "kv_bytes": kv_bytes,
        }
        assert median >= 1.0, (
            f"paged-fast-path gate: median paged/slot tokens-per-s ratio "
            f"{median:.3f} < 1 at {bits}-bit weights (equal KV bytes "
            f"{kv_bytes['paged']} <= {kv_bytes['slot']}) — {out}")
    return out


def run_kv_quant(model, cfg, args, sparams) -> dict:
    """Quantized-KV section: oracle parity, executable pins, kv8/kv4
    tokens/s, and the int4 equal-bytes concurrency gate.

    - **parity**: the int4-KV engine must emit EXACTLY the tokens of the
      fp-KV oracle engine (``kv_oracle=True`` stores the QDQ values the
      ``kernels/ref.py`` oracle attends) — the dequantized codes·scale
      product is bitwise the stored oracle float, so this is equality.
    - **executables**: mixed prompt lengths through the quantized pool
      still compile exactly ONE prefill and ONE decode (per-layer bits
      are data, not shape).
    - **concurrency**: at an equal-or-smaller byte budget than the slot
      pool, int4 blocks (half the bytes of fp16 KV even with per-token
      f32 scales at the smoke head_dim) must run ``>= 2x`` the slot
      pool's peak concurrent sequences.
    """
    from repro.serve import PagedCachePool, SlotCachePool

    rng = np.random.default_rng(7)
    n = args.requests
    max_len = args.prompt_len + args.gen + 1
    bs = args.block_size
    plens = np.linspace(2, args.prompt_len, n).round().astype(int)
    prompts = [rng.integers(0, cfg.vocab_size, int(l)) for l in plens]
    gens = rng.permutation(
        np.linspace(max(1, args.gen // 4), args.gen, n).round().astype(int))

    def drive(**kw):
        eng = ServeEngine(model, sparams, max_len=max_len, cache="paged",
                          block_size=bs, prefill_chunk=args.prefill_chunk,
                          **kw)
        for p, g in zip(prompts, gens):
            eng.submit(p, int(g) + 1)
        peak = 0
        t0 = time.perf_counter()
        while eng.scheduler.has_work():
            eng.step()
            peak = max(peak, eng.num_running)
        dt = time.perf_counter() - t0
        outs = [eng.output(i) for i in range(n)]
        return eng, outs, peak, eng.metrics()["tokens_total"] / dt

    # --- fp-KV-oracle token parity (deterministic: exact equality)
    _, got, _, _ = drive(num_slots=args.batch, kv_bits=4)
    _, want, _, _ = drive(num_slots=args.batch, kv_bits=4, kv_oracle=True)
    assert got == want, "int4-KV tokens diverge from the fp-KV oracle"
    out: dict = {"parity": {"kv_bits": 4, "oracle_match": True,
                            "requests": n}}

    # --- executable pins with quantized blocks (mixed prompt lengths)
    prefill_fn = make_chunked_prefill(model, donate=False)
    decode_fn = make_decode_step(model, donate=False)
    eng, _, _, _ = drive(num_slots=args.batch, kv_bits=8,
                         prefill_fn=prefill_fn, decode_fn=decode_fn)
    out["executables"] = {"prefill": prefill_fn._cache_size(),
                          "decode": decode_fn._cache_size()}
    assert out["executables"] == {"prefill": 1, "decode": 1}, out

    # --- kv8/kv4 paged tokens/s (trajectory rows; reuse the warm
    # executables-section fns — kv_bits is data, so kv8 and kv4 share
    # the SAME compiled prefill/decode and the rows time steady state)
    fns = dict(prefill_fn=prefill_fn, decode_fn=decode_fn)
    out["tokens_per_s"] = {}
    for kv_bits in (8, 4):
        drive(num_slots=args.batch, kv_bits=kv_bits, **fns)
        _, _, _, tps = drive(num_slots=args.batch, kv_bits=kv_bits, **fns)
        out["tokens_per_s"][f"kv{kv_bits}"] = round(tps, 1)

    # --- int4 equal-bytes concurrency: >= 2x the slot pool's peak
    slot_bytes = SlotCachePool(model, args.batch, max_len).cache_bytes()
    probe = PagedCachePool(model, 1, max_len, block_size=bs, kv_bits=4)
    per_block = probe.cache_bytes() / probe.num_blocks
    num_blocks = int(slot_bytes // per_block)
    eng_slot = ServeEngine(model, sparams, num_slots=args.batch,
                           max_len=max_len, cache="slot")
    for p, g in zip(prompts, gens):
        eng_slot.submit(p, int(g) + 1)
    slot_peak = 0
    while eng_slot.scheduler.has_work():
        eng_slot.step()
        slot_peak = max(slot_peak, eng_slot.num_running)
    eng, _, q4_peak, _ = drive(num_slots=4 * args.batch, kv_bits=4,
                               num_blocks=num_blocks)
    out["concurrency_int4"] = {
        "slot_peak": slot_peak,
        "paged_int4_peak": q4_peak,
        "slot_kv_bytes": slot_bytes,
        "paged_kv_bytes": eng.pool.cache_bytes(),
        "ratio": round(q4_peak / max(slot_peak, 1), 2),
    }
    assert eng.pool.cache_bytes() <= slot_bytes, out["concurrency_int4"]
    assert q4_peak >= 2 * slot_peak, (
        f"int4-KV concurrency gate: peak {q4_peak} < 2x slot peak "
        f"{slot_peak} at equal cache bytes — {out['concurrency_int4']}")
    return out


def run_multi_tenant(model, cfg, args, sparams) -> dict:
    """Multi-tenant prefix-cache section: N tenants x shared system
    prompt x short user turns, arrivals staggered one per step (so a
    tenant's later requests see the blocks its first request published).

    Two sub-gates, both deterministic (counts and peaks, not timing):

    - **launch sweep** (ample blocks): at a FIXED total prompt length,
      raise the share ratio — the fraction of the prompt drawn from the
      tenant's system prompt — through 0 / 0.5 / 1 and assert prefill
      launches strictly decrease: shared full blocks are mapped by
      refcount instead of re-prefilled, and only the unique tail still
      runs chunks.  One prefill + one decode executable across the
      whole sweep (partial prefill reuses the fixed-shape chunk).
    - **concurrency gate** (tight blocks, equal bytes): the SAME pool
      (same ``num_blocks``, byte-identical) serves the full-share
      workload with and without ``prefix_cache``.  The pool is sized so
      a no-sharing admission (every request charged its full
      ``ceil((prompt+1)/bs)`` blocks) can hold only a couple of
      sequences, while the sharing gate — which charges *new* blocks
      only — admits every tenant's tail alongside one copy of each
      system prompt.  Peak concurrently-running sequences must be
      strictly higher with sharing.
    """
    bs = args.block_size
    T = args.mt_tenants
    S = args.mt_shared // bs * bs  # full blocks only — hits are block-granular
    plen = S + args.mt_user
    gen = 8
    max_len = plen + gen + 1
    blocks_per_seq = -(-max_len // bs)
    n = max(args.requests, 2 * T)
    rng = np.random.default_rng(11)
    sys_prompts = rng.integers(0, cfg.vocab_size, (max(T, 2), S))

    def make_prompts(n_req, tenants, ratio, seed):
        r = np.random.default_rng(seed)
        shared = int(S * ratio) // bs * bs
        prompts = r.integers(0, cfg.vocab_size, (n_req, plen))
        for i in range(n_req):
            prompts[i, :shared] = sys_prompts[i % tenants, :shared]
        return prompts

    def drive(prompts, prefill_fn, decode_fn, *, num_slots, num_blocks,
              prefix_cache=True):
        eng = ServeEngine(model, sparams, num_slots=num_slots,
                          max_len=max_len, cache="paged", block_size=bs,
                          num_blocks=num_blocks,
                          prefill_chunk=args.prefill_chunk,
                          prefill_fn=prefill_fn, decode_fn=decode_fn,
                          prefix_cache=prefix_cache)
        submitted, peak = 0, 0
        while submitted < len(prompts) or eng.scheduler.has_work():
            while submitted < len(prompts) and eng.steps >= submitted:
                eng.submit(prompts[submitted], gen + 1)
                submitted += 1
            eng.step()
            peak = max(peak, eng.num_running)
        return eng, peak

    # --- launch sweep: ample pool (full capacity per slot + slack, so
    # launch counts are preemption-free and exactly reproducible)
    ample = args.batch * (blocks_per_seq + 2) + 1
    prefill_fn = make_chunked_prefill(model, donate=False)
    decode_fn = make_decode_step(model, donate=False)
    out: dict = {"tenants": T, "shared_tokens": S, "user_tokens": plen - S,
                 "requests": n, "gen": gen, "ratios": {}}
    launches = []
    for ratio in (0.0, 0.5, 1.0):
        prompts = make_prompts(n, T, ratio, seed=23)
        eng, peak = drive(prompts, prefill_fn, decode_fn,
                          num_slots=args.batch, num_blocks=ample)
        m = eng.metrics()
        pc = m["prefix_cache"]
        assert m["preemptions"] == 0, (ratio, m["preemptions"])
        out["ratios"][str(ratio)] = {
            "prefill_launches": m["prefill_launches"],
            "prefix_hit_rate": round(m["prefix_hit_rate"], 3),
            "hit_tokens": pc["hit_tokens"],
            "cow_copies": pc["cow_copies"],
            "peak_concurrent": peak,
            "preemptions": m["preemptions"],
        }
        launches.append(m["prefill_launches"])
    assert launches[0] > launches[1] > launches[2], (
        f"multi-tenant gate: prefill launches {launches} not strictly "
        f"decreasing over share ratios 0/0.5/1 — {out}")
    assert prefill_fn._cache_size() == 1, prefill_fn._cache_size()
    assert decode_fn._cache_size() == 1, decode_fn._cache_size()
    out["prefill_launches"] = launches

    # --- concurrency gate: tight pool, equal bytes, full sharing.
    # Sized from the sharing engine's true demand — one prefix chain per
    # tenant, every request's unique tail + decode block, the 1-block
    # admission watermark per sequence, a little slack, the garbage
    # block — which is far below n_gate * blocks-per-request, the rent a
    # no-sharing admission charges.
    t_gate, n_gate = 2, max(args.requests, 8)
    prefix_blocks = S // bs
    gate_blocks = (t_gate * prefix_blocks
                   + n_gate * (blocks_per_seq - prefix_blocks)
                   + n_gate + 2 + 1)
    prompts = make_prompts(n_gate, t_gate, 1.0, seed=29)
    pf2 = make_chunked_prefill(model, donate=False)
    df2 = make_decode_step(model, donate=False)
    beng, base_peak = drive(prompts, pf2, df2, num_slots=n_gate,
                            num_blocks=gate_blocks, prefix_cache=False)
    weng, shared_peak = drive(prompts, pf2, df2, num_slots=n_gate,
                              num_blocks=gate_blocks, prefix_cache=True)
    assert weng.pool.cache_bytes() == beng.pool.cache_bytes()
    out["concurrency"] = {
        "shared_peak": shared_peak,
        "baseline_peak": base_peak,
        "requests": n_gate, "tenants": t_gate,
        "num_blocks": gate_blocks,
        "kv_bytes": weng.pool.cache_bytes(),
    }
    assert shared_peak > base_peak, (
        f"multi-tenant gate: shared peak concurrency {shared_peak} not "
        f"above the no-sharing baseline {base_peak} at equal cache "
        f"bytes — {out['concurrency']}")
    assert pf2._cache_size() == 1 and df2._cache_size() == 1
    return out


def run_spec(args) -> dict:
    """Speculative section: acceptance x draft bitwidth + tokens/s vs the
    non-spec paged engine, with the ``>= 1.3x`` gate at the cheapest
    draft.

    Runs on its own d256/L4 glm4 cell regardless of ``--arch``: the smoke
    dims are dispatch-bound (every decode step costs the same regardless
    of bitwidth), so a low-bit draft cannot win there — speculation's
    margin only appears once per-step cost scales with weight traffic.
    Weights are first snapped onto the cheapest draft's quantization grid
    (:func:`repro.spec.draft.snap_params_to_grid`), which makes every
    low-bit re-pack LOSSLESS: acceptance ~ 1 by construction and honestly
    measured, so the section isolates the *mechanical* speedup ceiling
    (draft roll at ~bits/8 of target traffic + one k+1-wide amortized
    verify) from draft quality, which is a property of trained weights.
    """
    dm = args.spec_cell
    cfg = replace(get_config("glm4-9b", smoke=True), name="spec-cell",
                  d_model=dm, d_ff=2 * dm, num_layers=4,
                  num_heads=dm // 32, head_dim=32, num_kv_heads=2)
    model = build_model(cfg)
    params = snap_params_to_grid(model, model.init(jax.random.PRNGKey(0)),
                                 min(args.spec_draft_bits))
    sparams = quantize_for_serving(model, params,
                                   policy_for(model, default_bits=8))
    # homogeneous gens at full occupancy: every decode step carries all
    # `batch` rows, so median step latency / tokens-per-step is a clean
    # per-token cost (the gate metric — medians over ~100 steps reject
    # shared-machine noise that wall-clock tokens/s cannot)
    rng = np.random.default_rng(3)
    n = 2 * args.batch
    prompts = [rng.integers(0, cfg.vocab_size, args.prompt_len)
               for _ in range(n)]
    gens = np.full(n, args.gen)
    max_len = args.prompt_len + args.gen + 1
    prefill_fn = make_chunked_prefill(model, donate=False)
    decode_fn = make_decode_step(model, donate=False)
    verify_fn = make_verify_chunk(model, donate=False)

    def drive(spec):
        eng = ServeEngine(model, sparams, num_slots=args.batch,
                          max_len=max_len, cache="paged",
                          block_size=args.block_size,
                          prefill_chunk=args.prefill_chunk,
                          prefill_fn=prefill_fn, decode_fn=decode_fn,
                          verify_fn=verify_fn, spec=spec)
        for p, g in zip(prompts, gens):
            eng.submit(p, int(g) + 1)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        m = eng.metrics()
        return m, m["tokens_total"] / dt

    def step_ms(m):
        return {"decode_step_p50_ms": round(m["decode_step_p50_ms"], 3),
                "decode_step_p99_ms": round(m["decode_step_p99_ms"], 3)}

    def per_token_ms(m):
        """Median over decode steps of (step latency / tokens that step
        emitted) — the gate metric.  Truncated tail windows carry their
        own (cheap step, few tokens) ratio instead of skewing a global
        mean, and the median rejects shared-machine latency spikes."""
        return m["decode_tok_p50_ms"]

    # warmups: land every compile (prefill, 8b decode, per-bits draft
    # decode, verify) outside the timed drives
    specs = [None] + [SpecConfig(k=args.spec_k, draft_bits=b)
                      for b in args.spec_draft_bits]
    for spec in specs:
        drive(spec)
    # best-of-N per mode, modes interleaved: a transient slowdown of the
    # shared machine lands inside ONE drive, not inside every drive of one
    # mode — the gate compares each mode's cleanest median
    best: dict = {}
    for _ in range(args.spec_trials):
        for spec in specs:
            m, tps = drive(spec)
            key = spec.draft_bits if spec else None
            if key not in best or per_token_ms(m) < per_token_ms(best[key][0]):
                best[key] = (m, tps)
    m0, tps0 = best[None]
    out = {
        "cell": {"arch": "glm4-9b", "d_model": cfg.d_model,
                 "num_layers": cfg.num_layers},
        "k": args.spec_k,
        "target_bits": 8,
        "trials": args.spec_trials,
        "baseline": {"tokens_per_s": round(tps0, 1),
                     "per_token_ms": round(per_token_ms(m0), 3),
                     **step_ms(m0)},
        "drafts": {},
    }
    for bits in args.spec_draft_bits:
        m, tps = best[bits]
        out["drafts"][str(bits)] = {
            "tokens_per_s": round(tps, 1),
            "per_token_ms": round(per_token_ms(m), 3),
            "speedup_vs_paged": round(per_token_ms(m0) / per_token_ms(m), 3),
            "acceptance_rate": round(m["spec"]["acceptance_rate"], 3),
            "proposed": m["spec"]["proposed"],
            "accepted": m["spec"]["accepted"],
            **step_ms(m),
        }
    top = max(d["speedup_vs_paged"] for d in out["drafts"].values())
    assert top >= 1.3, (
        f"speculative decoding gate: best speedup {top:.3f}x < 1.3x "
        f"over non-spec paged on the spec cell — {out}")
    return out


def run_hotpath_gate(args) -> dict:
    """One-token hotpath section: on-device sampling + the lookahead
    pipeline vs the synchronous host-sampling engine, with two gates.

    Runs on its own wide-vocab cell (``--hotpath-vocab``, smoke glm4
    body): the host path's per-step cost — the ``(rows, V)`` logits
    fetch plus a per-row float64 ``warp_probs`` — scales with the vocab
    while the device step barely does, so this is the regime the
    tentpole exists for.  The workload samples (temperature 1, nucleus
    0.9): host sampling is the cost being moved on device, and greedy
    token parity between the two paths is already pinned in
    tests/test_sampler_device.py — this section measures throughput.
    All ``2 * batch == num_slots`` requests are submitted up front with
    homogeneous budgets, so after admission the queue is empty and the
    lookahead pipeline runs steady-state.

    Gates (CI fails the build on either):

    - **throughput**: hotpath tokens/s ``>= 1.15x`` the host-sampling
      baseline — same noise discipline as the paged-vs-slot gate
      (time-adjacent order-rotated pairs, MEDIAN per-pair ratio over
      ``--gate-trials`` pairs);
    - **attribution**: on the hotpath engine ``decode_host_p50_ms <=
      0.25 * decode_step_p50_ms`` — the Python serving loop stays off
      the critical path (dispatch counts as device time, so the bound
      means the same thing on asynchronous and synchronous backends).

    Also asserts the executable pins survive: ONE prefill + ONE decode
    jit entry after serving both modes (the shared sampler jit is
    tracked separately by the engine's recompile detector).
    """
    vocab = args.hotpath_vocab
    cfg = replace(get_config("glm4-9b", smoke=True), name="hotpath-cell",
                  vocab_size=vocab)
    model = build_model(cfg)
    sparams = quantize_for_serving(model, model.init(jax.random.PRNGKey(0)),
                                   policy_for(model, default_bits=8))
    prefill_fn = make_chunked_prefill(model, donate=False)
    decode_fn = make_decode_step(model, donate=False)
    rng = np.random.default_rng(23)
    n = 2 * args.batch
    gen = max(args.gen, 48)
    prompts = [rng.integers(0, vocab, args.prompt_len) for _ in range(n)]
    max_len = args.prompt_len + gen + 1
    sampling = SamplingParams(temperature=1.0, top_p=0.9, seed=29)

    def drive(hot):
        eng = ServeEngine(model, sparams, num_slots=n, max_len=max_len,
                          cache="paged", block_size=args.block_size,
                          prefill_chunk=args.prefill_chunk,
                          prefill_fn=prefill_fn, decode_fn=decode_fn,
                          sample_device=hot, pipeline=hot)
        for p in prompts:
            eng.submit(p, gen + 1, sampling=sampling)
        return eng.run_until_drained()

    for hot in (False, True):  # warmup: compiles land outside timing
        drive(hot)
    best: dict = {}
    pair_ratios = []
    for t in range(args.gate_trials):
        order = (False, True) if t % 2 == 0 else (True, False)
        pair = {}
        for hot in order:
            m = drive(hot)
            pair[hot] = m["tokens_per_s"]
            if hot not in best or pair[hot] > best[hot]["tokens_per_s"]:
                best[hot] = m
        pair_ratios.append(pair[True] / pair[False])
    median = sorted(pair_ratios)[len(pair_ratios) // 2]
    mh = best[True]
    out = {
        "cell": {"arch": "glm4-9b", "vocab_size": vocab},
        # engine modes under test, in launch/serve.py flag terms — so a
        # regression here is bisectable with the same switches
        "modes": {"baseline": "--host-sampling --no-pipeline",
                  "hotpath": "default (device sampling + pipeline)"},
        "trials": args.gate_trials, "requests": n, "gen": gen,
        "baseline": round(best[False]["tokens_per_s"], 1),
        "hotpath": round(mh["tokens_per_s"], 1),
        "ratio": round(median, 3),
        "pair_ratios": [round(r, 3) for r in pair_ratios],
        "decode_step_p50_ms": round(mh["decode_step_p50_ms"], 3),
        "decode_host_p50_ms": round(mh["decode_host_p50_ms"], 3),
        "host_fraction_p50": round(mh["decode_host_p50_ms"]
                                   / mh["decode_step_p50_ms"], 3),
        "pipeline": mh["pipeline"],
        "executables": {"prefill": prefill_fn._cache_size(),
                        "decode": decode_fn._cache_size()},
    }
    assert median >= 1.15, (
        f"hotpath throughput gate: median hotpath/baseline tokens-per-s "
        f"ratio {median:.3f} < 1.15 — {out}")
    assert (mh["decode_host_p50_ms"]
            <= 0.25 * mh["decode_step_p50_ms"]), (
        f"hotpath attribution gate: decode_host_p50 "
        f"{mh['decode_host_p50_ms']:.3f} ms > 0.25 x step p50 "
        f"{mh['decode_step_p50_ms']:.3f} ms — {out}")
    assert mh["pipeline"]["lookahead_steps"] > 0, out
    assert out["executables"] == {"prefill": 1, "decode": 1}, out
    return out


def run_obs_gate(model, cfg, args, sparams, trace_path: str | None) -> dict:
    """Observability section: the tracing-overhead gate plus a traced
    multi-tenant speculative smoke run exported as a Chrome-trace file.

    - **overhead gate**: tokens/s with a live ``Tracer`` + registry must
      stay within 3% of the tracing-disabled engine (``span()`` on a
      disabled tracer is one attribute check; instrument observes are a
      lock + float add).  Same noise discipline as the paged-vs-slot
      gate: time-adjacent order-rotated pairs, MEDIAN per-pair ratio
      over ``--gate-trials`` pairs.
    - **smoke trace**: two tenants sharing a system prompt, staggered
      arrivals, speculative decoding on — the full acceptance scenario —
      traced end to end and saved to ``trace_smoke.json`` (CI uploads
      it; open at ui.perfetto.dev).  Asserts the trace is *balanced*
      (zero open spans after the drain), shows ZERO ``xla.compile``
      events after warmup, attributes every decode step into device vs
      host time, and round-trips as valid Chrome-trace JSON (every
      event carries name/ph/ts/pid/tid; X events carry dur).
    """
    n_gate = max(args.requests, 24)
    gen_gate = max(args.gen, 48)
    prompts, gens = make_workload(n_gate, args.prompt_len, gen_gate,
                                  cfg.vocab_size, seed=13)
    max_len = args.prompt_len + gen_gate + 1
    prefill_fn = make_chunked_prefill(model, donate=False)
    decode_fn = make_decode_step(model, donate=False)

    def drive(tracer):
        eng = ServeEngine(model, sparams, num_slots=args.batch,
                          max_len=max_len, cache="paged",
                          block_size=args.block_size,
                          prefill_chunk=args.prefill_chunk,
                          prefill_fn=prefill_fn, decode_fn=decode_fn,
                          tracer=tracer)
        for p, g in zip(prompts, gens):
            eng.submit(p, int(g) + 1)
        m = eng.run_until_drained()
        return m["tokens_per_s"]

    for kind in ("off", "on"):  # warmup: compiles land outside timing
        drive(Tracer(enabled=True) if kind == "on" else None)
    pair_ratios = []
    for t in range(args.gate_trials):
        order = ("off", "on") if t % 2 == 0 else ("on", "off")
        pair = {}
        for kind in order:
            pair[kind] = drive(Tracer(enabled=True) if kind == "on"
                               else None)
        pair_ratios.append(pair["on"] / pair["off"])
    median = sorted(pair_ratios)[len(pair_ratios) // 2]
    out: dict = {"overhead": {
        "ratio": round(median, 4),
        "pair_ratios": [round(r, 4) for r in pair_ratios],
        "trials": args.gate_trials, "requests": n_gate, "gen": gen_gate,
    }}
    assert median >= 0.97, (
        f"tracing-overhead gate: median enabled/disabled tokens-per-s "
        f"ratio {median:.4f} < 0.97 (3% budget) — {out}")

    # --- traced multi-tenant speculative smoke run
    bs = args.block_size
    S, plen, gen, n = 2 * bs, 3 * bs, 16, 6
    rng = np.random.default_rng(17)
    sys_prompts = rng.integers(0, cfg.vocab_size, (2, S))
    sprompts = rng.integers(0, cfg.vocab_size, (n, plen))
    for i in range(n):
        sprompts[i, :S] = sys_prompts[i % 2]
    smax_len = plen + gen + 1
    pf = make_chunked_prefill(model, donate=False)
    df = make_decode_step(model, donate=False)
    vf = make_verify_chunk(model, donate=False)

    def smoke(tracer):
        eng = ServeEngine(model, sparams, num_slots=4, max_len=smax_len,
                          cache="paged", block_size=bs,
                          prefill_chunk=args.prefill_chunk,
                          prefill_fn=pf, decode_fn=df, verify_fn=vf,
                          spec=SpecConfig(k=4, draft_bits=4),
                          tracer=tracer)
        submitted = 0
        while submitted < n or eng.scheduler.has_work():
            while submitted < n and eng.steps >= 2 * submitted:
                eng.submit(sprompts[submitted], gen + 1)
                submitted += 1
            eng.step()
        return eng

    smoke(None)  # warmup: draft/verify/prefill compiles land here
    tracer = Tracer(enabled=True)
    tracer.name_thread("serve-loop")
    eng = smoke(tracer)
    m = eng.metrics()
    assert m["recompiles"] == 0, (
        f"smoke trace saw {m['recompiles']} xla.compile events after "
        f"warmup — steady-state serving must not recompile")
    assert tracer.depth() == 0, (
        f"unbalanced trace: {tracer.depth()} spans still open after "
        f"the drain")
    names = {e["name"] for e in tracer.events()}
    for want in ("queue.wait", "admit", "prefill.chunk", "decode.step",
                 "spec.draft", "spec.verify", "spec.resolve"):
        assert want in names, f"smoke trace missing {want!r} spans: {names}"
    assert "decode_device_p50_ms" in m and "decode_host_p50_ms" in m, m
    doc = tracer.to_chrome()
    for ev in doc["traceEvents"]:  # schema check, then round-trip
        for key in ("name", "ph", "pid", "tid"):
            assert key in ev, ev
        assert ev["ph"] in ("X", "i", "M"), ev
        if ev["ph"] == "X":
            assert "dur" in ev and "ts" in ev, ev
    json.loads(json.dumps(doc))
    out["smoke_trace"] = {
        "events": tracer.num_events,
        "dropped": tracer.dropped,
        "span_names": sorted(names),
        "recompiles": m["recompiles"],
        "spec_acceptance": round(m["spec"]["acceptance_rate"], 3),
        "prefix_hits": m["prefix_hits"],
        "prefix_lookups": m["prefix_lookups"],
        "decode_device_p50_ms": round(m["decode_device_p50_ms"], 3),
        "decode_host_p50_ms": round(m["decode_host_p50_ms"], 3),
    }
    if trace_path:
        os.makedirs(os.path.dirname(trace_path) or ".", exist_ok=True)
        tracer.save(trace_path)
        out["smoke_trace"]["path"] = trace_path
    return out


def bench(args):
    """-> (csv rows, (cfg, model, sparams at args.bits[0]) for reuse)."""
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, gens = make_workload(args.requests, args.prompt_len, args.gen,
                                  cfg.vocab_size)
    max_len = args.prompt_len + args.gen + 1
    rows = []
    first_sparams = None
    for bits in args.bits:
        sparams = quantize_for_serving(model, params,
                                       policy_for(model, default_bits=bits))
        if first_sparams is None:
            first_sparams = sparams
        prefill_fn = make_prefill(model)
        # static batch == num_slots -> identical decode executable
        decode_fn = make_decode_step(model, donate=False)
        # warm both paths: every static batch size that will occur (the
        # tail batch compiles its own executables) and the batch-1
        # admission prefill (continuous), so compiles land outside timing
        warm_sizes = {args.batch}
        if args.requests % args.batch:
            warm_sizes.add(args.requests % args.batch)
        chunk_fn = make_chunked_prefill(model, donate=False)
        for b in warm_sizes:
            run_static(model, sparams, prompts[:b], np.minimum(gens[:b], 2),
                       b, max_len, prefill_fn, decode_fn)
        run_continuous(model, sparams, prompts[:2], np.minimum(gens[:2], 2),
                       args.batch, max_len, prefill_fn, decode_fn,
                       cache="slot")
        run_continuous(model, sparams, prompts[:2], np.minimum(gens[:2], 2),
                       args.batch, max_len, chunk_fn, decode_fn,
                       cache="paged", block_size=args.block_size,
                       prefill_chunk=args.prefill_chunk)

        dt, total = run_static(model, sparams, prompts, gens, args.batch,
                               max_len, prefill_fn, decode_fn)
        tps_static = total / dt
        rows.append((f"serve_static@{bits}b", tps_static,
                     f"tokens={total};batch={args.batch}"))

        m = run_continuous(model, sparams, prompts, gens, args.batch,
                           max_len, prefill_fn, decode_fn, cache="slot")
        tps_cont = m["tokens_per_s"]
        rows.append((f"serve_continuous@{bits}b", tps_cont,
                     f"tokens={m['tokens_total']};"
                     f"occupancy={m['mean_occupancy']:.2f};"
                     f"vs_static={tps_cont / max(tps_static, 1e-9):.2f}x"))

        m = run_continuous(model, sparams, prompts, gens, args.batch,
                           max_len, chunk_fn, decode_fn, cache="paged",
                           block_size=args.block_size,
                           prefill_chunk=args.prefill_chunk)
        tps_paged = m["tokens_per_s"]
        rows.append((f"serve_paged@{bits}b", tps_paged,
                     f"tokens={m['tokens_total']};"
                     f"block_occ={m['mean_block_occupancy']:.2f};"
                     f"vs_static={tps_paged / max(tps_static, 1e-9):.2f}x"))
    return rows, (cfg, model, first_sparams)


def write_record(args, rows, path: str, paged_mixed: dict | None = None,
                 speculative: dict | None = None,
                 paged_gate: dict | None = None,
                 kv_quant: dict | None = None,
                 multi_tenant: dict | None = None,
                 observability: dict | None = None,
                 hotpath: dict | None = None) -> dict:
    """Persist the per-bitwidth static/continuous/paged tokens/s plus the
    mixed-prompt-length paged section so the perf trajectory is comparable
    across PRs (CI uploads this file as an artifact; humans diff it).
    Every record carries a ``provenance`` stamp (git sha, timestamp, jax
    version, device count) so a perf number stays interpretable."""
    per_bits: dict[str, dict] = {}
    for name, tps, derived in rows:
        mode, b = name.replace("serve_", "").split("@")
        per_bits.setdefault(b, {})[mode] = round(tps, 1)
    for b, d in per_bits.items():
        if "static" in d and "continuous" in d and d["static"] > 0:
            d["continuous_vs_static"] = round(d["continuous"] / d["static"], 3)
        if "static" in d and "paged" in d and d["static"] > 0:
            d["paged_vs_static"] = round(d["paged"] / d["static"], 3)
    rec = {
        "benchmark": "serve_bench",
        "provenance": run_provenance(),
        "arch": args.arch, "smoke": bool(args.smoke),
        "requests": args.requests, "batch": args.batch,
        "prompt_len": args.prompt_len, "gen": args.gen,
        "block_size": args.block_size, "prefill_chunk": args.prefill_chunk,
        "tokens_per_s": per_bits,
    }
    if paged_mixed is not None:
        rec["paged_mixed_prompts"] = paged_mixed
    if paged_gate is not None:
        rec["paged_vs_slot_gate"] = paged_gate
    if kv_quant is not None:
        rec["kv_quant"] = kv_quant
    if multi_tenant is not None:
        rec["multi_tenant"] = multi_tenant
    if speculative is not None:
        rec["speculative"] = speculative
    if observability is not None:
        rec["observability"] = observability
    if hotpath is not None:
        rec["hotpath"] = hotpath
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "results",
                           "BENCH_serve.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--bits", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size == continuous slot count")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="paged engine: fixed prefill chunk length")
    ap.add_argument("--gate-trials", type=int, default=3,
                    help="paged-vs-slot gate: timed drives per mode "
                         "(interleaved best-of, noise rejection)")
    ap.add_argument("--kv", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the quantized-KV section (oracle parity, "
                         "executable pins, int4 2x-concurrency gate)")
    ap.add_argument("--mt", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the multi-tenant prefix-cache section "
                         "(launch sweep + equal-bytes concurrency gate)")
    ap.add_argument("--mt-tenants", type=int, default=4,
                    help="multi-tenant section: tenants in the launch "
                         "sweep (each owns one system prompt)")
    ap.add_argument("--mt-shared", type=int, default=512,
                    help="multi-tenant section: system-prompt tokens "
                         "(rounded down to whole blocks)")
    ap.add_argument("--mt-user", type=int, default=16,
                    help="multi-tenant section: unique user-turn tokens "
                         "appended per request")
    ap.add_argument("--spec", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the speculative-decoding section (1.3x gate)")
    ap.add_argument("--spec-k", type=int, default=8,
                    help="speculative window (draft tokens per step)")
    ap.add_argument("--spec-trials", type=int, default=3,
                    help="timed drives per mode (best-of, noise rejection)")
    ap.add_argument("--spec-cell", type=int, default=512,
                    help="spec-section cell width (d_model; d_ff/heads "
                         "scale with it)")
    ap.add_argument("--spec-draft-bits", type=int, nargs="+", default=[2, 4],
                    help="draft bitwidths to sweep (weights snapped to the "
                         "cheapest one's grid)")
    ap.add_argument("--hotpath", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the one-token-hotpath section (>= 1.15x "
                         "throughput gate + <= 0.25 host-fraction gate)")
    ap.add_argument("--hotpath-vocab", type=int, default=4096,
                    help="hotpath-section cell vocab (host sampling cost "
                         "scales with it; device step barely does)")
    ap.add_argument("--obs", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the observability section (<= 3% tracing-"
                         "overhead gate + traced multi-tenant spec smoke "
                         "run exported as a Chrome trace)")
    ap.add_argument("--trace-out",
                    default=os.path.join(os.path.dirname(__file__),
                                         "results", "trace_smoke.json"),
                    help="Chrome-trace path for the smoke run "
                         "('' disables the file)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON record path ('' disables)")
    args = ap.parse_args()

    rows, (cfg, model, sparams) = bench(args)
    print("name,tokens_per_s,derived")
    for name, tps, derived in rows:
        print(f"{name},{tps:.1f},{derived}", flush=True)
    gate = run_paged_gate(model, cfg, args,
                          model.init(jax.random.PRNGKey(0)))
    for b in args.bits:
        d = gate[str(b)]
        print(f"paged_gate@{b}b,{d['paged']:.1f},"
              f"slot={d['slot']:.1f};ratio={d['ratio']:.3f}x;"
              f"equal_kv_bytes", flush=True)
    kv = None
    if args.kv:
        kv = run_kv_quant(model, cfg, args, sparams)
        c = kv["concurrency_int4"]
        print(f"kv_quant: oracle_parity=exact "
              f"executables=1/1 "
              f"kv8={kv['tokens_per_s']['kv8']:.1f} "
              f"kv4={kv['tokens_per_s']['kv4']:.1f} tok/s, "
              f"int4 peak_concurrent {c['paged_int4_peak']} >= "
              f"2x slot {c['slot_peak']} at kv_bytes "
              f"{c['paged_kv_bytes']} <= {c['slot_kv_bytes']}", flush=True)
    mt = None
    if args.mt:
        mt = run_multi_tenant(model, cfg, args, sparams)
        c = mt["concurrency"]
        print(f"multi_tenant: prefill_launches "
              f"{' > '.join(str(l) for l in mt['prefill_launches'])} "
              f"over share 0/0.5/1 ({mt['tenants']} tenants x "
              f"{mt['shared_tokens']}-token system prompt), "
              f"peak_concurrent shared={c['shared_peak']} vs "
              f"no-sharing={c['baseline_peak']} at equal kv_bytes "
              f"{c['kv_bytes']}", flush=True)
    mixed = run_paged_mixed(model, sparams, cfg, args)
    print(f"paged_mixed: prefill_executables="
          f"{mixed['paged']['prefill_executables']} "
          f"(slot compiled {mixed['slot']['prefill_executables']} for "
          f"{mixed['distinct_prompt_lens']} lengths), "
          f"peak_concurrent paged={mixed['paged']['peak_concurrent']} "
          f"vs slot={mixed['slot']['peak_concurrent']} at "
          f"kv_bytes {mixed['paged']['kv_bytes']} <= "
          f"{mixed['slot']['kv_bytes']}", flush=True)
    obs = None
    if args.obs:
        obs = run_obs_gate(model, cfg, args, sparams, args.trace_out)
        st = obs["smoke_trace"]
        print(f"observability: tracing overhead ratio "
              f"{obs['overhead']['ratio']:.4f} >= 0.97, smoke trace "
              f"{st['events']} events ({st['dropped']} dropped, "
              f"{st['recompiles']} recompiles), device/host p50 "
              f"{st['decode_device_p50_ms']:.2f}/"
              f"{st['decode_host_p50_ms']:.2f} ms"
              + (f" -> {st['path']}" if "path" in st else ""), flush=True)
    hot = None
    if args.hotpath:
        hot = run_hotpath_gate(args)
        print(f"hotpath: {hot['hotpath']:.1f} vs baseline "
              f"{hot['baseline']:.1f} tok/s "
              f"(median ratio {hot['ratio']:.3f}x >= 1.15), host p50 "
              f"{hot['decode_host_p50_ms']:.2f} ms = "
              f"{hot['host_fraction_p50']:.3f} of step "
              f"{hot['decode_step_p50_ms']:.2f} ms (<= 0.25), "
              f"lookahead {hot['pipeline']['lookahead_steps']} / bubbles "
              f"{hot['pipeline']['bubbles']}, executables 1/1", flush=True)
    spec = None
    if args.spec:
        spec = run_spec(args)
        base = spec["baseline"]["tokens_per_s"]
        print(f"serve_spec_paged@8b,{base:.1f},"
              f"cell=d{spec['cell']['d_model']}L{spec['cell']['num_layers']};"
              f"k={spec['k']}", flush=True)
        for bits, d in spec["drafts"].items():
            print(f"serve_spec@{bits}b_draft,{d['tokens_per_s']:.1f},"
                  f"acceptance={d['acceptance_rate']:.3f};"
                  f"speedup={d['speedup_vs_paged']:.2f}x;"
                  f"p50={d['decode_step_p50_ms']:.2f}ms;"
                  f"p99={d['decode_step_p99_ms']:.2f}ms", flush=True)
    if args.out:
        write_record(args, rows, args.out, paged_mixed=mixed,
                     speculative=spec, paged_gate=gate, kv_quant=kv,
                     multi_tenant=mt, observability=obs, hotpath=hot)
        print(f"wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
