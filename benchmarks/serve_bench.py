# Static vs continuous (slot/paged) batching tokens/s at ReLeQ policies.
"""Serving benchmark: ``python -m benchmarks.serve_bench [--arch glm4-9b]``.

One workload of requests with heterogeneous output lengths, served three
ways at each ``--bits`` policy:

- **static**: the legacy fixed-batch loop — each batch decodes until its
  *longest* member finishes, early finishers idle their slot,
- **continuous**: :class:`repro.serve.ServeEngine` with the legacy slot
  pool — finished slots refilled from the queue on the very next step,
- **paged**: the block-granular engine with chunked prefill.

A separate *mixed-prompt-length* section pins the paged engine's two
structural wins and records them in ``BENCH_serve.json``:

- compile churn: the paged engine compiles exactly ONE prefill and ONE
  decode executable for any prompt-length mix (jit cache counters
  asserted), while the slot engine compiles a prefill per distinct
  length;
- memory: at EQUAL paged-leaf cache bytes the paged pool serves strictly
  more concurrent sequences than the slot pool.

Prints ``name,tokens_per_s,derived`` CSV rows (useful tokens only — a
finished sequence's padding steps never count for any mode).  All modes
share one jit cache per policy; a warmup pass runs before timing.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import ServeEngine
from repro.train.serve import (
    make_chunked_prefill,
    make_decode_step,
    make_prefill,
    quantize_for_serving,
)


def make_workload(n: int, prompt_len: int, gen: int, vocab: int, seed: int = 0):
    """(prompts (n, prompt_len), gens (n,)) — gen lengths spread over
    [gen//4, gen] so static batches always carry stragglers."""
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, (n, prompt_len), dtype=np.int64)
    lo = max(1, gen // 4)
    gens = np.linspace(lo, gen, n).round().astype(int)
    return prompts, rng.permutation(gens)


def run_static(model, sparams, prompts, gens, batch, max_len,
               prefill_fn, decode_fn) -> tuple[float, int]:
    """Fixed-batch loop -> (seconds, useful tokens)."""
    n = len(prompts)
    total = 0
    t0 = time.perf_counter()
    for lo in range(0, n, batch):
        p = jnp.asarray(prompts[lo:lo + batch])
        g = gens[lo:lo + batch]
        logits, cache = prefill_fn(sparams, p, max_len)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        emitted = np.ones(len(g), np.int64)  # prefill token
        for _ in range(int(g.max())):
            logits, cache = decode_fn(sparams, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
            emitted += emitted < g + 1  # only unfinished sequences count
        total += int(emitted.sum())
    return time.perf_counter() - t0, total


def run_continuous(model, sparams, prompts, gens, num_slots, max_len,
                   prefill_fn, decode_fn, **kw) -> dict:
    engine = ServeEngine(model, sparams, num_slots=num_slots,
                         max_len=max_len, decode_fn=decode_fn,
                         prefill_fn=prefill_fn, **kw)
    for p, g in zip(prompts, gens):
        engine.submit(p, int(g) + 1)
    return engine.run_until_drained()


def run_paged_mixed(model, sparams, cfg, args) -> dict:
    """Mixed-prompt-length section: slot vs paged at equal KV bytes.

    Asserts the paged engine's acceptance contract — exactly one prefill
    and one decode executable for the whole length mix (jit cache
    counters), and strictly more concurrent sequences than the slot pool
    at an equal-or-smaller KV-byte budget — and returns the numbers for
    ``BENCH_serve.json``.
    """
    rng = np.random.default_rng(2)
    n = args.requests
    max_len = args.prompt_len + args.gen + 1
    bs = args.block_size
    plens = np.linspace(2, args.prompt_len, n).round().astype(int)
    prompts = [rng.integers(0, cfg.vocab_size, int(l)) for l in plens]
    gens = rng.permutation(
        np.linspace(max(1, args.gen // 4), args.gen, n).round().astype(int))
    # equal-bytes budget: paged pool (incl the garbage block) holds at most
    # floor(slot tokens / bs) blocks — never MORE KV bytes than the slot pool
    num_blocks = args.batch * max_len // bs
    setups = {
        "slot": dict(cache="slot", num_slots=args.batch),
        "paged": dict(cache="paged", num_slots=2 * args.batch,
                      block_size=bs, num_blocks=num_blocks,
                      prefill_chunk=args.prefill_chunk),
    }
    out = {}
    for kind, kw in setups.items():
        prefill_fn = (make_chunked_prefill(model, donate=False)
                      if kind == "paged" else make_prefill(model))
        decode_fn = make_decode_step(model, donate=False)

        def drive():
            eng = ServeEngine(model, sparams, max_len=max_len,
                              prefill_fn=prefill_fn, decode_fn=decode_fn,
                              **kw)
            for p, g in zip(prompts, gens):
                eng.submit(p, int(g) + 1)
            peak = 0
            t0 = time.perf_counter()
            while eng.scheduler.has_work():
                eng.step()
                peak = max(peak, eng.num_running)
            return eng, peak, time.perf_counter() - t0

        drive()  # warmup: all compiles land outside timing (same shapes,
        #          so the executable counters below are unchanged)
        eng, peak, dt = drive()
        m = eng.metrics()
        out[kind] = {
            "prefill_executables": prefill_fn._cache_size(),
            "decode_executables": decode_fn._cache_size(),
            "peak_concurrent": peak,
            "kv_bytes": eng.pool.cache_bytes(),
            "tokens_per_s": round(m["tokens_total"] / dt, 1),
            "preemptions": m.get("preemptions", 0),
        }
    assert out["paged"]["prefill_executables"] == 1, out
    assert out["paged"]["decode_executables"] == 1, out
    assert out["paged"]["kv_bytes"] <= out["slot"]["kv_bytes"], out
    assert out["paged"]["peak_concurrent"] > out["slot"]["peak_concurrent"], out
    out["distinct_prompt_lens"] = len(set(int(l) for l in plens))
    return out


def bench(args):
    """-> (csv rows, (cfg, model, sparams at args.bits[0]) for reuse)."""
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, gens = make_workload(args.requests, args.prompt_len, args.gen,
                                  cfg.vocab_size)
    max_len = args.prompt_len + args.gen + 1
    rows = []
    first_sparams = None
    for bits in args.bits:
        sparams = quantize_for_serving(model, params,
                                       policy_for(model, default_bits=bits))
        if first_sparams is None:
            first_sparams = sparams
        prefill_fn = make_prefill(model)
        # static batch == num_slots -> identical decode executable
        decode_fn = make_decode_step(model, donate=False)
        # warm both paths: every static batch size that will occur (the
        # tail batch compiles its own executables) and the batch-1
        # admission prefill (continuous), so compiles land outside timing
        warm_sizes = {args.batch}
        if args.requests % args.batch:
            warm_sizes.add(args.requests % args.batch)
        chunk_fn = make_chunked_prefill(model, donate=False)
        for b in warm_sizes:
            run_static(model, sparams, prompts[:b], np.minimum(gens[:b], 2),
                       b, max_len, prefill_fn, decode_fn)
        run_continuous(model, sparams, prompts[:2], np.minimum(gens[:2], 2),
                       args.batch, max_len, prefill_fn, decode_fn,
                       cache="slot")
        run_continuous(model, sparams, prompts[:2], np.minimum(gens[:2], 2),
                       args.batch, max_len, chunk_fn, decode_fn,
                       cache="paged", block_size=args.block_size,
                       prefill_chunk=args.prefill_chunk)

        dt, total = run_static(model, sparams, prompts, gens, args.batch,
                               max_len, prefill_fn, decode_fn)
        tps_static = total / dt
        rows.append((f"serve_static@{bits}b", tps_static,
                     f"tokens={total};batch={args.batch}"))

        m = run_continuous(model, sparams, prompts, gens, args.batch,
                           max_len, prefill_fn, decode_fn, cache="slot")
        tps_cont = m["tokens_per_s"]
        rows.append((f"serve_continuous@{bits}b", tps_cont,
                     f"tokens={m['tokens_total']};"
                     f"occupancy={m['mean_occupancy']:.2f};"
                     f"vs_static={tps_cont / max(tps_static, 1e-9):.2f}x"))

        m = run_continuous(model, sparams, prompts, gens, args.batch,
                           max_len, chunk_fn, decode_fn, cache="paged",
                           block_size=args.block_size,
                           prefill_chunk=args.prefill_chunk)
        tps_paged = m["tokens_per_s"]
        rows.append((f"serve_paged@{bits}b", tps_paged,
                     f"tokens={m['tokens_total']};"
                     f"block_occ={m['mean_block_occupancy']:.2f};"
                     f"vs_static={tps_paged / max(tps_static, 1e-9):.2f}x"))
    return rows, (cfg, model, first_sparams)


def write_record(args, rows, path: str, paged_mixed: dict | None = None) -> dict:
    """Persist the per-bitwidth static/continuous/paged tokens/s plus the
    mixed-prompt-length paged section so the perf trajectory is comparable
    across PRs (CI uploads this file as an artifact; humans diff it)."""
    per_bits: dict[str, dict] = {}
    for name, tps, derived in rows:
        mode, b = name.replace("serve_", "").split("@")
        per_bits.setdefault(b, {})[mode] = round(tps, 1)
    for b, d in per_bits.items():
        if "static" in d and "continuous" in d and d["static"] > 0:
            d["continuous_vs_static"] = round(d["continuous"] / d["static"], 3)
        if "static" in d and "paged" in d and d["static"] > 0:
            d["paged_vs_static"] = round(d["paged"] / d["static"], 3)
    rec = {
        "benchmark": "serve_bench",
        "arch": args.arch, "smoke": bool(args.smoke),
        "requests": args.requests, "batch": args.batch,
        "prompt_len": args.prompt_len, "gen": args.gen,
        "block_size": args.block_size, "prefill_chunk": args.prefill_chunk,
        "tokens_per_s": per_bits,
    }
    if paged_mixed is not None:
        rec["paged_mixed_prompts"] = paged_mixed
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "results",
                           "BENCH_serve.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--bits", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size == continuous slot count")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="paged engine: fixed prefill chunk length")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON record path ('' disables)")
    args = ap.parse_args()

    rows, (cfg, model, sparams) = bench(args)
    print("name,tokens_per_s,derived")
    for name, tps, derived in rows:
        print(f"{name},{tps:.1f},{derived}", flush=True)
    mixed = run_paged_mixed(model, sparams, cfg, args)
    print(f"paged_mixed: prefill_executables="
          f"{mixed['paged']['prefill_executables']} "
          f"(slot compiled {mixed['slot']['prefill_executables']} for "
          f"{mixed['distinct_prompt_lens']} lengths), "
          f"peak_concurrent paged={mixed['paged']['peak_concurrent']} "
          f"vs slot={mixed['slot']['peak_concurrent']} at "
          f"kv_bytes {mixed['paged']['kv_bytes']} <= "
          f"{mixed['slot']['kv_bytes']}", flush=True)
    if args.out:
        write_record(args, rows, args.out, paged_mixed=mixed)
        print(f"wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
