# Static vs continuous batching tokens/s at ReLeQ bitwidth policies.
"""Serving benchmark: ``python -m benchmarks.serve_bench [--arch glm4-9b]``.

One workload of requests with heterogeneous output lengths, served two
ways at each ``--bits`` policy:

- **static**: the legacy fixed-batch loop — each batch decodes until its
  *longest* member finishes, early finishers idle their slot,
- **continuous**: :class:`repro.serve.ServeEngine` — finished slots are
  refilled from the queue on the very next step.

Prints ``name,tokens_per_s,derived`` CSV rows (useful tokens only — a
finished sequence's padding steps never count for either mode).  Both
modes share one jit cache per policy; a warmup pass runs before timing.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.quant.qat import policy_for
from repro.serve import ServeEngine
from repro.train.serve import make_decode_step, make_prefill, quantize_for_serving


def make_workload(n: int, prompt_len: int, gen: int, vocab: int, seed: int = 0):
    """(prompts (n, prompt_len), gens (n,)) — gen lengths spread over
    [gen//4, gen] so static batches always carry stragglers."""
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, (n, prompt_len), dtype=np.int64)
    lo = max(1, gen // 4)
    gens = np.linspace(lo, gen, n).round().astype(int)
    return prompts, rng.permutation(gens)


def run_static(model, sparams, prompts, gens, batch, max_len,
               prefill_fn, decode_fn) -> tuple[float, int]:
    """Fixed-batch loop -> (seconds, useful tokens)."""
    n = len(prompts)
    total = 0
    t0 = time.perf_counter()
    for lo in range(0, n, batch):
        p = jnp.asarray(prompts[lo:lo + batch])
        g = gens[lo:lo + batch]
        logits, cache = prefill_fn(sparams, p, max_len)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        emitted = np.ones(len(g), np.int64)  # prefill token
        for _ in range(int(g.max())):
            logits, cache = decode_fn(sparams, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
            emitted += emitted < g + 1  # only unfinished sequences count
        total += int(emitted.sum())
    return time.perf_counter() - t0, total


def run_continuous(model, sparams, prompts, gens, num_slots, max_len,
                   prefill_fn, decode_fn) -> dict:
    engine = ServeEngine(model, sparams, num_slots=num_slots,
                         max_len=max_len, decode_fn=decode_fn,
                         prefill_fn=prefill_fn)
    for p, g in zip(prompts, gens):
        engine.submit(p, int(g) + 1)
    return engine.run_until_drained()


def bench(args) -> list[tuple[str, float, str]]:
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, gens = make_workload(args.requests, args.prompt_len, args.gen,
                                  cfg.vocab_size)
    max_len = args.prompt_len + args.gen + 1
    rows = []
    for bits in args.bits:
        sparams = quantize_for_serving(model, params,
                                       policy_for(model, default_bits=bits))
        prefill_fn = make_prefill(model)
        # static batch == num_slots -> identical decode executable
        decode_fn = make_decode_step(model, donate=False)
        # warm both paths: every static batch size that will occur (the
        # tail batch compiles its own executables) and the batch-1
        # admission prefill (continuous), so compiles land outside timing
        warm_sizes = {args.batch}
        if args.requests % args.batch:
            warm_sizes.add(args.requests % args.batch)
        for b in warm_sizes:
            run_static(model, sparams, prompts[:b], np.minimum(gens[:b], 2),
                       b, max_len, prefill_fn, decode_fn)
        run_continuous(model, sparams, prompts[:2], np.minimum(gens[:2], 2),
                       args.batch, max_len, prefill_fn, decode_fn)

        dt, total = run_static(model, sparams, prompts, gens, args.batch,
                               max_len, prefill_fn, decode_fn)
        tps_static = total / dt
        rows.append((f"serve_static@{bits}b", tps_static,
                     f"tokens={total};batch={args.batch}"))

        m = run_continuous(model, sparams, prompts, gens, args.batch,
                           max_len, prefill_fn, decode_fn)
        tps_cont = m["tokens_per_s"]
        rows.append((f"serve_continuous@{bits}b", tps_cont,
                     f"tokens={m['tokens_total']};"
                     f"occupancy={m['mean_occupancy']:.2f};"
                     f"vs_static={tps_cont / max(tps_static, 1e-9):.2f}x"))
    return rows


def write_record(args, rows, path: str) -> dict:
    """Persist the per-bitwidth static/continuous tokens/s so the perf
    trajectory is comparable across PRs (CI and humans diff this file)."""
    per_bits: dict[str, dict] = {}
    for name, tps, derived in rows:
        mode, b = name.replace("serve_", "").split("@")
        per_bits.setdefault(b, {})[mode] = round(tps, 1)
    for b, d in per_bits.items():
        if "static" in d and "continuous" in d and d["static"] > 0:
            d["continuous_vs_static"] = round(d["continuous"] / d["static"], 3)
    rec = {
        "benchmark": "serve_bench",
        "arch": args.arch, "smoke": bool(args.smoke),
        "requests": args.requests, "batch": args.batch,
        "prompt_len": args.prompt_len, "gen": args.gen,
        "tokens_per_s": per_bits,
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "results",
                           "BENCH_serve.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--bits", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size == continuous slot count")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON record path ('' disables)")
    args = ap.parse_args()

    rows = bench(args)
    print("name,tokens_per_s,derived")
    for name, tps, derived in rows:
        print(f"{name},{tps:.1f},{derived}", flush=True)
    if args.out:
        write_record(args, rows, args.out)
        print(f"wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
