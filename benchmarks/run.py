# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: ``python -m benchmarks.run [--full] [--only NAME]``.

quick (default): the RL-driven artifacts run on the CPU-budget networks
with shortened searches; --full widens to the 7-network Table-2 sweep.
The roofline rows come from the dry-run records if present.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import paper
    from benchmarks import roofline as rf

    nets = paper.FULL_NETS if args.full else paper.QUICK_NETS
    benches = [
        ("table2", lambda: paper.table2_bitwidths(nets)),
        ("fig5", paper.fig5_policy_evolution),
        ("fig6", paper.fig6_pareto),
        ("fig7", paper.fig7_learning_curves),
        ("fig8", lambda: paper.fig8_tvm_speedup(nets)),
        ("fig9", lambda: paper.fig9_stripes(nets)),
        ("table4", paper.table4_admm),
        ("table5", paper.table5_ppo_clip),
        ("fig10", paper.fig10_reward_ablation),
        ("lstm_ablation", paper.lstm_ablation),
        ("qmm", paper.qmm_microbench),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
        except Exception as e:  # keep the harness going; surface the failure
            print(f"{name},0.0,ERROR:{type(e).__name__}:{str(e)[:120]}",
                  flush=True)
            continue
        print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)

    # roofline rows (from dry-run artifacts, if the sweep has run)
    try:
        for r in rf.rows(rf.load_records()):
            if r["status"] == "skipped":
                print(f"roofline/{r['cell'].replace(' ', '')},0.0,skipped")
            else:
                print(f"roofline/{r['cell'].replace(' ', '')},0.0,"
                      f"bottleneck={r['bottleneck']};frac={r['roofline_frac']:.3f};"
                      f"peakGB={r['peak_gb']:.1f}")
    except Exception:
        pass


if __name__ == "__main__":
    main()
